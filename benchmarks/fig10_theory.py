"""Paper Fig. 10: theoretical vs experimental running time.

Calibrates the two cost-model constants (t_flop from a leaf matmul
micro-benchmark, t_elem from a block-add micro-benchmark) — the same
implicit normalization the paper applies — then reports predicted vs
measured wall-clock for a grid of (n, depth) and their Pearson r.
"""
from __future__ import annotations

import functools

import jax
import numpy as np

from benchmarks.common import emit, rand, time_fn
from repro.core.cost_model import CostModel, total_cost
from repro.core.strassen import strassen_matmul

GRID = [(512, 1), (512, 2), (512, 3), (1024, 1), (1024, 2), (1024, 3), (2048, 2)]


def calibrate() -> CostModel:
    """t_flop from a 256^3 matmul; t_elem from a 1M-element add."""
    m = 256
    a, b = rand((m, m)), rand((m, m))
    t_mm = time_fn(jax.jit(lambda x, y: x @ y), a, b)
    t_flop = t_mm / m**3

    v = rand((1024, 1024))
    t_add = time_fn(jax.jit(lambda x: x + x), v)
    t_elem = t_add / v.size
    return CostModel(t_flop=t_flop, t_elem=t_elem)


def run():
    model = calibrate()
    rows = [
        emit("fig10/calibration/t_flop", model.t_flop, "s_per_flop"),
        emit("fig10/calibration/t_elem", model.t_elem, "s_per_elem"),
    ]
    preds, meas = [], []
    for n, depth in GRID:
        a, b = rand((n, n)), rand((n, n))
        t = time_fn(jax.jit(functools.partial(strassen_matmul, depth=depth)), a, b)
        pred = total_cost("stark", n, 2**depth, cores=1, model=model)
        preds.append(pred)
        meas.append(t)
        rows.append(
            emit(f"fig10/stark/n{n}/b{2**depth}", t, f"pred_s={pred:.5f}")
        )
    r = float(np.corrcoef(np.log(preds), np.log(meas))[0, 1])
    rows.append(emit("fig10/pearson_r_log", 0.0, f"r={r:.3f}"))
    return rows

"""Compiler-level validation of the 7/8 claim (beyond-paper artifact).

The paper's central claim is b^2.807 vs b^3 leaf multiplications. On a
real compiler we can verify the FLOP reduction directly: lower naive vs
Strassen matmuls and compare XLA's counted HLO FLOPs. One level should
approach 7/8 = 0.875 of naive (plus O(n^2) add overhead); two levels
(7/8)^2 = 0.766.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core.strassen import strassen_matmul


def _flops(fn, *specs) -> float:
    from repro.core.compat import compiled_cost_analysis

    compiled = jax.jit(fn).lower(*specs).compile()
    return float(compiled_cost_analysis(compiled).get("flops", 0.0))


def run():
    rows = []
    n = 4096
    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    base = _flops(lambda a, b: a @ b, spec, spec)
    rows.append(emit("hlo/naive_flops/n4096", base * 1e-12, "TFLOP"))
    for depth in (1, 2, 3):
        f = _flops(
            functools.partial(strassen_matmul, depth=depth), spec, spec
        )
        rows.append(
            emit(
                f"hlo/strassen_d{depth}_flops/n4096",
                f * 1e-12,
                f"ratio={f/base:.3f};ideal={(7/8)**depth:.3f}",
            )
        )
    return rows

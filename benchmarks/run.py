"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Select subsets with
``python -m benchmarks.run fig8 table6 ...`` (default: all).
"""
from __future__ import annotations

import sys


def main() -> None:
    from benchmarks import (
        autotune_sweep,
        fig8_fastest,
        fig8_scaling,
        fig9_partition,
        fig10_theory,
        fig11_stagewise,
        fig12_scalability,
        roofline_table,
        serve_load,
        spin_scaling,
        strassen_hlo,
        table6_single_node,
        table7_leaf,
    )

    suites = {
        "autotune": autotune_sweep.run,
        "fig8": fig8_fastest.run,
        "fig8_scaling": fig8_scaling.run,
        "table6": table6_single_node.run,
        "table7": table7_leaf.run,
        "fig9": fig9_partition.run,
        "fig10": fig10_theory.run,
        "fig11": fig11_stagewise.run,
        "fig12": fig12_scalability.run,
        "hlo": strassen_hlo.run,
        "roofline": roofline_table.run,
        "serve_load": serve_load.run,
        "spin_scaling": spin_scaling.run,
    }
    wanted = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in wanted:
        if name not in suites:
            raise SystemExit(f"unknown suite {name!r}; have {sorted(suites)}")
        suites[name]()


if __name__ == "__main__":
    main()

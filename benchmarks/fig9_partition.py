"""Paper Fig. 9: running time vs partition size (U-curve), per matrix size.

Partition size b = 2**depth. The paper finds a U: too few partitions ->
big leaf multiplications dominate; too many -> divide/combine overhead
dominates. The same tradeoff appears here as recursion depth: deeper =
smaller leaf matmuls (less O(n^3) work) but more divide/combine passes
(more O(n^2) memory traffic).

Emits measured times AND the paper cost model's prediction for the same
(n, b) so fig10 can correlate them.
"""
from __future__ import annotations

import functools

import jax

from benchmarks.common import emit, rand, time_fn
from repro.core.cost_model import CostModel, total_cost
from repro.core.strassen import strassen_matmul

SIZES = (512, 1024)
DEPTHS = (0, 1, 2, 3, 4)


def run(calibrated: CostModel | None = None):
    model = calibrated or CostModel(t_flop=2e-10, t_elem=1e-9)
    rows = []
    for n in SIZES:
        a, b = rand((n, n)), rand((n, n))
        for depth in DEPTHS:
            fn = jax.jit(functools.partial(strassen_matmul, depth=depth))
            t = time_fn(fn, a, b)
            theory = total_cost("stark", n, 2**depth, cores=1, model=model) if depth else None
            rows.append(
                emit(
                    f"fig9/stark/n{n}/b{2**depth}", t,
                    f"theory_s={theory:.4f}" if theory else "theory_s=na",
                )
            )
    return rows

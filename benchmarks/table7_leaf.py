"""Paper Table VII: theoretical vs actual LEAF-stage computation cost.

The paper caches the leaf blocks and times just the leaf multiplications,
showing the minima of theoretical and measured cost shift together across
partition sizes. We reproduce it: for each depth (partition size
b = 2**depth) time ONLY the batched leaf multiply on precomputed divided
operands, and emit the theoretical per-core cost b^2.807 * (n/b)^3 /
min(b^2.807, cores) alongside (cores=1 here).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from benchmarks.common import emit, rand, time_fn
from repro.core.coefficients import STRASSEN
from repro.core.strassen import divide_level

SIZES = (1024,)
DEPTHS = (1, 2, 3, 4)


def run():
    rows = []
    for n in SIZES:
        a, b = rand((n, n)), rand((n, n))
        ac = jnp.asarray(STRASSEN.a_coef)
        bc = jnp.asarray(STRASSEN.b_coef)
        for depth in DEPTHS:
            ta, tb = a[None], b[None]
            for _ in range(depth):
                ta = divide_level(ta, ac)
                tb = divide_level(tb, bc)
            ta, tb = jax.block_until_ready((ta, tb))
            leaf = jax.jit(lambda x, y: jnp.einsum("mij,mjk->mik", x, y))
            t = time_fn(leaf, ta, tb)
            blk = n >> depth
            theory_flops = (7**depth) * 2.0 * blk**3
            rows.append(
                emit(
                    f"table7/stark_leaf/n{n}/b{2**depth}", t,
                    f"leaves={7**depth};blk={blk};theory_gflop={theory_flops/1e9:.2f}",
                )
            )
            # Marlin/MLLib analogue: b^3 leaf multiplications of the same block size
            naive_leaves = (2**depth) ** 3
            mb = jnp.broadcast_to(ta[:1], (naive_leaves, blk, blk)).copy()
            t2 = time_fn(leaf, mb, mb)
            rows.append(
                emit(
                    f"table7/marlin_leaf/n{n}/b{2**depth}", t2,
                    f"leaves={naive_leaves};vs_stark={t2/t:.2f}x",
                )
            )
    return rows

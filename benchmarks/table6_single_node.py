"""Paper Table VI: single-node systems vs Stark.

Analogue mapping on this container:
  numpy-BLAS   — Colt/JBlas/ParallelColt class (optimized native library)
  serial-naive — the paper's three-loop naive (jnp.dot WITHOUT fusion is
                 already BLAS; we use an explicit einsum on fp64 as the
                 unoptimized stand-in)
  serial-strassen — paper Algorithm 1 (strassen_recursive)
  stark        — batched-BFS Strassen under jit (the distributed pipeline
                 on one device)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, rand, time_fn
from repro.core.strassen import strassen_matmul, strassen_recursive

SIZES = (256, 512, 1024)


def run():
    rows = []
    for n in SIZES:
        a, b = rand((n, n)), rand((n, n))
        an, bn = np.asarray(a), np.asarray(b)

        t_blas = time_fn(lambda: jnp.asarray(an @ bn))
        rows.append(emit(f"table6/numpy_blas/n{n}", t_blas))

        t_rec = time_fn(
            jax.jit(functools.partial(strassen_recursive, threshold=max(n // 8, 64))),
            a, b,
        )
        rows.append(emit(f"table6/serial_strassen/n{n}", t_rec))

        t_stark = time_fn(
            jax.jit(functools.partial(strassen_matmul, depth=2)), a, b
        )
        rows.append(
            emit(f"table6/stark/n{n}", t_stark, f"vs_blas={t_blas/t_stark:.2f}x")
        )
    return rows

"""Benchmark utilities: timing, CSV emission, shared workloads."""
from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

__all__ = ["time_fn", "emit", "rand"]


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall-clock seconds of fn(*args) after warmup (jit-compiles)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


_rng = np.random.default_rng(0)


def rand(shape, dtype=np.float32):
    import jax.numpy as jnp

    return jnp.asarray(_rng.standard_normal(shape).astype(dtype))


def emit(name: str, seconds: float, derived: str = "") -> str:
    """One CSV row: name,us_per_call,derived."""
    row = f"{name},{seconds * 1e6:.1f},{derived}"
    print(row, flush=True)
    return row

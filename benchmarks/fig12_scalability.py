"""Paper Fig. 12: scalability with executor count.

This container has ONE physical core, so wall-clock cannot show real
speedup. We measure what IS measurable from the compiled artifact — the
per-device work division — by lowering the distributed Strassen under
meshes of 1..8 host devices in a SUBPROCESS (device count is locked at
jax init) and reporting per-device HLO FLOPs. Ideal scaling halves
per-device FLOPs per doubling; the derived column reports the achieved
parallel efficiency vs T(1)/n, exactly the paper's ideal-line comparison.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_CHILD = r"""
import os, sys, json
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={sys.argv[1]}"
import functools, jax, jax.numpy as jnp
from repro.core.compat import compiled_cost_analysis, make_mesh
from repro.core.distributed import strassen_bfs_sharded
from repro.runtime.elastic import plan_mesh
n_dev = int(sys.argv[1])
n = 1024
shape, axes = ((n_dev,), ("data",)) if n_dev > 1 else ((1,), ("data",))
mesh = make_mesh(shape, axes)
a = jax.ShapeDtypeStruct((n, n), jnp.float32)
fn = jax.jit(functools.partial(
    strassen_bfs_sharded, mesh=mesh, depth=2, batch_axes=("data",)))
compiled = fn.lower(a, a).compile()
cost = compiled_cost_analysis(compiled)
print(json.dumps({"devices": n_dev, "flops": cost.get("flops", 0.0)}))
"""


def run():
    rows = []
    base = None
    for n_dev in (1, 2, 4, 8):
        env = dict(os.environ)
        env["PYTHONPATH"] = "src"
        out = subprocess.run(
            [sys.executable, "-c", _CHILD, str(n_dev)],
            capture_output=True, text=True, env=env, cwd=os.path.dirname(__file__) + "/..",
        )
        line = out.stdout.strip().splitlines()[-1]
        data = json.loads(line)
        if base is None:
            base = data["flops"]
        eff = base / (data["flops"] * n_dev) if data["flops"] else 0.0
        rows.append(
            emit(
                f"fig12/per_device_flops/dev{n_dev}",
                data["flops"] * 1e-6,  # report as 'us' column = MFLOP count
                f"parallel_efficiency={eff:.2f}",
            )
        )
    return rows

"""Paper Fig. 8: fastest wall-clock time vs matrix size, per system.

Systems (CPU-measurable analogues on this container):
  naive    — XLA's jnp.dot (the MLLib/Marlin leaf engine: one BLAS call;
             both baselines do b^3 block multiplications of this kind)
  stark    — batched-BFS Strassen (core.strassen), best depth per size
  winograd — beyond-paper variant (7 mults, fewer adds)

Like the paper, we report each system's best time over its tunable
parameter (depth = log2 partition size). Paper sizes 4096..16384 are run
scaled-down (256..2048) for single-core CPU measurability; the cost model
(fig10) extrapolates to the paper's cluster scale.
"""
from __future__ import annotations

import functools

import jax

from benchmarks.common import emit, rand, time_fn
from repro.core.strassen import strassen_matmul

SIZES = (256, 512, 1024, 2048)
DEPTHS = (1, 2, 3)


def run():
    rows = []
    for n in SIZES:
        a, b = rand((n, n)), rand((n, n))
        t_naive = time_fn(jax.jit(lambda x, y: x @ y), a, b)
        rows.append(emit(f"fig8/naive/n{n}", t_naive, "depth=0"))
        for scheme in ("strassen", "winograd"):
            best, best_d = None, None
            for depth in DEPTHS:
                fn = jax.jit(
                    functools.partial(strassen_matmul, depth=depth, scheme=scheme)
                )
                t = time_fn(fn, a, b)
                if best is None or t < best:
                    best, best_d = t, depth
            label = "stark" if scheme == "strassen" else "winograd"
            rows.append(
                emit(
                    f"fig8/{label}/n{n}", best,
                    f"best_depth={best_d};vs_naive={t_naive / best:.2f}x",
                )
            )
    return rows

"""Autotune crossover sweep: predicted-vs-measured per shape (paper §V-C).

For each size the calibrated dispatcher enumerates every legal candidate on
an 8-way host-platform mesh, records each candidate's predicted seconds,
executes the selected candidate (plus the naive baseline) for a measured
column, and checks the selected path's output against ``jnp.matmul``.

The crossover the paper reports is a *distributed* effect: a single XLA
device has no shuffle term, so the naive matmul wins every single-device
size here (measured 0.9x at 8192^2 on CPU). On the mesh the naive path pays
the SUMMA panel broadcasts — MLLib's coGroup shuffle in JAX clothing — and
the dispatcher flips to a Strassen strategy once dims clear the leaf
threshold, exactly the §V-C picture.

Standalone (reliable device forcing — must happen before jax init):

    PYTHONPATH=src python benchmarks/autotune_sweep.py \
        [--sizes 256,2048,8192] [--out autotune_sweep.json] [--measure]

Also registered as the ``autotune`` suite in ``benchmarks.run``; when jax
is already initialized with one device the sweep degrades to local-only
candidates and says so in the JSON.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    # 8 host-platform devices, forced before any jax import.
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)  # `benchmarks` package when run as a script

import argparse
import json

import jax
import jax.numpy as jnp


def _make_mesh():
    """(data, model) mesh over whatever devices exist; None if single-device."""
    from repro.core.compat import make_mesh

    d = jax.device_count()
    if d < 2:
        return None
    model = 2
    return make_mesh((d // model, model), ("data", "model"))


def sweep(sizes=(256, 2048, 8192), *, min_dim=1024, max_depth=2, measure=False,
          out_path="autotune_sweep.json"):
    from benchmarks.common import emit, rand, time_fn
    from repro.core import autotune

    mesh = _make_mesh()
    device_count = jax.device_count() if mesh is not None else 1
    calib = autotune.calibrate()
    rows = []
    for n in sizes:
        cands = autotune.enumerate_candidates(
            n, n, n, max_depth=max_depth, min_dim=min_dim, mesh=mesh
        )

        def label_of(kind, scheme, depth):
            if kind == "naive":
                return "naive@d0"
            if kind == scheme:  # local BFS candidate
                return f"{kind}@d{depth}"
            return f"{kind}[{scheme}]@d{depth}"  # mesh strategy per scheme

        predictions = {
            label_of(c.kind, c.scheme, c.depth): autotune.predict_seconds(
                c, n, n, n, calib, device_count=device_count
            )
            for c in cands
        }
        decision = autotune.autotune(
            n, n, n,
            min_dim=min_dim, max_depth=max_depth, mesh=mesh,
            calibration=calib, measure=measure,
        )

        a, b = rand((n, n)), rand((n, n))
        naive_fn = jax.jit(lambda x, y: jnp.matmul(x, y))
        want = naive_fn(a, b)
        t_naive = time_fn(naive_fn, a, b, warmup=1, iters=2)
        sel = decision.candidate
        sel_fn = jax.jit(lambda x, y: autotune.execute(sel, x, y, mesh=mesh))
        got = sel_fn(a, b)
        t_sel = time_fn(sel_fn, a, b, warmup=1, iters=2)
        scale = float(jnp.max(jnp.abs(want))) or 1.0
        rel_err = float(jnp.max(jnp.abs(got - want))) / scale

        label = label_of(decision.kind, decision.scheme, decision.depth)
        rows.append({
            "n": n,
            "selected": label,
            "source": decision.source,
            "predicted_s": {k: round(v, 6) for k, v in sorted(predictions.items())},
            "predicted_selected_s": decision.predicted_s,
            "measured_selected_s": t_sel,
            "measured_naive_s": t_naive,
            "rel_err_vs_naive": rel_err,
            "ok": rel_err < 2e-3,
        })
        emit(f"autotune[{n}]->{label}", t_sel,
             f"naive={t_naive*1e6:.1f}us err={rel_err:.2e}")

    payload = {
        "device_kind": calib.device_kind,
        "device_count": device_count,
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "calibration": calib.to_dict(),
        "min_dim": min_dim,
        "max_depth": max_depth,
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {out_path}", flush=True)
    return payload


def run():
    """benchmarks.run entry point (uses whatever devices jax already has)."""
    sweep()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="256,2048,8192")
    ap.add_argument("--min-dim", type=int, default=1024)
    ap.add_argument("--max-depth", type=int, default=2)
    ap.add_argument("--measure", action="store_true",
                    help="time top-k candidates instead of trusting the model")
    ap.add_argument("--out", default="autotune_sweep.json")
    args = ap.parse_args()
    sizes = tuple(int(s) for s in args.sizes.split(","))
    payload = sweep(
        sizes, min_dim=args.min_dim, max_depth=args.max_depth,
        measure=args.measure, out_path=args.out,
    )
    for row in payload["rows"]:
        print(f"# n={row['n']:6d} -> {row['selected']:24s} "
              f"pred {row['predicted_selected_s']:.4f}s "
              f"meas {row['measured_selected_s']:.4f}s "
              f"naive {row['measured_naive_s']:.4f}s ok={row['ok']}")


if __name__ == "__main__":
    main()

"""Autotune crossover sweep: predicted-vs-measured per shape (paper §V-C).

For each size the calibrated dispatcher enumerates every legal candidate on
an 8-way host-platform mesh, records each candidate's predicted seconds,
executes the selected candidate (plus the naive baseline) for a measured
column, and checks the selected path's output against ``jnp.matmul``.

The crossover the paper reports is a *distributed* effect: a single XLA
device has no shuffle term, so the naive matmul wins every single-device
size here (measured 0.9x at 8192^2 on CPU). On the mesh the naive path pays
the SUMMA panel broadcasts — MLLib's coGroup shuffle in JAX clothing — and
the dispatcher flips to a Strassen strategy once dims clear the leaf
threshold, exactly the §V-C picture.

Standalone (reliable device forcing — must happen before jax init):

    PYTHONPATH=src python benchmarks/autotune_sweep.py \
        [--sizes 256,2048,8192] [--out autotune_sweep.json] [--measure]

CI smoke mode — small dims on the forced 8-device host mesh, plus a gate:

    PYTHONPATH=src python benchmarks/autotune_sweep.py --smoke

``--smoke`` shrinks sizes/min_dim so the run finishes in minutes, dumps
the decision telemetry alongside the crossover table, and EXITS NON-ZERO
if the chosen kind at the largest smoke dim regresses to naive (or any
selected path fails the correctness check) — the bench-smoke CI job's
pass/fail signal.

Also registered as the ``autotune`` suite in ``benchmarks.run``; when jax
is already initialized with one device the sweep degrades to local-only
candidates and says so in the JSON.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    # 8 host-platform devices, forced before any jax import.
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)  # `benchmarks` package when run as a script

import argparse
import json

import jax
import jax.numpy as jnp


def _make_mesh():
    """(data, model) mesh over whatever devices exist; None if single-device."""
    from repro.core.compat import make_mesh

    d = jax.device_count()
    if d < 2:
        return None
    model = 2
    return make_mesh((d // model, model), ("data", "model"))


def sweep(sizes=(256, 2048, 4096), *, min_dim=1024, max_depth=2, measure=False,
          out_path="autotune_sweep.json", calibration=None, oot_budget=None):
    from benchmarks.common import emit, rand, time_fn
    from repro.core import autotune

    mesh = _make_mesh()
    device_count = jax.device_count() if mesh is not None else 1
    calib = calibration or autotune.calibrate()
    autotune.get_telemetry().reset()
    rows = []
    for n in sizes:
        cands = autotune.enumerate_candidates(
            n, n, n, max_depth=max_depth, min_dim=min_dim, mesh=mesh,
            oot_budget=oot_budget,
        )

        def label_of(kind, scheme, depth):
            if kind == "naive":
                return "naive@d0"
            if kind == scheme:  # local BFS candidate
                return f"{kind}@d{depth}"
            return f"{kind}[{scheme}]@d{depth}"  # mesh strategy per scheme

        predictions = {}
        predicted_terms = {}
        for c in cands:
            label = label_of(c.kind, c.scheme, c.depth)
            terms = autotune.predict_cost_terms(
                c, n, n, n, calib, device_count=device_count
            )
            predictions[label] = sum(terms.values())
            # The per-constant split (t_flop/t_elem/t_coll/t_h2d seconds)
            # is the evidence column: for strassen_oot it shows the
            # host<->device staging term next to compute and traffic.
            predicted_terms[label] = {k: round(v, 6) for k, v in terms.items()}
        decision = autotune.autotune(
            n, n, n,
            min_dim=min_dim, max_depth=max_depth, mesh=mesh,
            calibration=calib, measure=measure, oot_budget=oot_budget,
        )

        a, b = rand((n, n)), rand((n, n))
        naive_fn = jax.jit(lambda x, y: jnp.matmul(x, y))
        want = naive_fn(a, b)
        t_naive = time_fn(naive_fn, a, b, warmup=1, iters=2)
        sel = decision.candidate
        if sel.kind == autotune.OOT_KIND:
            # Host-resident pipeline: eager by construction (no jit).
            def sel_fn(x, y):
                return autotune.execute(sel, x, y, oot_budget=oot_budget)
        else:
            sel_fn = jax.jit(lambda x, y: autotune.execute(sel, x, y, mesh=mesh))
        got = sel_fn(a, b)
        t_sel = time_fn(sel_fn, a, b, warmup=1, iters=2)
        scale = float(jnp.max(jnp.abs(want))) or 1.0
        rel_err = float(jnp.max(jnp.abs(got - want))) / scale

        label = label_of(decision.kind, decision.scheme, decision.depth)
        rows.append({
            "n": n,
            "selected": label,
            "source": decision.source,
            "predicted_s": {k: round(v, 6) for k, v in sorted(predictions.items())},
            "predicted_terms": dict(sorted(predicted_terms.items())),
            "predicted_selected_s": decision.predicted_s,
            "measured_selected_s": t_sel,
            "measured_naive_s": t_naive,
            "rel_err_vs_naive": rel_err,
            "ok": rel_err < 2e-3,
        })
        emit(f"autotune[{n}]->{label}", t_sel,
             f"naive={t_naive*1e6:.1f}us err={rel_err:.2e}")

    payload = {
        "device_kind": calib.device_kind,
        "device_count": device_count,
        "mesh": dict(mesh.shape) if mesh is not None else None,
        "calibration": calib.to_dict(),
        "calibration_source": "pinned" if calibration else "measured",
        "min_dim": min_dim,
        "max_depth": max_depth,
        "oot_budget": oot_budget,
        "rows": rows,
        # Decision telemetry for the run: cache hit/miss counters, chosen
        # kind per resolution, predicted-vs-measured seconds per decision.
        "telemetry": autotune.get_telemetry().snapshot(),
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {out_path}", flush=True)
    return payload


def run():
    """benchmarks.run entry point (uses whatever devices jax already has)."""
    sweep()


# Smoke-mode defaults: small enough for a CPU CI runner, large enough that
# the largest dim clears min_dim at depth >= 1 and the mesh strategies can
# out-predict the naive SUMMA term. min_dim sits between the first two
# sizes so the table shows the §V-C flip: 128 -> naive, 256+ -> Strassen.
SMOKE_SIZES = (128, 256, 512)
SMOKE_MIN_DIM = 192


def smoke_calibration():
    """Pinned constants for the CI gate: the pass/fail signal must depend on
    the code's candidate set and cost model, not on whatever t_flop/t_elem
    ratio a loaded shared runner happens to measure at job time. The ratios
    mirror a typical CPU-host fit (elem ~100x flop, coll ~4x elem)."""
    from repro.core import autotune

    dev = jax.devices()[0]
    return autotune.Calibration(
        t_flop=1e-11,
        t_elem=1e-9,
        t_coll=4e-9,
        t_h2d=2e-9,
        device_kind=dev.platform,
        device_count=jax.device_count(),
    )


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    # 4096 is the largest default: above it the interpret-mode Pallas leaf
    # (CPU hosts) unrolls thousands of grid steps at trace time and the
    # measured column takes longer than the information is worth. On a real
    # TPU (compiled leaf) pass --sizes 256,2048,8192,16384 to reproduce the
    # paper-scale crossover table.
    ap.add_argument("--sizes", default="256,2048,4096")
    ap.add_argument("--min-dim", type=int, default=1024)
    ap.add_argument("--max-depth", type=int, default=2)
    ap.add_argument("--measure", action="store_true",
                    help="time top-k candidates instead of trusting the model")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: small dims, and fail if the largest dim "
                         "selects naive or any correctness check fails")
    ap.add_argument("--oot-budget-mb", type=float, default=0.0,
                    help="device-memory budget enabling the strassen_oot "
                         "out-of-core candidate family (0 = off); its "
                         "predicted t_h2d term lands in predicted_terms")
    ap.add_argument("--out", default="autotune_sweep.json")
    args = ap.parse_args()
    calibration = None
    oot_budget = int(args.oot_budget_mb * 2**20) or None
    if args.smoke:
        sizes, min_dim = SMOKE_SIZES, SMOKE_MIN_DIM
        calibration = smoke_calibration()
        # Budget the oot family into the smoke table too, so the t_h2d
        # column is exercised on every CI run. 8 MiB: large enough that
        # the dense working set fits at every smoke size (3*512^2*4 =
        # 3 MiB), so oot rows appear as *candidates* without the
        # infeasibility filter hijacking the mesh-crossover story the
        # naive-regression gate asserts.
        oot_budget = oot_budget or (8 << 20)
    else:
        sizes = tuple(int(s) for s in args.sizes.split(","))
        min_dim = args.min_dim
    payload = sweep(
        sizes, min_dim=min_dim, max_depth=args.max_depth,
        measure=args.measure, out_path=args.out, calibration=calibration,
        oot_budget=oot_budget,
    )
    for row in payload["rows"]:
        print(f"# n={row['n']:6d} -> {row['selected']:24s} "
              f"pred {row['predicted_selected_s']:.4f}s "
              f"meas {row['measured_selected_s']:.4f}s "
              f"naive {row['measured_naive_s']:.4f}s ok={row['ok']}")
    if args.smoke:
        top = payload["rows"][-1]
        if not all(r["ok"] for r in payload["rows"]):
            print("# SMOKE FAIL: a selected path failed its correctness check")
            sys.exit(1)
        if top["selected"].startswith("naive"):
            print(f"# SMOKE FAIL: n={top['n']} regressed to naive; "
                  f"predicted table: {top['predicted_s']}")
            sys.exit(1)
        print(f"# smoke ok: n={top['n']} -> {top['selected']}")


if __name__ == "__main__":
    main()

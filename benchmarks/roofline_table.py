"""Roofline table: aggregates experiments/dryrun/*.json into §Roofline rows.

Not a timing benchmark — emits one row per dry-run cell with the three
roofline terms, dominant bottleneck, and useful-FLOP fraction.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")


def load_cells(pattern: str = "*.json"):
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, pattern))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def run():
    rows = []
    for cell in load_cells():
        if cell.get("workload") == "paper_matmul":
            name = f"roofline/matmul_n{cell['n']}/{cell['strategy']}/{cell['mesh']}"
        else:
            tag = f":{cell['tag']}" if cell.get("tag") else ""
            name = f"roofline/{cell.get('arch','?')}{tag}/{cell.get('shape','?')}/{cell.get('mesh','?')}"
        if cell.get("skipped"):
            rows.append(emit(name, 0.0, "skipped"))
            continue
        r = cell["roofline"]
        uf = cell.get("useful_fraction")
        rows.append(
            emit(
                name,
                r["bound_s"],  # seconds of the binding term
                f"bottleneck={r['bottleneck']};compute={r['compute_s']:.2e};"
                f"memory={r['memory_s']:.2e};collective={r['collective_s']:.2e};"
                f"useful={uf:.3f}" if uf is not None else f"bottleneck={r['bottleneck']}",
            )
        )
    if not rows:
        rows.append(emit("roofline/none", 0.0, "run repro.launch.dryrun first"))
    return rows

"""SPIN block-recursive inversion at scale: wall clock vs matrix size.

The recursive-plan layer (PR 10) generalizes the tagged out-of-core
runtime beyond multiplication; this benchmark drives its headline new
operator — SPIN-style block-recursive inversion — across sizes under a
*capped device-memory budget*. Every dense leaf inverse runs on device
and every recursive multiply whose working set exceeds the budget
re-enters the tagged Strassen scheduler, so a size "fits on device" only
if its dense-inverse working set (operand + result) does, and the table
deliberately includes sizes that do not.

Full run (hours at the large sizes on CPU hosts):

    PYTHONPATH=src python benchmarks/spin_scaling.py \
        [--sizes 1024,2048,4096] [--budget-mb 16] [--store memmap]

Every size is steady-state: one full untimed warmup run pays the leaf
jit compiles and the autotuner's calibration micro-benchmarks before the
timed run starts. Rows carry parity against the dense device
``jnp.linalg.inv`` up to ``--parity-max``.

CI smoke mode — f32, an artificially small budget that forces the
nested multiplies through multiple staging waves, a 1e-5 parity gate,
and the budget/pipeline gates:

    PYTHONPATH=src python benchmarks/spin_scaling.py --smoke

``--smoke`` EXITS NON-ZERO if any size drifts beyond the tolerance from
the dense inverse, if the sweep never needed a nested out-of-core
multiply, if the nested multiplies never ran >= 2 staging waves, if no
size exceeded the device budget, or if ``peak_device_bytes`` exceeds
the budget. ``--fault-rate`` adds a seeded chaos run per size gated
bit-identical against the fault-free run with zero unrecovered faults.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)  # `benchmarks` package when run as a script

import argparse
import json
import time


def _spd(rng, n, np_dtype):
    """Well-conditioned SPD input: every leading principal block
    invertible, which the SPIN recursion requires."""
    import numpy as np

    g = rng.standard_normal((n, n)).astype(np.float32)
    return (g @ g.T / n + np.eye(n, dtype=np.float32) * 2.0).astype(np_dtype)


def _dense_inverse_seconds(a, repeats: int = 2):
    import jax
    import jax.numpy as jnp

    fn = jax.jit(jnp.linalg.inv)
    da = jnp.asarray(a)
    out = jax.block_until_ready(fn(da))  # warmup/compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(da))
        best = min(best, time.perf_counter() - t0)
    return out, best


def sweep(
    sizes=(1024, 2048),
    *,
    budget_bytes=16 << 20,
    dtype="float32",
    store="dict",
    depth=None,
    parity_max=4096,
    fault_rate=0.0,
    chaos_seed=0,
    out_path="spin_scaling.json",
):
    """Run the inversion wall-clock-vs-size table; returns the payload.

    ``depth=None`` lets each size pick the shallowest solver depth whose
    dense leaf inverse fits the budget. ``fault_rate`` > 0 adds an
    (untimed) chaos run per size: the nested out-of-core multiplies see
    seeded block drops/corruption/leaf failures while lineage recovery
    heals them; the row's ``chaos`` record carries the counters and a
    ``bit_exact`` flag against the fault-free timed run.
    """
    import numpy as np

    from benchmarks.common import emit
    from repro.blocks.recovery import ChaosConfig
    from repro.blocks.solve import spin_inverse_oot

    if dtype == "bfloat16":
        import ml_dtypes

        np_dtype = np.dtype(ml_dtypes.bfloat16)
        tol = 1e-2
    else:
        np_dtype = np.dtype(dtype)
        tol = 1e-5

    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        a = _spd(rng, n, np_dtype)
        item = np.result_type(np_dtype, np.float32).itemsize
        # "Fits on device" the way the dense inverse needs it: operand
        # plus result resident at once.
        fits = 2 * n * n * item <= budget_bytes
        kwargs = dict(
            depth=depth, budget_bytes=budget_bytes, store=store,
        )
        # Untimed warmup: leaf jit compiles and calibration land here.
        spin_inverse_oot(a, **kwargs)
        out, stats = spin_inverse_oot(a, **kwargs)
        row = {
            "n": n,
            "dtype": np_dtype.name,
            "depth": stats.depth,
            "oot_runs": stats.oot_runs,
            "leaves": stats.leaves,
            "waves": stats.waves,
            "fits_on_device": fits,
            "budget_bytes": budget_bytes,
            "peak_device_bytes": stats.peak_device_bytes,
            "operand_bytes": a.nbytes,
            "inv_s": stats.total_s,
            "leaf_s": stats.leaf_s,
            "h2d_bytes": stats.h2d_bytes,
            "d2h_bytes": stats.d2h_bytes,
            "overlap_efficiency": stats.overlap_efficiency,
            "dense_s": None,
            "rel_err": None,
            "ok": None,
            "chaos": None,
        }
        if fault_rate > 0:
            chaos = ChaosConfig(
                drop=fault_rate,
                corrupt=fault_rate * 0.4,
                leaf_fail_rate=fault_rate * 0.5,
                seed=chaos_seed,
            )
            out_chaos, stats_chaos = spin_inverse_oot(a, chaos=chaos, **kwargs)
            row["chaos"] = {
                "drop": chaos.drop,
                "corrupt": chaos.corrupt,
                "leaf_fail_rate": chaos.leaf_fail_rate,
                "seed": chaos.seed,
                "injected_faults": stats_chaos.injected_faults,
                "lost_blocks": stats_chaos.lost_blocks,
                "corrupt_blocks": stats_chaos.corrupt_blocks,
                "recovered_blocks": stats_chaos.recovered_blocks,
                "leaf_retries": stats_chaos.leaf_retries,
                "unrecovered_faults": stats_chaos.unrecovered_faults,
                "rung": stats_chaos.rung,
                "degrades": stats_chaos.degrades,
                "peak_device_bytes": stats_chaos.peak_device_bytes,
                "bit_exact": bool(
                    np.array_equal(
                        np.asarray(out, np.float32),
                        np.asarray(out_chaos, np.float32),
                    )
                ),
            }
        if n <= parity_max:
            want, dense_s = _dense_inverse_seconds(a)
            want = np.asarray(want).astype(np.float32)
            scale = float(np.abs(want).max()) or 1.0
            err = float(np.abs(out.astype(np.float32) - want).max() / scale)
            row["dense_s"] = dense_s
            row["rel_err"] = err
            row["ok"] = err < tol
        rows.append(row)
        emit(
            f"spin/{np_dtype.name}/n{n}", stats.total_s,
            f"depth={stats.depth};muls={stats.oot_runs};waves={stats.waves};"
            f"fits={fits};"
            f"err={row['rel_err'] if row['rel_err'] is not None else 'n/a'}",
        )

    payload = {
        "budget_bytes": budget_bytes,
        "dtype": np_dtype.name,
        "store": store,
        "tolerance": tol,
        "fault_rate": fault_rate,
        "chaos_seed": chaos_seed,
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {out_path}", flush=True)
    return payload


def run():
    """benchmarks.run entry point: a small f32 table with parity checks."""
    sweep(sizes=(256, 384), budget_bytes=128 << 10, out_path="spin_scaling.json")


# Smoke-mode constants: f32 sizes small enough for a CI runner; the
# budget (i) is smaller than the 256^2 f32 dense-inverse working set
# (2 * 262144 B) — so the largest size cannot invert on device — and
# (ii) forces the nested multiplies through multi-wave staging.
SMOKE_SIZES = (192, 256)
SMOKE_BUDGET = 96 << 10


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="1024,2048,4096")
    ap.add_argument("--budget-mb", type=float, default=16.0)
    ap.add_argument("--dtype", choices=["float32", "bfloat16"], default="float32")
    ap.add_argument("--store", choices=["dict", "arena", "memmap"], default="dict")
    ap.add_argument("--depth", type=int, default=0,
                    help="0 = shallowest depth whose leaf fits the budget")
    ap.add_argument("--parity-max", type=int, default=4096,
                    help="largest n to verify against the dense inverse")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny f32 sizes under a budget that "
                         "forces out-of-core multiplies; non-zero exit on "
                         "parity drift > 1e-5 or a degenerate plan")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="chaos mode: per-get drop probability in the "
                         "nested multiplies (corruption and leaf-failure "
                         "rates derive from it); adds a recovery run per "
                         "size gated bit-exact against the fault-free run")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--out", default="spin_scaling.json")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the sweep here")
    args = ap.parse_args()

    if args.trace_out:
        from repro import obs

        obs.configure(enabled=True)

    if args.smoke:
        payload = sweep(
            SMOKE_SIZES, budget_bytes=SMOKE_BUDGET, dtype=args.dtype,
            store=args.store, parity_max=max(SMOKE_SIZES),
            fault_rate=args.fault_rate, chaos_seed=args.chaos_seed,
            out_path=args.out,
        )
    else:
        payload = sweep(
            tuple(int(s) for s in args.sizes.split(",")),
            budget_bytes=int(args.budget_mb * 2**20), dtype=args.dtype,
            store=args.store, depth=args.depth or None,
            parity_max=args.parity_max,
            fault_rate=args.fault_rate, chaos_seed=args.chaos_seed,
            out_path=args.out,
        )

    print(f"# {'n':>7} {'depth':>5} {'muls':>5} {'waves':>5} {'fits':>5} "
          f"{'inv_s':>9} {'dense_s':>9} {'rel_err':>9}")
    for r in payload["rows"]:
        dense = f"{r['dense_s']:.4f}" if r["dense_s"] is not None else "-"
        err = f"{r['rel_err']:.2e}" if r["rel_err"] is not None else "-"
        print(f"# {r['n']:>7} {r['depth']:>5} {r['oot_runs']:>5} "
              f"{r['waves']:>5} {str(r['fits_on_device']):>5} "
              f"{r['inv_s']:>9.4f} {dense:>9} {err:>9}")

    if args.trace_out:
        # Written before the smoke gates so a failing run still uploads
        # its trace as a CI artifact.
        from repro import obs
        from repro.obs import export

        export.write_trace(args.trace_out, metrics=obs.get_metrics())
        print(f"# wrote {args.trace_out} "
              f"({len(obs.get_tracer().spans)} spans)", flush=True)

    if args.smoke:
        bad = [r for r in payload["rows"] if r["ok"] is False]
        if bad:
            print(f"# SMOKE FAIL: parity drift beyond {payload['tolerance']}: "
                  f"{[(r['n'], r['rel_err']) for r in bad]}")
            sys.exit(1)
        if not any(r["oot_runs"] > 0 for r in payload["rows"]):
            print("# SMOKE FAIL: no nested multiply re-entered the "
                  "out-of-core scheduler")
            sys.exit(1)
        if all(r["waves"] < 2 for r in payload["rows"]):
            print("# SMOKE FAIL: nested multiplies never ran >= 2 "
                  "staging waves")
            sys.exit(1)
        if not any(not r["fits_on_device"] for r in payload["rows"]):
            print("# SMOKE FAIL: no size exceeded the device budget")
            sys.exit(1)
        over = [
            r for r in payload["rows"]
            if r["peak_device_bytes"] > r["budget_bytes"]
        ]
        if over:
            print(f"# SMOKE FAIL: peak device bytes exceeded the budget: "
                  f"{[(r['n'], r['peak_device_bytes']) for r in over]}")
            sys.exit(1)
        top = payload["rows"][-1]
        print(f"# smoke ok: n={top['n']} inverted via {top['oot_runs']} "
              f"nested out-of-core multiplies ({top['waves']} waves) under "
              f"a {payload['budget_bytes']} B budget "
              f"(dense working set {2 * top['operand_bytes']} B)")

    if args.fault_rate > 0:
        # Chaos gates (independent of --smoke): every chaos run must heal
        # to a bit-identical result with zero unrecovered faults, under
        # budget, and the harness must actually have exercised recovery.
        chaos_rows = [r for r in payload["rows"] if r["chaos"] is not None]
        inexact = [r["n"] for r in chaos_rows if not r["chaos"]["bit_exact"]]
        if inexact:
            print(f"# CHAOS FAIL: recovered result not bit-identical: {inexact}")
            sys.exit(1)
        unrec = [
            (r["n"], r["chaos"]["unrecovered_faults"])
            for r in chaos_rows if r["chaos"]["unrecovered_faults"]
        ]
        if unrec:
            print(f"# CHAOS FAIL: unrecovered faults: {unrec}")
            sys.exit(1)
        recovered = sum(r["chaos"]["recovered_blocks"] for r in chaos_rows)
        retries = sum(r["chaos"]["leaf_retries"] for r in chaos_rows)
        if not recovered or not retries:
            print(f"# CHAOS FAIL: harness under-exercised "
                  f"(recovered={recovered}, retries={retries})")
            sys.exit(1)
        over = [
            r["n"] for r in chaos_rows
            if r["chaos"]["peak_device_bytes"] > r["budget_bytes"]
        ]
        if over:
            print(f"# CHAOS FAIL: chaos run exceeded the device budget: {over}")
            sys.exit(1)
        injected = sum(r["chaos"]["injected_faults"] for r in chaos_rows)
        print(f"# chaos ok: {injected} faults injected across "
              f"{len(chaos_rows)} sizes; {recovered} blocks recomputed from "
              f"lineage, {retries} leaf retries, 0 unrecovered, all results "
              f"bit-identical to the fault-free runs")


if __name__ == "__main__":
    main()

"""Paper Fig. 11 / Tables VIII-X: stage-wise time breakdown.

Times Stark's three sections separately — divide levels, leaf batched
multiply, combine levels — by jitting each phase as its own program
(the Spark analogue of per-stage wall-clock from the event log). Confirms
the paper's finding: leaf multiplication dominates at small b; the
divide/combine share grows with depth.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, rand, time_fn
from repro.core.coefficients import STRASSEN
from repro.core.strassen import combine_level, divide_level

SIZES = (1024,)
DEPTHS = (1, 2, 3)


def _divide_phase(a, b, depth):
    ac = jnp.asarray(STRASSEN.a_coef)
    bc = jnp.asarray(STRASSEN.b_coef)
    ta, tb = a[None], b[None]
    for _ in range(depth):
        ta = divide_level(ta, ac)
        tb = divide_level(tb, bc)
    return ta, tb


def _leaf_phase(ta, tb):
    return jnp.einsum("mij,mjk->mik", ta, tb)


def _combine_phase(prod, depth):
    cc = jnp.asarray(STRASSEN.c_coef)
    for _ in range(depth):
        prod = combine_level(prod, cc)
    return prod[0]


def run():
    rows = []
    for n in SIZES:
        a, b = rand((n, n)), rand((n, n))
        for depth in DEPTHS:
            div = jax.jit(functools.partial(_divide_phase, depth=depth))
            t_div = time_fn(div, a, b)
            ta, tb = jax.block_until_ready(div(a, b))
            leaf = jax.jit(_leaf_phase)
            t_leaf = time_fn(leaf, ta, tb)
            prod = jax.block_until_ready(leaf(ta, tb))
            comb = jax.jit(functools.partial(_combine_phase, depth=depth))
            t_comb = time_fn(comb, prod)
            total = t_div + t_leaf + t_comb
            rows.append(
                emit(
                    f"fig11/stark/n{n}/b{2**depth}", total,
                    f"divide={t_div/total:.0%};leaf={t_leaf/total:.0%};combine={t_comb/total:.0%}",
                )
            )
    return rows

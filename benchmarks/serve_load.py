"""Open-loop serving load generator: continuous vs static batching.

Submits a Poisson arrival stream of mixed-length requests to the
request-based serving engine and reports tokens/sec plus per-token
latency percentiles (TTFT and inter-token gap p50/p99) for

* ``continuous`` — the engine's native scheduler: requests admitted and
  evicted mid-decode, paged KV pool shared across slots;
* ``static`` — gang-scheduled baseline (``ServeConfig(batching=
  "static")``): a batch is admitted only into an idle engine and holds
  its slots until every member finishes. Same kernels, same bucket
  width — the comparison isolates the scheduling policy.

``--smoke`` runs a small fixed workload and **gates**: the generate()
compat shim must be token-exact with the retained pre-redesign static
loop, and continuous batching must reach at least the static gang's
tokens/sec. Non-zero exit on any failure (wired into CI bench-smoke).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    # 8 host-platform devices, forced before any jax import.
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)  # `benchmarks` package when run as a script

import argparse
import json
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import emit


def _build(model: str, serve_kwargs: dict):
    import jax

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import Engine, ServeConfig

    cfg = get_smoke_config(model)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, Engine(cfg, params, ServeConfig(**serve_kwargs))


def _workload(n_requests: int, vocab: int, seed: int):
    """Mixed-length requests with Poisson (exponential inter-arrival)
    timestamps. Prompt lengths are quantized to two buckets so prefill
    retraces stay bounded on CPU."""
    rng = np.random.default_rng(seed)
    # rate high enough that the engine saturates (otherwise the makespan
    # just tracks the arrival process and both schedulers tie)
    arrivals = np.cumsum(rng.exponential(1.0 / 200.0, size=n_requests))
    reqs = []
    for i in range(n_requests):
        s = int(rng.choice([4, 8]))
        # long-tailed output lengths: this is what separates the
        # schedulers — a gang holds its slots until the LONGEST member
        # finishes, continuous backfills freed slots immediately
        max_new = int(rng.choice([4, 4, 4, 32]))
        reqs.append(
            {
                "at": float(arrivals[i]),
                "prompt": rng.integers(0, vocab, size=s),
                "max_new": max_new,
            }
        )
    return reqs


def _drive(engine, reqs: List[dict], poison: Dict[int, int] = {}) -> Dict[str, float]:
    """Open loop: submit each request at its arrival timestamp (never
    waiting for the engine), step the scheduler in between.

    ``poison`` maps request index -> token count at which that request's
    decode dispatch raises an injected fault (the engine's per-request
    isolation must evict it and keep the survivors intact)."""
    t0 = time.perf_counter()
    handles = []
    i = 0
    while i < len(reqs) or not all(h.done for h in handles):
        now = time.perf_counter() - t0
        while i < len(reqs) and reqs[i]["at"] <= now:
            handles.append(
                engine.submit(
                    reqs[i]["prompt"], reqs[i]["max_new"],
                    _inject_fault_at=poison.get(i),
                )
            )
            i += 1
        if handles and not all(h.done for h in handles):
            engine.step()
        elif i < len(reqs):
            time.sleep(min(0.001, reqs[i]["at"] - now))
    makespan = time.perf_counter() - t0

    total_tokens = sum(len(h.tokens()) for h in handles)
    finished = [h for h in handles if h.finish_reason in ("eos", "length")]
    evicted = [h for h in handles if h.state.value == "evicted"]
    goodput_tokens = sum(len(h.tokens()) for h in finished)
    clean_unfinished = sum(
        1
        for idx, h in enumerate(handles)
        if idx not in poison and h.finish_reason not in ("eos", "length")
    )
    ttfts, tpots = [], []
    for h in handles:
        ttft, gaps = h.latency_stats()
        if ttft is not None:
            ttfts.append(ttft)
        # tokens surface at sync boundaries, so raw inter-token gaps are
        # bursty (0 within a drain); the per-request MEAN gap — first to
        # last token span over n-1 tokens — is the steady-state TPOT.
        if gaps:
            tpots.append(float(np.mean(gaps)))
    pct = lambda xs, q: float(np.percentile(xs, q)) if xs else 0.0  # noqa: E731
    return {
        "requests": len(handles),
        "total_tokens": total_tokens,
        "makespan_s": makespan,
        "tokens_per_s": total_tokens / makespan if makespan else 0.0,
        # goodput counts only completed (eos/length) requests' tokens —
        # work delivered to callers, not work evicted mid-flight
        "finished_requests": len(finished),
        "evicted_requests": len(evicted),
        "goodput_tokens_per_s": goodput_tokens / makespan if makespan else 0.0,
        "clean_unfinished": clean_unfinished,
        "ttft_p50_s": pct(ttfts, 50),
        "ttft_p99_s": pct(ttfts, 99),
        "tpot_p50_s": pct(tpots, 50),
        "tpot_p99_s": pct(tpots, 99),
    }


def _parity_check(cfg, params) -> bool:
    """Old-vs-new greedy parity: the generate() shim on the request loop
    must reproduce the pre-redesign static loop token-for-token."""
    import jax

    from repro.serving.engine import Engine, ServeConfig

    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=0.0))
    t_old, _ = eng._generate_static(prompts, 8)
    t_new, _ = eng.generate(prompts, 8)
    return bool(np.array_equal(np.asarray(t_old), np.asarray(t_new)))


def run(
    model: str = "phi4_mini_3_8b",
    n_requests: int = 16,
    slots: int = 3,
    seed: int = 0,
    smoke: bool = False,
    fault_rate: float = 0.0,
    chaos_seed: int = 0,
    out: str = "",
    trace_out: str = "",
) -> int:
    if trace_out:
        from repro import obs

        obs.configure(enabled=True)
    # Fault mode: poison a deterministic subset of the continuous run's
    # requests (injected decode failure after 2 tokens). rate * n rounds
    # to ~0 at smoke scale, so at least one request is always poisoned.
    poison: Dict[int, int] = {}
    if fault_rate > 0:
        rng = np.random.default_rng(chaos_seed)
        n_poison = max(1, round(fault_rate * n_requests))
        chosen = rng.choice(n_requests, size=n_poison, replace=False)
        poison = {int(i): 2 for i in chosen}
    # decode_pages pinned: both modes run the same fixed decode bucket,
    # so per-step cost is identical and the measured difference is purely
    # the scheduling policy (packing, not kernel shape).
    serve_base = dict(
        max_seq=64, temperature=0.0, slots=slots, page_size=8, sync_interval=2,
        decode_pages=8,
    )
    results: Dict[str, dict] = {}
    cfg = params = None
    for mode in ("static", "continuous"):
        cfg, params, engine = _build(model, dict(serve_base, batching=mode))
        reqs = _workload(n_requests, cfg.vocab, seed)
        _drive(engine, reqs)  # warmup: absorb jit traces for this engine
        engine.metrics.reset()  # drop the warmup's TTFT/TPOT samples
        # faults are injected into the continuous engine's timed run only
        # (the static gang is the clean baseline; the warmup stays clean
        # so fault counters reflect the measured run alone)
        stats = _drive(engine, reqs, poison if mode == "continuous" else {})
        stats["serve"] = engine.serve_stats()
        # The same latencies, read back from the engine's obs histograms —
        # the smoke gate below holds them to the per-request values.
        for name, key in (("serve.ttft_s", "ttft"), ("serve.tpot_s", "tpot")):
            hist = engine.metrics.histogram(name)
            for q in (50, 99):
                p = hist.percentile(q)
                stats[f"obs_{key}_p{q}_s"] = 0.0 if p is None else p
        stats["obs"] = engine.stats()["obs"]["metrics"]
        results[mode] = stats
        emit(
            f"serve_load/{mode}",
            stats["makespan_s"],
            f"tok_per_s={stats['tokens_per_s']:.1f};"
            f"goodput={stats['goodput_tokens_per_s']:.1f};"
            f"evicted={stats['evicted_requests']};"
            f"tpot_p50={stats['tpot_p50_s'] * 1e3:.1f}ms;"
            f"tpot_p99={stats['tpot_p99_s'] * 1e3:.1f}ms",
        )

    parity_ok = _parity_check(cfg, params)
    cont, stat = results["continuous"], results["static"]
    speedup = (
        cont["tokens_per_s"] / stat["tokens_per_s"] if stat["tokens_per_s"] else 0.0
    )
    emit(
        "serve_load/speedup",
        0.0,
        f"continuous_vs_static={speedup:.2f}x;parity={'ok' if parity_ok else 'FAIL'}",
    )

    report = {
        "model": model,
        "n_requests": n_requests,
        "serve": serve_base,
        "fault_rate": fault_rate,
        "chaos_seed": chaos_seed,
        "poisoned_requests": sorted(poison),
        "modes": results,
        "continuous_vs_static": speedup,
        "generate_shim_parity": parity_ok,
    }
    if out:
        with open(out, "w") as f:
            json.dump(report, f, indent=1)
    if trace_out:
        from repro.obs import export

        export.write_trace(trace_out, metrics=engine.metrics)
        print(f"wrote {trace_out}")

    if smoke:
        failures = []
        if not parity_ok:
            failures.append("generate() shim diverged from the legacy static loop")
        if not poison and cont["tokens_per_s"] < stat["tokens_per_s"]:
            # fault mode evicts continuous-run requests mid-decode, so the
            # raw-throughput comparison against the clean static gang is
            # meaningless there — the fault gates below replace it
            failures.append(
                f"continuous {cont['tokens_per_s']:.1f} tok/s < "
                f"static {stat['tokens_per_s']:.1f} tok/s"
            )
        if poison:
            counters = cont["obs"].get("counters", {})
            if cont["evicted_requests"] != len(poison):
                failures.append(
                    f"poisoned {len(poison)} requests but "
                    f"{cont['evicted_requests']} were evicted"
                )
            if cont["clean_unfinished"]:
                failures.append(
                    f"{cont['clean_unfinished']} non-poisoned requests "
                    "failed to finish — fault isolation leaked"
                )
            if not cont["goodput_tokens_per_s"] > 0:
                failures.append("zero goodput under fault injection")
            if not counters.get("fault.injected_faults"):
                failures.append("fault.injected_faults counter never fired")
            if not counters.get("fault.evicted_requests"):
                failures.append("fault.evicted_requests counter never fired")
        for mode, st in results.items():
            if not (st["tpot_p50_s"] > 0 and st["tpot_p99_s"] >= st["tpot_p50_s"]):
                failures.append(f"{mode}: degenerate latency percentiles")
            # obs histograms must agree with the per-request latency_stats()
            # derivation — same samples, same (numpy-linear) interpolation.
            for key in ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s"):
                want, got = st[key], st[f"obs_{key}"]
                if abs(got - want) > 1e-9 + 1e-6 * abs(want):
                    failures.append(
                        f"{mode}: obs histogram {key} {got:.6f}s disagrees "
                        f"with latency_stats {want:.6f}s"
                    )
        if failures:
            for f_ in failures:
                print(f"SMOKE FAIL: {f_}")
            return 1
        print("SMOKE OK")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="phi4_mini_3_8b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true", help="small run + gates")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="poison ~rate*requests of the continuous run with "
                    "injected decode faults; reports goodput + evictions "
                    "and gates per-request isolation under --smoke")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--out", default="", help="write full JSON report here")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome/Perfetto trace of the load run here")
    args = ap.parse_args()
    raise SystemExit(
        run(
            model=args.model,
            n_requests=args.requests,
            slots=args.slots,
            seed=args.seed,
            smoke=args.smoke,
            fault_rate=args.fault_rate,
            chaos_seed=args.chaos_seed,
            out=args.out,
            trace_out=args.trace_out,
        )
    )


if __name__ == "__main__":
    main()

"""Paper Fig. 8 at scale: out-of-core wall clock vs matrix size.

The paper's headline experiment multiplies matrices (up to 16384^2) that
no single executor could hold; Stark's tagged-block RDD streams them
through the cluster. This benchmark reproduces that curve on one host:
operands live in a host block store and :mod:`repro.blocks.scheduler`
stages the 7^q leaf waves through a *capped device-memory budget* — so a
size "fits on device" only if 3n^2 operand/product bytes do, and the
table deliberately includes sizes that do not.

Full run (paper-scale; hours on CPU hosts, real-TPU recommended):

    PYTHONPATH=src python benchmarks/fig8_scaling.py \
        [--sizes 2048,4096,8192,16384] [--budget-mb 64] [--store memmap]

Every (size, strategy) sample is steady-state: one full untimed warmup
run per size pays the leaf jit compile and the autotune
``get_calibration()`` micro-benchmarks before the timed run starts.

CI smoke mode — bf16, an artificially small budget that forces >= 2
staging waves, a parity gate, and the async-pipeline gates:

    PYTHONPATH=src python benchmarks/fig8_scaling.py --smoke

``--smoke`` also times the synchronous (``prefetch=False``) loop per
size and reports ``overlap_speedup``. It EXITS NON-ZERO if the pipelined
and synchronous results are not bit-identical, if any size's
out-of-core result drifts more than 1e-2 from the dense bf16 matmul, if
the staging plan degenerates to a single wave (the budget failed to
force out-of-core behavior), if no size exceeds the device budget, if
any multi-wave pipelined run fails to report ``overlap_efficiency > 0``
(with per-wave timestamps), or if ``peak_device_bytes`` exceeds the
budget.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__":
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, os.path.join(_root, "src"))
    sys.path.insert(0, _root)  # `benchmarks` package when run as a script

import argparse
import json
import time


def _dense_seconds(a, b, repeats: int = 2):
    import jax
    import jax.numpy as jnp

    fn = jax.jit(lambda x, y: jnp.matmul(x, y))
    da, db = jnp.asarray(a), jnp.asarray(b)
    out = jax.block_until_ready(fn(da, db))  # warmup/compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(da, db))
        best = min(best, time.perf_counter() - t0)
    return out, best


def sweep(
    sizes=(2048, 4096),
    *,
    budget_bytes=64 << 20,
    dtype="float32",
    store="dict",
    depth=0,
    parity_max=4096,
    compare_sync=False,
    fault_rate=0.0,
    chaos_seed=0,
    out_path="fig8_scaling.json",
):
    """Run the wall-clock-vs-size table; returns the JSON payload.

    Each size pays one full untimed warmup run first — leaf jit compile
    and the autotuner's ``get_calibration()`` micro-benchmarks land
    there, never in the reported sample. ``compare_sync`` additionally
    times the synchronous (``prefetch=False``) loop per size so the row
    carries ``sync_s`` and ``overlap_speedup``.

    ``fault_rate`` > 0 adds an (untimed) chaos run per size: blocks are
    dropped/corrupted and leaf multiplies fail at seeded rates while
    lineage recovery heals the store. The row's ``chaos`` record carries
    the injection/recovery counters and a ``bit_exact`` flag comparing
    the chaos run's result against the fault-free timed run — recovery
    replays the exact computation path, so anything short of
    bit-identical is a failure.
    """
    import numpy as np

    from benchmarks.common import emit
    from repro.blocks.recovery import ChaosConfig
    from repro.blocks.scheduler import min_depth_for_budget, strassen_oot_matmul
    from repro.core.backend import MatmulBackend

    if dtype == "bfloat16":
        import ml_dtypes

        np_dtype = np.dtype(ml_dtypes.bfloat16)
        tol = 1e-2
    else:
        np_dtype = np.dtype(dtype)
        tol = 2e-3

    backend = MatmulBackend(kind="auto", depth=2)
    rng = np.random.default_rng(0)
    rows = []
    for n in sizes:
        a = rng.standard_normal((n, n)).astype(np_dtype)
        b = rng.standard_normal((n, n)).astype(np_dtype)
        # "Fits on device" the way a dense multiply would need it:
        # both operands plus the product resident at once.
        fits = 3 * a.nbytes <= budget_bytes
        # pipelined=True: pick the depth whose pipelined wave slot (two
        # leaf working sets + one wave of operand prefetch) fits, so the
        # async pipeline stays enabled instead of degrading to sync.
        d = depth or min_depth_for_budget(
            n, n, n, budget_bytes, np_dtype, pipelined=True
        )
        kwargs = dict(depth=d, budget_bytes=budget_bytes, backend=backend, store=store)
        # Untimed warmup: first call compiles the leaf matmul and runs the
        # calibration micro-benchmarks; the same leaf shape serves the
        # timed pipelined AND synchronous runs below.
        strassen_oot_matmul(a, b, **kwargs)
        repeats = 2 if compare_sync else 1
        out, stats = min(
            (strassen_oot_matmul(a, b, **kwargs) for _ in range(repeats)),
            key=lambda r: r[1].total_s,
        )
        row = {
            "n": n,
            "dtype": np_dtype.name,
            "depth": d,
            "leaves": stats.leaves,
            "waves": stats.waves,
            "wave_size": stats.wave_size,
            "prefetch": stats.prefetch,
            "fits_on_device": fits,
            "budget_bytes": budget_bytes,
            "peak_device_bytes": stats.peak_device_bytes,
            "operand_bytes": a.nbytes,
            "oot_s": stats.total_s,
            "divide_s": stats.divide_s,
            "leaf_s": stats.leaf_s,
            "combine_s": stats.combine_s,
            "stage_s": stats.stage_s,
            "fetch_s": stats.fetch_s,
            "overlap_efficiency": stats.overlap_efficiency,
            "wave_events": stats.wave_events,
            "h2d_bytes": stats.h2d_bytes,
            "sync_s": None,
            "overlap_speedup": None,
            "dense_s": None,
            "rel_err": None,
            "ok": None,
            "chaos": None,
        }
        if fault_rate > 0:
            chaos = ChaosConfig(
                drop=fault_rate,
                corrupt=fault_rate * 0.4,
                leaf_fail_rate=fault_rate * 0.5,
                seed=chaos_seed,
            )
            out_chaos, stats_chaos = strassen_oot_matmul(a, b, chaos=chaos, **kwargs)
            row["chaos"] = {
                "drop": chaos.drop,
                "corrupt": chaos.corrupt,
                "leaf_fail_rate": chaos.leaf_fail_rate,
                "seed": chaos.seed,
                "injected_faults": stats_chaos.injected_faults,
                "lost_blocks": stats_chaos.lost_blocks,
                "corrupt_blocks": stats_chaos.corrupt_blocks,
                "recovered_blocks": stats_chaos.recovered_blocks,
                "leaf_retries": stats_chaos.leaf_retries,
                "unrecovered_faults": stats_chaos.unrecovered_faults,
                "rung": stats_chaos.rung,
                "degrades": stats_chaos.degrades,
                "peak_device_bytes": stats_chaos.peak_device_bytes,
                "bit_exact": bool(
                    np.array_equal(
                        np.asarray(out, np.float32),
                        np.asarray(out_chaos, np.float32),
                    )
                ),
            }
        if compare_sync:
            out_sync, stats_sync = min(
                (
                    strassen_oot_matmul(a, b, prefetch=False, **kwargs)
                    for _ in range(repeats)
                ),
                key=lambda r: r[1].total_s,
            )
            # Explicit gate (not a bare assert: those vanish under -O and
            # would silently drop the CI guarantee).
            if not np.array_equal(
                np.asarray(out, np.float32), np.asarray(out_sync, np.float32)
            ):
                print(f"# SMOKE FAIL: pipelined vs sync mismatch at n={n}")
                sys.exit(1)
            row["sync_s"] = stats_sync.total_s
            row["overlap_speedup"] = stats_sync.total_s / stats.total_s
        if n <= parity_max:
            want, dense_s = _dense_seconds(a, b)
            want = np.asarray(want).astype(np.float32)
            scale = float(np.abs(want).max()) or 1.0
            err = float(np.abs(out.astype(np.float32) - want).max() / scale)
            row["dense_s"] = dense_s
            row["rel_err"] = err
            row["ok"] = err < tol
        rows.append(row)
        emit(
            f"fig8s/{np_dtype.name}/n{n}", stats.total_s,
            f"depth={d};waves={stats.waves};fits={fits};"
            f"overlap={stats.overlap_efficiency:.2f};"
            f"err={row['rel_err'] if row['rel_err'] is not None else 'n/a'}",
        )

    payload = {
        "budget_bytes": budget_bytes,
        "dtype": np_dtype.name,
        "store": store,
        "tolerance": tol,
        "fault_rate": fault_rate,
        "chaos_seed": chaos_seed,
        "rows": rows,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {out_path}", flush=True)
    return payload


def run():
    """benchmarks.run entry point: a small f32 table with parity checks."""
    sweep(sizes=(256, 512), budget_bytes=1 << 20, out_path="fig8_scaling.json")


# Smoke-mode constants: bf16 sizes small enough for a CI runner; the
# budget (i) is smaller than one 256^2 bf16 operand (131072 B) — so the
# largest size cannot fit on device — and (ii) forces every size through
# >= 2 staging waves at the auto-chosen depth.
SMOKE_SIZES = (192, 256)
SMOKE_BUDGET = 96 << 10


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--sizes", default="2048,4096,8192,16384")
    ap.add_argument("--budget-mb", type=float, default=64.0)
    ap.add_argument("--dtype", choices=["float32", "bfloat16"], default="float32")
    ap.add_argument("--store", choices=["dict", "arena", "memmap"], default="dict")
    ap.add_argument("--depth", type=int, default=0,
                    help="0 = shallowest depth that fits the budget per size")
    ap.add_argument("--parity-max", type=int, default=4096,
                    help="largest n to verify against the dense matmul")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny bf16 sizes under a budget that "
                         "forces >= 2 staging waves; non-zero exit on "
                         "parity drift > 1e-2 or a degenerate plan")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="chaos mode: per-get drop probability (corruption "
                         "and leaf-failure rates derive from it); adds a "
                         "recovery run per size gated bit-exact against "
                         "the fault-free run")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--out", default="fig8_scaling.json")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the sweep here")
    args = ap.parse_args()

    if args.trace_out:
        from repro import obs

        obs.configure(enabled=True)

    if args.smoke:
        payload = sweep(
            SMOKE_SIZES, budget_bytes=SMOKE_BUDGET, dtype="bfloat16",
            store=args.store, parity_max=max(SMOKE_SIZES), compare_sync=True,
            fault_rate=args.fault_rate, chaos_seed=args.chaos_seed,
            out_path=args.out,
        )
    else:
        payload = sweep(
            tuple(int(s) for s in args.sizes.split(",")),
            budget_bytes=int(args.budget_mb * 2**20), dtype=args.dtype,
            store=args.store, depth=args.depth, parity_max=args.parity_max,
            fault_rate=args.fault_rate, chaos_seed=args.chaos_seed,
            out_path=args.out,
        )

    print(f"# {'n':>7} {'depth':>5} {'waves':>5} {'fits':>5} "
          f"{'oot_s':>9} {'sync_s':>9} {'overlap':>7} {'dense_s':>9} {'rel_err':>9}")
    for r in payload["rows"]:
        dense = f"{r['dense_s']:.4f}" if r["dense_s"] is not None else "-"
        err = f"{r['rel_err']:.2e}" if r["rel_err"] is not None else "-"
        sync = f"{r['sync_s']:.4f}" if r["sync_s"] is not None else "-"
        print(f"# {r['n']:>7} {r['depth']:>5} {r['waves']:>5} "
              f"{str(r['fits_on_device']):>5} {r['oot_s']:>9.4f} {sync:>9} "
              f"{r['overlap_efficiency']:>7.2f} {dense:>9} {err:>9}")

    if args.trace_out:
        # Written before the smoke gates so a failing run still uploads
        # its trace as a CI artifact.
        from repro import obs
        from repro.obs import export

        export.write_trace(args.trace_out, metrics=obs.get_metrics())
        print(f"# wrote {args.trace_out} "
              f"({len(obs.get_tracer().spans)} spans)", flush=True)

    if args.smoke:
        bad = [r for r in payload["rows"] if r["ok"] is False]
        if bad:
            print(f"# SMOKE FAIL: parity drift beyond {payload['tolerance']}: "
                  f"{[(r['n'], r['rel_err']) for r in bad]}")
            sys.exit(1)
        if any(r["waves"] < 2 for r in payload["rows"]):
            print("# SMOKE FAIL: budget failed to force >= 2 staging waves")
            sys.exit(1)
        if not any(not r["fits_on_device"] for r in payload["rows"]):
            print("# SMOKE FAIL: no size exceeded the device budget")
            sys.exit(1)
        # Async-pipeline gates: every multi-wave pipelined run must report
        # positive overlap with per-wave timestamps, and the modeled
        # pipelined peak must stay inside the budget.
        no_overlap = [
            r for r in payload["rows"]
            if r["prefetch"] and r["waves"] >= 2
            and not (r["overlap_efficiency"] > 0.0 and r["wave_events"])
        ]
        if no_overlap:
            print(f"# SMOKE FAIL: pipelined multi-wave rows without overlap "
                  f"telemetry: {[r['n'] for r in no_overlap]}")
            sys.exit(1)
        over = [
            r for r in payload["rows"]
            if r["peak_device_bytes"] > r["budget_bytes"]
        ]
        if over:
            print(f"# SMOKE FAIL: peak device bytes exceeded the budget: "
                  f"{[(r['n'], r['peak_device_bytes']) for r in over]}")
            sys.exit(1)
        if not any(r["prefetch"] for r in payload["rows"]):
            print("# SMOKE FAIL: no size ran the async pipeline")
            sys.exit(1)
        top = payload["rows"][-1]
        speedups = ", ".join(
            f"n={r['n']}: {r['overlap_speedup']:.2f}x"
            for r in payload["rows"] if r["overlap_speedup"] is not None
        )
        print(f"# smoke ok: n={top['n']} ran {top['waves']} waves under a "
              f"{payload['budget_bytes']} B budget (operand {top['operand_bytes']} B); "
              f"pipelined-vs-sync speedup [{speedups}]")

    if args.fault_rate > 0:
        # Chaos gates (independent of --smoke): every chaos run must heal
        # to a bit-identical result with zero unrecovered faults, under
        # budget, and the harness must actually have exercised recovery —
        # recompute AND retry counters > 0 across the sweep.
        chaos_rows = [r for r in payload["rows"] if r["chaos"] is not None]
        inexact = [r["n"] for r in chaos_rows if not r["chaos"]["bit_exact"]]
        if inexact:
            print(f"# CHAOS FAIL: recovered result not bit-identical: {inexact}")
            sys.exit(1)
        unrec = [
            (r["n"], r["chaos"]["unrecovered_faults"])
            for r in chaos_rows if r["chaos"]["unrecovered_faults"]
        ]
        if unrec:
            print(f"# CHAOS FAIL: unrecovered faults: {unrec}")
            sys.exit(1)
        recovered = sum(r["chaos"]["recovered_blocks"] for r in chaos_rows)
        retries = sum(r["chaos"]["leaf_retries"] for r in chaos_rows)
        if not recovered or not retries:
            print(f"# CHAOS FAIL: harness under-exercised "
                  f"(recovered={recovered}, retries={retries})")
            sys.exit(1)
        over = [
            r["n"] for r in chaos_rows
            if r["chaos"]["peak_device_bytes"] > r["budget_bytes"]
        ]
        if over:
            print(f"# CHAOS FAIL: chaos run exceeded the device budget: {over}")
            sys.exit(1)
        injected = sum(r["chaos"]["injected_faults"] for r in chaos_rows)
        print(f"# chaos ok: {injected} faults injected across "
              f"{len(chaos_rows)} sizes; {recovered} blocks recomputed from "
              f"lineage, {retries} leaf retries, 0 unrecovered, all results "
              f"bit-identical to the fault-free runs")


if __name__ == "__main__":
    main()

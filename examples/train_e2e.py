"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

The model is a scaled phi4-family decoder (~100M params with its 32k
vocab) on the synthetic Zipf+motif pipeline; loss decreases as the model
learns the motif structure. Checkpoints every 50 steps (atomic,
keep-last-3) and auto-resumes — kill it mid-run and rerun to see restart.

Full run (a few hundred steps, ~100M params — hours on 1 CPU core):
  PYTHONPATH=src python examples/train_e2e.py --steps 300
CI-scale run (~8M params, minutes):
  PYTHONPATH=src python examples/train_e2e.py --ci --steps 120
Strassen-backend run (the paper's technique in the training path):
  PYTHONPATH=src python examples/train_e2e.py --ci --backend strassen
Autotuned run — every projection resolves from the calibrated dispatcher,
and the summary JSON records the measured step-time delta vs the
hand-picked (naive) backend:
  PYTHONPATH=src python examples/train_e2e.py --ci --backend auto --out run.json
"""
import argparse
import dataclasses
import json

from repro.core.backend import MatmulBackend
from repro.launch.train import train_loop
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamWConfig

FULL_100M = ModelConfig(
    name="repro-100m", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
    d_ff=2048, vocab=32768, act="silu", glu=True,
    rope_theta=10000.0, tie_embeddings=True,
    dtype="float32", remat=False,
)

CI_8M = dataclasses.replace(
    FULL_100M, name="repro-8m", n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=704, vocab=4096,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ci", action="store_true", help="8M-param CI-scale config")
    ap.add_argument(
        "--backend", choices=["naive", "strassen", "winograd", "auto"], default="naive",
        help="'auto' sets ModelConfig(matmul_autotune=True): every dense "
        "projection resolves from the calibrated dispatcher",
    )
    ap.add_argument(
        "--compare-steps", type=int, default=20,
        help="with --backend auto: steps of the hand-picked baseline run "
        "used to measure the step-time delta (0 = skip)",
    )
    ap.add_argument("--ckpt-dir", default="/tmp/repro_e2e_ckpt")
    ap.add_argument("--out", default=None, help="write run summary JSON here")
    args = ap.parse_args()

    cfg = CI_8M if args.ci else FULL_100M
    handpicked_cfg = cfg  # config-default backend, the comparison baseline
    if args.backend == "auto":
        # The ROADMAP wiring: the flag (not a hand-built backend) drives
        # the rewrite, so the run exercises exactly what users toggle.
        cfg = dataclasses.replace(
            cfg,
            matmul_autotune=True,
            matmul_backend=MatmulBackend(kind="auto", depth=2, min_dim=256),
        )
    elif args.backend != "naive":
        cfg = dataclasses.replace(
            cfg, matmul_backend=MatmulBackend(kind=args.backend, depth=1, min_dim=256)
        )
    n_params = cfg.param_count()
    print(f"config {cfg.name}: ~{n_params/1e6:.1f}M params, backend={args.backend}")

    opt = AdamWConfig(
        lr=args.lr, warmup_steps=max(args.steps // 20, 10), total_steps=args.steps
    )
    run_stats = {}
    _, history = train_loop(
        cfg, opt,
        steps=args.steps, batch=args.batch, seq=args.seq,
        ckpt_dir=args.ckpt_dir, save_every=50, log_every=10,
        stats_out=run_stats,
    )
    print(f"loss: first={history[0]:.4f} min={min(history):.4f} last={history[-1]:.4f}")

    summary = {
        "config": cfg.name,
        "params": n_params,
        "backend": args.backend,
        "loss": history,
        "median_step_time_s": run_stats.get("median_step_time_s"),
    }
    if args.backend == "auto" and args.compare_steps > 0:
        from repro.core import autotune
        from repro.launch.train import autotune_step_delta

        summary.update(
            autotune_step_delta(
                handpicked_cfg, opt,
                auto_step_time=run_stats.get("median_step_time_s", 0.0),
                steps=args.compare_steps, batch=args.batch, seq=args.seq,
            )
        )
        summary["autotune_kinds"] = autotune.get_telemetry().kind_counts()
    if args.out:
        with open(args.out, "w") as f:
            json.dump(summary, f)
        print(f"wrote {args.out}")
    assert history[-1] < history[0], "loss must decrease"


if __name__ == "__main__":
    main()

"""Batched serving demo: prefill a prompt batch, decode with a KV cache.

Uses the smoke-size recurrentgemma config so the run also exercises the
ring-buffer local-attention cache and RG-LRU state. Swap --arch for any
of the 10 assigned architectures.

Run: PYTHONPATH=src python examples/serve.py [--arch phi4_mini_3_8b]
"""
import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.models.frontends import make_stub_frames
from repro.serving.engine import Engine, ServeConfig

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="recurrentgemma_9b", choices=list(ARCH_IDS))
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--new-tokens", type=int, default=32)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
engine = Engine(cfg, params, ServeConfig(max_seq=256, temperature=0.8))

prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0, cfg.vocab)
frames = make_stub_frames(cfg, args.batch) if cfg.frontend == "audio_stub" else None

t0 = time.perf_counter()
tokens, stats = engine.generate(prompts, args.new_tokens, frames=frames)
dt = time.perf_counter() - t0
n_gen = tokens.shape[0] * tokens.shape[1]
print(f"arch={cfg.name} generated {tokens.shape} tokens in {dt:.2f}s "
      f"({n_gen/dt:.1f} tok/s incl. compile)")
print("sample:", tokens[0, :16].tolist())
print("stats:", stats)

"""Continuous-batching serving demo: submit, stream, evict.

Submits a handful of mixed-length requests to the request-based engine,
streams tokens as they arrive (per-token callback + the stream()
iterator), cancels one request mid-decode, and prints the scheduler's
pool accounting at the end.

Uses the smoke-size recurrentgemma config so the run also exercises the
ring-buffer local-attention cache and RG-LRU state alongside the paged
full-attention pool of attention archs. Swap --arch for any of the 10
assigned architectures (whisper, the encoder-decoder arch, serves
through the legacy engine.generate path instead).

Run: PYTHONPATH=src python examples/serve.py [--arch phi4_mini_3_8b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models import model as M
from repro.serving.engine import Engine, ServeConfig

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="recurrentgemma_9b", choices=list(ARCH_IDS))
ap.add_argument("--requests", type=int, default=5)
ap.add_argument("--new-tokens", type=int, default=24)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
key = jax.random.PRNGKey(0)
params = M.init_params(cfg, key)
engine = Engine(
    cfg,
    params,
    ServeConfig(
        max_seq=256,
        temperature=0.8,
        slots=3,  # decode bucket width: requests resident at once
        page_size=16,  # paged KV pool granularity (full-attention layers)
        sync_interval=4,  # host fetches tokens every 4 decode steps
    ),
)

if cfg.is_encdec:
    # whisper: encoder-decoder serving stays on the legacy batched path
    from repro.models.frontends import make_stub_frames

    prompts = jax.random.randint(key, (4, 8), 0, cfg.vocab)
    tokens, stats = engine.generate(
        prompts, args.new_tokens, frames=make_stub_frames(cfg, 4)
    )
    print(f"arch={cfg.name} (encdec legacy path) generated {tokens.shape}")
    print("stats:", stats)
    raise SystemExit(0)

rng = np.random.default_rng(0)
t0 = time.perf_counter()


def on_token(handle, event):
    if event.index == 0:
        print(f"  [{time.perf_counter() - t0:6.2f}s] req {event.request_id}: "
              f"first token {event.token}")


# mixed prompt/output lengths: the scheduler packs the decode bucket and
# backfills slots as short requests finish
handles = [
    engine.submit(
        rng.integers(0, cfg.vocab, size=int(rng.integers(4, 17))),
        args.new_tokens + int(rng.integers(0, 16)),
        on_token=on_token,
    )
    for _ in range(args.requests)
]
victim = handles[-1]

n_events = 0
for ev in engine.stream(handles):
    n_events += 1
    if n_events == 10 and not victim.done:
        victim.cancel()  # mid-decode eviction: pages return to the pool
        print(f"  evicted req {victim.id} after {len(victim.tokens())} tokens")

dt = time.perf_counter() - t0
for h in handles:
    ttft, gaps = h.latency_stats()
    mean_tpot = float(np.mean(gaps)) if gaps else 0.0
    ttft_s = f"{ttft:.3f}s" if ttft is not None else "-"
    print(
        f"req {h.id}: {h.state.value:8s} reason={h.finish_reason:8s} "
        f"tokens={len(h.tokens()):3d} ttft={ttft_s} tpot={mean_tpot * 1e3:.1f}ms"
    )
print(f"\n{n_events} tokens streamed in {dt:.2f}s ({n_events / dt:.1f} tok/s "
      f"incl. compile)")
st = engine.serve_stats()
print(f"pool: {st.get('pages_in_use', 0)} pages in use / "
      f"{st.get('page_budget', 0)} budget; "
      f"requests={st['requests']}; decode_steps={st['decode_steps']}")
print("sample:", handles[0].tokens()[:16])

"""Distributed Strassen on a multi-device mesh (the paper's cluster demo).

Forces 8 host CPU devices (re-execs with XLA_FLAGS if needed), builds a
(4 data x 2 model) mesh, and runs all three distribution strategies:
  * strassen_bfs_sharded — Stark/CAPS BFS leaf-batch sharding
  * strassen_2d          — Luo & Drake Strassen-2D (2D-parallel leaves)
  * strassen_shardmap    — explicit-collective 7-way level (on a 7-mesh)

Run: PYTHONPATH=src python examples/strassen_distributed.py
"""
import os
import sys

if os.environ.get("XLA_FLAGS", "").find("host_platform_device_count") < 0:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    os.execv(sys.executable, [sys.executable] + sys.argv)

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compat import make_mesh
from repro.core.distributed import strassen_2d, strassen_bfs_sharded, strassen_shardmap

print(f"devices: {jax.device_count()}")
rng = np.random.default_rng(1)
a = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
b = jnp.asarray(rng.standard_normal((512, 512)), jnp.float32)
want = a @ b

mesh = make_mesh((4, 2), ("data", "model"))

bfs = jax.jit(functools.partial(strassen_bfs_sharded, mesh=mesh, depth=2))
got = bfs(a, b)
print(f"bfs_sharded   max|err| = {float(jnp.max(jnp.abs(got - want))):.3e}")

s2d = jax.jit(functools.partial(strassen_2d, mesh=mesh, depth=1))
got = s2d(a, b)
print(f"strassen_2d   max|err| = {float(jnp.max(jnp.abs(got - want))):.3e}")

mesh7 = make_mesh((7,), ("mult",))
smap = jax.jit(functools.partial(strassen_shardmap, mesh=mesh7))
got = smap(a, b)
print(f"shardmap(7)   max|err| = {float(jnp.max(jnp.abs(got - want))):.3e}")

# show the collective footprint of the BFS pipeline
txt = bfs.lower(a, b).compile().as_text()
from repro.launch.roofline import collective_bytes
print("collective bytes (bfs, depth=2):", collective_bytes(txt))

"""Quickstart: the paper's algorithm in five lines, then the full menu.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MatmulBackend, matmul, strassen_matmul, strassen_recursive

rng = np.random.default_rng(0)
a = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)
b = jnp.asarray(rng.standard_normal((1024, 1024)), jnp.float32)

# 1. The paper's Algorithm 1 (serial recursion, Breeze leaf -> jnp.dot).
c_serial = strassen_recursive(a, b, threshold=128)

# 2. Stark's flattened distributed form: 2 BFS levels -> 49 leaf products
#    in ONE batched stage (the Spark tags become the batch index).
c_bfs = jax.jit(lambda x, y: strassen_matmul(x, y, depth=2))(a, b)

# 3. As a framework feature: route any model matmul through the backend.
backend = MatmulBackend(kind="strassen", depth=2, min_dim=512)
c_backend = matmul(a, b, backend)

# 4. Winograd variant (beyond-paper: 7 mults, 15 adds).
c_wino = jax.jit(lambda x, y: strassen_matmul(x, y, depth=2, scheme="winograd"))(a, b)

want = a @ b
for name, got in [("serial", c_serial), ("bfs", c_bfs), ("backend", c_backend), ("winograd", c_wino)]:
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"{name:9s} max|err| = {err:.3e}")
    assert err < 2e-2, name
print("quickstart OK — see examples/strassen_distributed.py for the sharded version")

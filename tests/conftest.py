"""Test-session environment: force a multi-device CPU host platform.

Must run before the first ``import jax`` anywhere in the process (device
count locks at jax init — same idiom as bayespec's config.py), which is why
it lives at conftest import time rather than in a fixture. 8 host-platform
devices let the mesh/shard_map paths (test_distributed, autotune mesh
candidates) exercise real multi-device code on CPU; tests that need a
different count (e.g. the 512-device dry-run) spawn subprocesses and set
their own XLA_FLAGS.
"""
import os
import sys

_FLAG = "--xla_force_host_platform_device_count=8"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()

# src/ layout without requiring an editable install (pyproject makes
# `pip install -e .` work too; this keeps bare `python -m pytest` green).
_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

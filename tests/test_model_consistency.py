"""Cross-path consistency + property tests on the model stack."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored grid shim
    from _propshim import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models.attention import chunked_attention
from repro.models.frontends import make_stub_positions
from repro.models.rope import apply_mrope, apply_rope
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(11)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32), dtype)


# ---------------------------------------------------- chunked == reference
@pytest.mark.parametrize(
    "b,hq,hkv,sq,sk,causal,window",
    [
        (2, 4, 2, 128, 128, True, None),
        (1, 4, 1, 96, 96, True, None),  # non-pow2 seq exercises chunk picking
        (2, 2, 2, 64, 64, False, None),
        (1, 4, 2, 128, 128, True, 32),
    ],
)
def test_chunked_attention_matches_naive(b, hq, hkv, sq, sk, causal, window):
    q, k, v = _rand((b, hq, sq, 32)), _rand((b, hkv, sk, 32)), _rand((b, hkv, sk, 32))
    got = chunked_attention(q, k, v, causal=causal, window=window, q_chunk=32, k_chunk=48)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_chunked_attention_is_differentiable_and_matches_naive_grad():
    q, k, v = _rand((1, 2, 64, 16)), _rand((1, 2, 64, 16)), _rand((1, 2, 64, 16))

    def loss_chunked(q):
        return jnp.sum(chunked_attention(q, k, v, q_chunk=16, k_chunk=16) ** 2)

    def loss_naive(q):
        return jnp.sum(attention_ref(q, k, v) ** 2)

    g1 = jax.grad(loss_chunked)(q)
    g2 = jax.grad(loss_naive)(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=2e-4, rtol=2e-4)


# ---------------------------------------------------- rope
def test_mrope_reduces_to_rope_for_text():
    """Equal position streams == plain RoPE (vision stub contract)."""
    x = _rand((2, 4, 16, 16))
    pos = jnp.broadcast_to(jnp.arange(16)[None], (2, 16))
    pos3 = make_stub_positions(2, 16)
    a = apply_rope(x, pos, theta=10000.0)
    b = apply_mrope(x, pos3, theta=10000.0, sections=(4, 2, 2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_rope_preserves_norm_and_relativity():
    x = _rand((1, 1, 8, 32))
    pos = jnp.broadcast_to(jnp.arange(8)[None], (1, 8))
    y = apply_rope(x, pos, theta=10000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <rope(q,m), rope(k,n)> depends only on m-n
    q, k = _rand((1, 1, 1, 32)), _rand((1, 1, 1, 32))
    def dot_at(m, n):
        qm = apply_rope(q, jnp.full((1, 1), m), 10000.0)
        kn = apply_rope(k, jnp.full((1, 1), n), 10000.0)
        return float(jnp.sum(qm * kn))
    assert dot_at(3, 1) == pytest.approx(dot_at(10, 8), rel=1e-4)


# ---------------------------------------------------- decode == train logits
@pytest.mark.parametrize("arch", ["phi4_mini_3_8b", "xlstm_1_3b", "recurrentgemma_9b", "whisper_tiny"])
def test_stepwise_decode_matches_teacher_forcing(arch):
    """Greedy decode logits must equal teacher-forced logits position-wise."""
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(7)
    params = M.init_params(cfg, key)
    B, S = 1, 12
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.frontend == "audio_stub":
        from repro.models.frontends import make_stub_frames
        batch["frames"] = make_stub_frames(cfg, B)
    full_logits, _ = M.apply_train(params, {**batch, "labels": tokens}, cfg)

    cache = M.init_cache(cfg, B, S + 2)
    prefix = {**batch, "tokens": tokens[:, :4]}
    lp, cache = M.apply_prefill(params, prefix, cache, cfg)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(full_logits[:, 3]), atol=3e-3, rtol=1e-3
    )
    for t in range(4, S):
        step_logits, cache = M.apply_decode(params, tokens[:, t : t + 1], cache, cfg)
        np.testing.assert_allclose(
            np.asarray(step_logits), np.asarray(full_logits[:, t]),
            atol=3e-3, rtol=1e-3, err_msg=f"{arch} step {t}",
        )


# ---------------------------------------------------- moe properties
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_property_moe_gates_normalized_and_finite(seed):
    from repro.models.moe import moe_block, init_moe
    cfg = get_smoke_config("olmoe_1b_7b")
    key = jax.random.PRNGKey(seed)
    params = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 8, cfg.d_model))
    out, aux = moe_block(params, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0.0


def test_moe_capacity_drops_dont_blow_up():
    """With capacity_factor -> tiny, output degrades to ~zero, not NaN."""
    import dataclasses
    from repro.models.moe import moe_block, init_moe
    cfg = dataclasses.replace(get_smoke_config("olmoe_1b_7b"), capacity_factor=0.01)
    key = jax.random.PRNGKey(0)
    params = init_moe(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    out, _ = moe_block(params, x, cfg)
    assert bool(jnp.all(jnp.isfinite(out)))

"""SPIN block-recursive solvers on the recursive-plan runtime: parity
under capped budgets across stores, chaos healing, span/telemetry op
threading, backend-level routing, and the solver autotune families."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
import jax.scipy.linalg as jsl

from repro import obs
from repro.blocks.solve import (
    SolveScheduler,
    solver_min_depth_for_budget,
    spin_inverse_oot,
    triangular_solve_oot,
)
from repro.core import autotune
from repro.core.autotune import Calibration, TuningCache
from repro.core.backend import (
    SOLVER_KINDS,
    SOLVER_JIT_SAFE_KINDS,
    VALID_KINDS,
    MatmulBackend,
    inverse,
    solve_triangular,
)

RNG = np.random.default_rng(0)

# Budget small enough that a 256^2 f32 dense-inverse working set
# (2 * 256 KiB) cannot fit — every sized test below goes out-of-core
# and its nested multiplies run multi-wave staging.
BUDGET = 96 << 10

CALIB = Calibration(t_flop=1e-11, t_elem=1e-9, device_kind="test", device_count=1)


@pytest.fixture(autouse=True)
def _synthetic_calibration(monkeypatch):
    """No micro-benchmarks and no cross-test process-cache leakage."""
    monkeypatch.setattr(autotune, "_CALIBRATION", CALIB)
    monkeypatch.setattr(autotune, "_PROCESS_CACHES", {})


def _spd(n, dtype=np.float32):
    g = RNG.standard_normal((n, n)).astype(np.float32)
    return (g @ g.T / n + 2.0 * np.eye(n, dtype=np.float32)).astype(dtype)


def _tri(n, lower=True, dtype=np.float32):
    g = RNG.standard_normal((n, n)).astype(np.float32)
    t = np.tril(g) if lower else np.triu(g)
    return (t / np.sqrt(n) + 2.0 * np.eye(n, dtype=np.float32)).astype(dtype)


def _rel_err(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    scale = float(np.abs(want).max()) or 1.0
    return float(np.abs(got - want).max() / scale)


# ------------------------------------------------------ out-of-core parity


@pytest.mark.parametrize("store", ["dict", "arena", "memmap"])
def test_spin_inverse_parity_across_stores(store, tmp_path):
    a = _spd(256)
    out, stats = spin_inverse_oot(
        a, budget_bytes=BUDGET, store=store,
        store_root=str(tmp_path) if store == "memmap" else None,
    )
    want = np.asarray(jnp.linalg.inv(jnp.asarray(a)))
    assert _rel_err(out, want) <= 1e-5
    assert out.shape == a.shape and out.dtype == a.dtype
    assert stats.op == "inverse"
    assert stats.oot_runs > 0  # multiplies re-entered the oot scheduler
    assert stats.waves >= 2  # ...and needed real staging waves
    assert 0 < stats.peak_device_bytes <= BUDGET


@pytest.mark.parametrize("lower", [True, False])
def test_triangular_solve_parity(lower):
    t = _tri(256, lower=lower)
    b = RNG.standard_normal((256, 128)).astype(np.float32)
    out, stats = triangular_solve_oot(
        t, b, lower=lower, budget_bytes=BUDGET
    )
    want = np.asarray(jsl.solve_triangular(
        jnp.asarray(t), jnp.asarray(b), lower=lower
    ))
    assert _rel_err(out, want) <= 1e-5
    assert stats.op == "solve"
    assert stats.n == 128  # stats carry the RHS panel width
    assert stats.oot_runs > 0
    assert stats.peak_device_bytes <= BUDGET


def test_bf16_inverse_parity():
    import ml_dtypes

    a = _spd(192, dtype=ml_dtypes.bfloat16)
    out, stats = spin_inverse_oot(a, budget_bytes=BUDGET)
    want = np.asarray(
        jnp.linalg.inv(jnp.asarray(a, jnp.float32))
    )
    assert _rel_err(out, want) <= 1e-2
    assert out.dtype == a.dtype
    assert stats.stage_dtype == "float32"  # accumulation stays f32


def test_non_power_of_two_size_pads_with_identity():
    a = _spd(200)  # not divisible by 2**depth
    out, _ = spin_inverse_oot(a, depth=2, budget_bytes=BUDGET)
    want = np.asarray(jnp.linalg.inv(jnp.asarray(a)))
    assert out.shape == (200, 200)
    assert _rel_err(out, want) <= 1e-5


# ------------------------------------------------------- depth selection


def test_solver_min_depth_for_budget():
    f32 = np.float32
    # A 64^2 f32 inverse leaf needs 2*64*64*4 = 32 KiB.
    assert solver_min_depth_for_budget(64, 32 << 10, f32) == 0
    assert solver_min_depth_for_budget(128, 32 << 10, f32) == 1
    assert solver_min_depth_for_budget(256, 32 << 10, f32) == 2
    with pytest.raises(ValueError, match="budget_bytes"):
        solver_min_depth_for_budget(64, 0, f32)
    with pytest.raises(ValueError, match="no depth"):
        solver_min_depth_for_budget(1 << 20, 16, f32, max_depth=3)


def test_trsm_leaf_keeps_full_rhs_width():
    """The RHS splits by rows only: leaf columns never shrink, so a wide
    panel forces deeper recursion than a narrow one."""
    f32 = np.float32
    narrow = solver_min_depth_for_budget(
        256, 48 << 10, f32, nrhs=16, leaf_kind="trsm_lower"
    )
    wide = solver_min_depth_for_budget(
        256, 48 << 10, f32, nrhs=4096, leaf_kind="trsm_lower"
    )
    assert wide > narrow


def test_leaf_too_big_for_budget_raises():
    a = _spd(256)
    with pytest.raises(ValueError, match="cannot hold"):
        spin_inverse_oot(a, depth=0, budget_bytes=BUDGET)


def test_scheduler_rejects_bilinear_plan():
    from repro.blocks.plan import matmul_plan

    with pytest.raises((TypeError, ValueError)):
        SolveScheduler(plan=matmul_plan("strassen"), depth=1, budget_bytes=BUDGET)


# ------------------------------------------------------------ chaos parity


def test_chaos_heals_bit_identically():
    from repro.blocks.recovery import ChaosConfig

    a = _spd(256)
    clean, _ = spin_inverse_oot(a, budget_bytes=BUDGET)
    chaos = ChaosConfig(drop=0.05, corrupt=0.02, leaf_fail_rate=0.02, seed=0)
    healed, stats = spin_inverse_oot(a, budget_bytes=BUDGET, chaos=chaos)
    assert stats.injected_faults > 0
    assert stats.recovered_blocks > 0
    assert stats.unrecovered_faults == 0
    assert stats.peak_device_bytes <= BUDGET
    # Lineage recovery replays the exact computation path: anything short
    # of bit-identical is a recovery bug, not roundoff.
    assert np.array_equal(np.asarray(clean), np.asarray(healed))


def test_chaos_seeds_differ_per_nested_multiply():
    """Two multiplies in one run must not see identical fault streams —
    the per-call seed derivation keeps the harness deterministic but
    decorrelated. Same config twice, though, is bit-reproducible."""
    from repro.blocks.recovery import ChaosConfig

    a = _spd(192)
    chaos = ChaosConfig(drop=0.05, corrupt=0.02, leaf_fail_rate=0.02, seed=7)
    out1, s1 = spin_inverse_oot(a, budget_bytes=BUDGET, chaos=chaos)
    out2, s2 = spin_inverse_oot(a, budget_bytes=BUDGET, chaos=chaos)
    assert np.array_equal(np.asarray(out1), np.asarray(out2))
    assert s1.injected_faults == s2.injected_faults


# ------------------------------------------------- spans & op threading


@pytest.fixture
def global_tracing():
    obs.reset_tracing()
    obs.configure(enabled=True)
    yield obs.get_tracer()
    obs.configure(enabled=False)
    obs.reset_tracing()


def test_inverse_root_span_and_nested_matmul_spans(global_tracing):
    a = _spd(256)
    _, stats = spin_inverse_oot(a, budget_bytes=BUDGET)
    spans = obs.get_tracer().snapshot()
    roots = [s for s in spans if s.name == "oot.inverse"]
    assert len(roots) == 1  # one solver run, one root
    assert roots[0].attrs["op"] == "inverse"
    assert roots[0].attrs["oot_runs"] == stats.oot_runs
    # Nested out-of-core multiplies keep their own oot.matmul roots and
    # wave lanes — the plan layer renames nothing about the matmul path.
    assert len([s for s in spans if s.name == "oot.matmul"]) == stats.oot_runs
    assert any(s.name == "leaf.inv" for s in spans)
    assert any(s.name == "solve.node" for s in spans)


def test_solve_root_span(global_tracing):
    t = _tri(192)
    b = RNG.standard_normal((192, 64)).astype(np.float32)
    triangular_solve_oot(t, b, budget_bytes=BUDGET)
    roots = [s for s in obs.get_tracer().snapshot() if s.name == "oot.solve"]
    assert len(roots) == 1
    assert roots[0].attrs["op"] == "solve"
    assert roots[0].attrs["plan"] == "spin_trsm_lower"


def test_fault_counters_carry_op(global_tracing):
    from repro.blocks.recovery import ChaosConfig
    from repro.obs import metrics as obs_metrics

    obs_metrics.reset_metrics()
    a = _spd(256)
    chaos = ChaosConfig(drop=0.0, corrupt=0.0, leaf_fail_rate=0.1, seed=1)
    _, stats = spin_inverse_oot(a, budget_bytes=BUDGET, chaos=chaos)
    assert stats.leaf_retries > 0
    # The nested multiplies run the matmul plan, so their retry counter
    # is attributed to the matmul op — the solver op never masks it.
    mx = obs_metrics.get_metrics()
    assert mx.counter("fault.retries.matmul").value == stats.leaf_retries


def test_stats_ring_carries_op():
    from repro.blocks.scheduler import recent_oot_stats, reset_oot_stats

    reset_oot_stats()
    a = _spd(192)
    spin_inverse_oot(a, budget_bytes=BUDGET)
    ops = {row["op"] for row in recent_oot_stats()}
    assert "inverse" in ops  # the solver run itself
    assert "matmul" in ops  # its nested multiplies


# ----------------------------------------------------- backend-level ops


def test_backend_inverse_dense_kind():
    a = jnp.asarray(_spd(64))
    out = inverse(a, MatmulBackend(kind="naive"), kind="dense")
    assert np.allclose(np.asarray(out), np.asarray(jnp.linalg.inv(a)))


def test_backend_inverse_auto_routes_by_budget():
    a = jnp.asarray(_spd(256))
    bk = MatmulBackend(kind="auto", depth=2, device_budget=BUDGET)
    out = inverse(a, bk, kind="auto")  # 2n^2 bytes > budget -> spin_oot
    want = np.asarray(jnp.linalg.inv(a))
    assert _rel_err(out, want) <= 1e-5


def test_backend_solve_triangular_spin_oot():
    t = jnp.asarray(_tri(256))
    b = jnp.asarray(RNG.standard_normal((256, 64)).astype(np.float32))
    bk = MatmulBackend(kind="auto", depth=2, device_budget=BUDGET)
    out = solve_triangular(t, b, bk, lower=True, kind="spin_oot")
    want = np.asarray(jsl.solve_triangular(t, b, lower=True))
    assert _rel_err(out, want) <= 1e-5


def test_solver_kind_errors_enumerate_valid_kinds():
    """The message derives from SOLVER_KINDS itself: a new kind added to
    the tuple shows up in the error without touching the message."""
    a = jnp.asarray(_spd(16))
    with pytest.raises(ValueError) as ei:
        inverse(a, kind="cholesky")
    for k in SOLVER_KINDS:
        assert k in str(ei.value)
    with pytest.raises(ValueError) as ei:
        solve_triangular(a, a, kind="gauss")
    for k in SOLVER_KINDS:
        assert k in str(ei.value)


def test_matmul_kind_error_enumerates_valid_kinds():
    with pytest.raises(ValueError) as ei:
        MatmulBackend(kind="bogus")
    for k in VALID_KINDS:
        assert k in str(ei.value)


def test_spin_oot_rejects_jit_tracing():
    bk = MatmulBackend(kind="auto", depth=2, device_budget=BUDGET)

    @jax.jit
    def f(x):
        return inverse(x, bk, kind="spin_oot")

    with pytest.raises(ValueError) as ei:
        f(jnp.asarray(_spd(32)))
    for k in SOLVER_JIT_SAFE_KINDS:
        assert k in str(ei.value)


def test_auto_under_jit_falls_back_to_dense():
    """kind='auto' must stay jit-safe even with a tiny budget: tracing
    cannot host-stage, so auto picks the dense path."""
    bk = MatmulBackend(kind="auto", depth=2, device_budget=BUDGET)
    a = jnp.asarray(_spd(256))

    @jax.jit
    def f(x):
        return inverse(x, bk, kind="auto")

    out = f(a)
    assert np.allclose(
        np.asarray(out), np.asarray(jnp.linalg.inv(a)), atol=1e-4
    )


# ------------------------------------------------------- autotune family


def test_autotune_solver_families_and_cache():
    cache = TuningCache()
    d1 = autotune.autotune_solver(
        "inverse", 512, jnp.float32, oot_budget=BUDGET, max_depth=10,
        cache=cache, calibration=CALIB,
    )
    assert d1.kind == "inverse_oot"
    assert d1.source == "predicted"
    assert d1.depth >= solver_min_depth_for_budget(512, BUDGET, np.float32)
    d2 = autotune.autotune_solver(
        "inverse", 512, jnp.float32, oot_budget=BUDGET, max_depth=10,
        cache=cache, calibration=CALIB,
    )
    assert d2.source == "cache"
    assert d2.depth == d1.depth
    ds = autotune.autotune_solver(
        "solve", 512, jnp.float32, nrhs=128, oot_budget=BUDGET, max_depth=10,
        cache=cache, calibration=CALIB,
    )
    assert ds.kind == "solve_oot"
    assert len(cache.entries) == 2  # solver keys don't collide


def test_autotune_solver_unknown_op():
    with pytest.raises(ValueError, match="unknown solver op"):
        autotune.autotune_solver("lu", 256, jnp.float32)


def test_predict_solver_terms_scale_with_depth():
    """SPIN's arithmetic is depth-invariant (the six half-size multiplies
    telescope to the same 2n^3), but every added level stages more
    traffic and host adds — so among feasible depths the tuner prefers
    the shallowest, which is exactly the budget-respecting choice."""
    t1 = autotune.predict_solver_terms("inverse", 1024, 1, CALIB)
    t3 = autotune.predict_solver_terms("inverse", 1024, 3, CALIB)
    assert set(t1) == {"flop_s", "elem_s", "h2d_s"}
    assert t3["flop_s"] == pytest.approx(t1["flop_s"])
    assert t3["h2d_s"] > t1["h2d_s"]
    assert t3["elem_s"] > t1["elem_s"]
    assert autotune.predict_solver_seconds(
        "inverse", 1024, 1, CALIB
    ) < autotune.predict_solver_seconds("inverse", 1024, 3, CALIB)
    # Depth 0 stages the whole dense leaf with no compute to hide behind,
    # so its traffic term is fully exposed — larger than depth 1's.
    t0 = autotune.predict_solver_terms("inverse", 1024, 0, CALIB)
    assert t0["h2d_s"] > t1["h2d_s"]

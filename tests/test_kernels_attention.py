"""Flash attention kernel vs oracle: head-config/mask/shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

RNG = np.random.default_rng(2)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32), dtype)


@pytest.mark.parametrize(
    "b,hq,hkv,s,d",
    [
        (2, 4, 2, 128, 32),   # GQA
        (1, 8, 1, 64, 16),    # MQA
        (2, 4, 4, 128, 64),   # MHA
        (1, 2, 2, 256, 128),  # long-ish
    ],
)
@pytest.mark.parametrize("causal", [True, False])
def test_flash_vs_ref(b, hq, hkv, s, d, causal):
    q, k, v = _rand((b, hq, s, d)), _rand((b, hkv, s, d)), _rand((b, hkv, s, d))
    got = flash_attention(q, k, v, causal=causal, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [16, 64, 1])
def test_flash_sliding_window(window):
    q, k, v = _rand((1, 2, 128, 32)), _rand((1, 2, 128, 32)), _rand((1, 2, 128, 32))
    got = flash_attention(q, k, v, causal=True, window=window, block_q=32, block_k=32)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    q, k, v = (
        _rand((1, 4, 128, 64), jnp.bfloat16),
        _rand((1, 2, 128, 64), jnp.bfloat16),
        _rand((1, 2, 128, 64), jnp.bfloat16),
    )
    got = flash_attention(q, k, v, block_q=64, block_k=64)
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-2, rtol=2e-2
    )


def test_flash_window_requires_causal():
    q = _rand((1, 1, 32, 16))
    with pytest.raises(ValueError):
        flash_attention(q, q, q, causal=False, window=8)


@pytest.mark.parametrize("shape", [(64, 128), (4, 32, 256), (2, 2, 8, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x, w = _rand(shape, dtype), _rand(shape[-1:], dtype)
    got = rmsnorm(x, w)
    want = rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=2e-2 if dtype == jnp.bfloat16 else 1e-5
    )

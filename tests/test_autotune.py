"""Autotuned kind='auto' dispatcher: correctness, guards, cache, cost model."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored grid shim
    from _propshim import given, settings, strategies as st

from repro.core import autotune, compat
from repro.core.autotune import (
    Calibration,
    Candidate,
    Decision,
    TuningCache,
    cache_key,
    enumerate_candidates,
    predict_seconds,
)
from repro.core.backend import MatmulBackend, matmul, resolve_auto
from repro.core.cost_model import paper_stage_count, total_cost

RNG = np.random.default_rng(17)

# Fixed synthetic constants: decisions in these tests must never depend on
# the machine the suite happens to run on.
CALIB = Calibration(t_flop=1e-11, t_elem=1e-9, device_kind="test", device_count=1)


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32), dtype)


def _auto_backend(**kw):
    kw.setdefault("kind", "auto")
    kw.setdefault("depth", 2)
    return MatmulBackend(**kw)


@pytest.fixture(autouse=True)
def _synthetic_calibration(monkeypatch):
    """No micro-benchmarks and no cross-test lru_cache leakage."""
    monkeypatch.setattr(autotune, "_CALIBRATION", CALIB)
    monkeypatch.setattr(autotune, "_PROCESS_CACHES", {})
    resolve_auto.cache_clear()


# ------------------------------------------------------------- correctness
@settings(max_examples=20, deadline=None)
@given(
    logm=st.integers(min_value=5, max_value=8),
    logk=st.integers(min_value=5, max_value=8),
    logn=st.integers(min_value=5, max_value=8),
    min_dim=st.sampled_from([1, 64, 4096]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_auto_matches_matmul(logm, logk, logn, min_dim, seed):
    rng = np.random.default_rng(seed)
    m, k, n = 2**logm, 2**logk, 2**logn
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = matmul(x, w, _auto_backend(min_dim=min_dim))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
    )


@pytest.mark.parametrize("shape", [(96, 96, 96), (100, 60, 36), (33, 65, 17)])
def test_auto_odd_and_non_pow2_shapes(shape):
    """Divisibility guard: odd dims route to shallower depth or naive."""
    m, k, n = shape
    x, w = _rand((m, k)), _rand((k, n))
    got = matmul(x, w, _auto_backend(min_dim=1))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
    )


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-3), (jnp.bfloat16, 1.5e-1)])
def test_auto_dtypes(dtype, tol):
    x, w = _rand((128, 128), dtype), _rand((128, 128), dtype)
    got = matmul(x, w, _auto_backend(min_dim=1))
    want = jnp.matmul(x, w)
    np.testing.assert_allclose(
        np.asarray(got, dtype=np.float32), np.asarray(want, dtype=np.float32),
        atol=tol, rtol=tol,
    )


def test_auto_under_jit_and_batched_lead_dims():
    x, w = _rand((4, 32, 128)), _rand((128, 64))
    be = _auto_backend(min_dim=1)
    got = jax.jit(lambda a, b: matmul(a, b, be))(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
    )


# ------------------------------------------------------------------ guards
def test_never_selects_strassen_below_min_dim():
    for m, k, n in [(512, 512, 512), (1023, 1024, 1024), (64, 4096, 4096)]:
        cands = enumerate_candidates(m, k, n, min_dim=1024)
        assert cands == [Candidate(kind="naive")], (m, k, n, cands)
        d = autotune.autotune(m, k, n, min_dim=1024, calibration=CALIB)
        assert d.kind == "naive" and d.depth == 0


def test_depth_respects_divisibility_per_level():
    # 1028 = 4 * 257: two halvings possible, not three.
    cands = enumerate_candidates(1028, 1028, 1028, min_dim=1, max_depth=3)
    depths = {c.depth for c in cands if c.kind == "strassen"}
    assert depths == {1, 2}


def test_enumeration_matches_backend_effective_depth():
    be = MatmulBackend(kind="strassen", depth=3, min_dim=256)
    for dims in [(1024, 1024, 1024), (512, 2048, 1024), (640, 640, 640)]:
        cands = enumerate_candidates(*dims, min_dim=256, max_depth=3)
        max_enum = max((c.depth for c in cands if c.kind == "strassen"), default=0)
        assert max_enum == be.effective_depth(*dims), dims


def test_larger_shapes_prefer_strassen_smaller_prefer_naive():
    """The §V-C crossover under fixed constants: selection flips with n."""
    small = autotune.autotune(256, 256, 256, calibration=CALIB, min_dim=1024)
    large = autotune.autotune(8192, 8192, 8192, calibration=CALIB, min_dim=1024)
    assert small.kind == "naive"
    assert large.kind in ("strassen", "winograd", "strassen_fused")
    assert large.depth >= 1


# ------------------------------------------------------------------- cache
def test_cache_round_trip_no_remeasure(tmp_path, monkeypatch):
    path = os.path.join(tmp_path, "tuning.json")
    cache = TuningCache(path)
    d1 = autotune.autotune(
        4096, 4096, 4096, calibration=CALIB, cache=cache, measure=True, top_k=1
    )
    assert d1.source == "measured" and d1.measured_s is not None
    assert os.path.exists(path)

    # Fresh load: identical decision, and neither measurement nor
    # calibration may run again.
    def boom(*a, **k):
        raise AssertionError("re-measured on a warm cache")

    monkeypatch.setattr(autotune, "measure_seconds", boom)
    monkeypatch.setattr(autotune, "calibrate", boom)
    cache2 = TuningCache(path)
    assert cache2.calibration == CALIB  # calibration persists alongside
    d2 = autotune.autotune(4096, 4096, 4096, cache=cache2, measure=True, top_k=1)
    assert d2.source == "cache"
    assert (d2.kind, d2.scheme, d2.depth) == (d1.kind, d1.scheme, d1.depth)
    assert d2.measured_s == d1.measured_s


def test_cache_key_separates_dtype_and_shape():
    kw = dict(device_kind="cpu", device_count=1, schemes=("strassen",),
              min_dim=1024, max_depth=2)
    k1 = cache_key(512, 512, 512, jnp.float32, **kw)
    k2 = cache_key(512, 512, 512, jnp.bfloat16, **kw)
    k3 = cache_key(512, 512, 1024, jnp.float32, **kw)
    assert len({k1, k2, k3}) == 3


def test_backend_resolution_is_cached_per_shape(monkeypatch):
    be = _auto_backend(min_dim=1)
    calls = []
    real = autotune.autotune

    def counting(*a, **k):
        calls.append(a[:3])
        return real(*a, **k)

    monkeypatch.setattr(autotune, "autotune", counting)
    x, w = _rand((64, 64)), _rand((64, 64))
    matmul(x, w, be)
    matmul(x, w, be)  # same shape: lru-cached, no second decision
    assert len(calls) == 1


# -------------------------------------------------- cost model regressions
def test_paper_stage_count_matches_eq25():
    """Stark's Spark-stage count is 2(p-q)+2 — pinned against eq. 25."""
    for p, q in [(10, 8), (12, 8), (14, 10), (14, 4)]:
        n, b = 2**p, 2 ** (p - q)
        assert paper_stage_count(n, b) == 2 * (p - q) + 2


def test_stark_vs_mllib_advantage_monotone_in_n():
    """Predicted stark/mllib ratio decreases monotonically with n (§V-C)."""
    ratios = [
        total_cost("stark", n, 16, cores=25) / total_cost("mllib", n, 16, cores=25)
        for n in (2048, 4096, 8192, 16384, 32768)
    ]
    assert all(a > b for a, b in zip(ratios, ratios[1:])), ratios


def test_jax_crossover_monotone_in_n():
    """Auto model: strassen-vs-naive predicted ratio falls monotonically."""
    c = Candidate(kind="strassen", scheme="strassen", depth=1)
    naive = Candidate(kind="naive")
    ratios = [
        predict_seconds(c, n, n, n, CALIB) / predict_seconds(naive, n, n, n, CALIB)
        for n in (512, 1024, 2048, 4096, 8192, 16384)
    ]
    assert all(a > b for a, b in zip(ratios, ratios[1:])), ratios


def test_calibrated_constants_positive():
    calib = autotune.calibrate(sample_dim=64, repeats=1)
    assert calib.t_flop > 0.0 and calib.t_elem > 0.0
    assert calib.device_kind and calib.device_count >= 1


def test_predictions_positive_and_naive_flops_exact():
    assert predict_seconds(Candidate(kind="naive"), 100, 200, 300, CALIB) == (
        pytest.approx(2.0 * 100 * 200 * 300 * CALIB.t_flop)
    )
    for c in enumerate_candidates(2048, 2048, 2048, min_dim=1, max_depth=3):
        assert predict_seconds(c, 2048, 2048, 2048, CALIB) > 0.0


# -------------------------------------------------- fused Pallas candidate
def test_fused_enumerates_when_leaf_runs():
    """strassen_fused appears at every usable depth on hosts where the
    Pallas leaf runs (interpret mode on this CPU suite)."""
    assert compat.pallas_leaf_mode() in ("compiled", "interpret")
    cands = enumerate_candidates(4096, 4096, 4096, min_dim=1, max_depth=2)
    fused = {c.depth for c in cands if c.kind == "strassen_fused"}
    assert fused == {1, 2}
    assert all(c.scheme == "strassen" for c in cands if c.kind == "strassen_fused")


def test_fused_not_enumerated_without_pallas(monkeypatch):
    monkeypatch.setattr(compat, "pallas_leaf_mode", lambda: "none")
    cands = enumerate_candidates(4096, 4096, 4096, min_dim=1, max_depth=2)
    assert not any(c.kind == "strassen_fused" for c in cands)


def test_fused_selected_at_scale_and_executes():
    """Under the fixed constants the fused pipeline wins once dims clear
    the crossover; the candidate executes exactly (checked at a small
    shape — interpret-mode Pallas at 8192 would dominate suite time)."""
    d = autotune.autotune(8192, 8192, 8192, calibration=CALIB, min_dim=1024)
    assert d.kind == "strassen_fused" and d.depth >= 1
    small = Candidate(kind="strassen_fused", scheme="strassen", depth=d.depth)
    x, w = _rand((256, 256)), _rand((256, 256))
    got = autotune.execute(small, x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
    )


def test_resolve_auto_routes_through_fused_backend(monkeypatch):
    """A fused decision resolves to a kind='strassen_fused' backend and the
    matmul wrapper routes through the Pallas pipeline."""
    be = _auto_backend(min_dim=1)
    decision = Decision(
        kind="strassen_fused", scheme="strassen", depth=1, predicted_s=1e-3
    )
    monkeypatch.setattr(autotune, "autotune", lambda *a, **k: decision)
    resolved = resolve_auto(256, 256, 256, "float32", be)
    assert resolved.kind == "strassen_fused" and resolved.depth == 1
    x, w = _rand((256, 256)), _rand((256, 256))
    got = matmul(x, w, be)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
    )


def test_fused_predicted_cheaper_than_unfused_strassen():
    """The fused leaf skips the last level's materialized M-terms, so at
    equal depth its predicted cost must be strictly below plain BFS."""
    for depth in (1, 2, 3):
        fused = Candidate(kind="strassen_fused", scheme="strassen", depth=depth)
        plain = Candidate(kind="strassen", scheme="strassen", depth=depth)
        n = 8192
        assert predict_seconds(fused, n, n, n, CALIB) < predict_seconds(
            plain, n, n, n, CALIB
        )


# ------------------------------------------------------- t_coll cost model
def test_t_coll_monotonicity():
    """Mesh-strategy predictions are strictly increasing in t_coll; local
    candidates never touch the interconnect constant."""
    n, dc = 4096, 8
    mesh_kinds = [
        Candidate(kind="strassen_bfs_sharded", scheme="strassen", depth=2),
        Candidate(kind="strassen_2d", scheme="strassen", depth=2),
        Candidate(kind="strassen_fused_sharded", scheme="strassen", depth=2),
        Candidate(kind="naive"),
    ]
    local_kinds = [
        Candidate(kind="strassen", scheme="strassen", depth=2),
        Candidate(kind="strassen_fused", scheme="strassen", depth=2),
    ]
    t_colls = [1e-9, 4e-9, 1.6e-8, 6.4e-8]
    for cand in mesh_kinds:
        costs = [
            predict_seconds(
                cand, n, n, n,
                dataclasses.replace(CALIB, t_coll=tc, device_count=dc),
                device_count=dc,
            )
            for tc in t_colls
        ]
        assert all(a < b for a, b in zip(costs, costs[1:])), (cand.kind, costs)
    for cand in local_kinds:
        costs = {
            predict_seconds(
                cand, n, n, n,
                dataclasses.replace(CALIB, t_coll=tc, device_count=dc),
                device_count=dc,
            )
            for tc in t_colls
        }
        assert len(costs) == 1, (cand.kind, costs)


def test_t_coll_zero_falls_back_to_t_elem():
    """Pre-t_coll calibrations (t_coll=0) must reproduce the old model."""
    cand = Candidate(kind="strassen_bfs_sharded", scheme="strassen", depth=1)
    base = predict_seconds(cand, 2048, 2048, 2048, CALIB, device_count=8)
    explicit = predict_seconds(
        cand, 2048, 2048, 2048,
        dataclasses.replace(CALIB, t_coll=CALIB.t_elem), device_count=8,
    )
    assert base == pytest.approx(explicit)


def test_calibrate_collective_positive_on_multidevice():
    assert jax.device_count() >= 2  # conftest forces 8 host devices
    t_coll = autotune.calibrate_collective(sample_dim=64, repeats=1)
    assert t_coll > 0.0


# ------------------------------------------------------- call-site caching
def test_cache_key_site_tag_separates_and_composes():
    kw = dict(device_kind="cpu", device_count=1, schemes=("strassen",),
              min_dim=1024, max_depth=2)
    k_plain = cache_key(512, 512, 512, jnp.float32, **kw)
    k_q = cache_key(512, 512, 512, jnp.float32, site="attn.wq", **kw)
    k_up = cache_key(512, 512, 512, jnp.float32, site="mlp.up", **kw)
    assert len({k_plain, k_q, k_up}) == 3
    assert k_q.startswith(k_plain)


def test_site_lookup_falls_back_to_generic_in_predicted_mode():
    cache = TuningCache()
    d1 = autotune.autotune(4096, 4096, 4096, calibration=CALIB, cache=cache)
    # the generic entry answers a tagged lookup without a new resolution
    d2 = autotune.autotune(
        4096, 4096, 4096, calibration=CALIB, cache=cache, site="attn.wq"
    )
    assert d2.source == "cache"
    assert (d2.kind, d2.depth) == (d1.kind, d1.depth)
    assert len(cache.entries) == 1


def test_measured_site_decisions_diverge(monkeypatch):
    """Under measure mode, two sites of the same shape hold separate
    entries — the point of call-site keys."""
    cache = TuningCache()
    times = iter([3.0, 1.0, 2.0, 1.0, 2.0, 3.0])  # distinct winners per site

    monkeypatch.setattr(
        autotune, "measure_seconds", lambda *a, **k: next(times)
    )
    d_q = autotune.autotune(
        4096, 4096, 4096, calibration=CALIB, cache=cache,
        measure=True, top_k=3, site="attn.wq",
    )
    d_up = autotune.autotune(
        4096, 4096, 4096, calibration=CALIB, cache=cache,
        measure=True, top_k=3, site="mlp.up",
    )
    assert len(cache.entries) == 2
    assert (d_q.kind, d_q.depth) != (d_up.kind, d_up.depth)


def test_resolve_auto_site_is_part_of_memo_key(monkeypatch):
    be = _auto_backend(min_dim=1)
    calls = []
    real = autotune.autotune

    def counting(*a, **k):
        calls.append(k.get("site"))
        return real(*a, **k)

    monkeypatch.setattr(autotune, "autotune", counting)
    x, w = _rand((64, 64)), _rand((64, 64))
    matmul(x, w, be, site="attn.wq")
    matmul(x, w, be, site="attn.wq")  # lru hit
    matmul(x, w, be, site="mlp.up")  # new site: new resolution
    assert calls == ["attn.wq", "mlp.up"]


# ------------------------------------------------------------- telemetry
def test_telemetry_records_hits_misses_and_kinds():
    tel = autotune.get_telemetry()
    tel.reset()
    cache = TuningCache()
    autotune.autotune(4096, 4096, 4096, calibration=CALIB, cache=cache)
    autotune.autotune(4096, 4096, 4096, calibration=CALIB, cache=cache)
    snap = tel.snapshot()
    assert snap["cache_misses"] == 1 and snap["cache_hits"] == 1
    assert sum(snap["kinds"].values()) == 2
    first, second = snap["decisions"]
    assert first["cache_hit"] is False and second["cache_hit"] is True
    assert first["kind"] == second["kind"]
    assert first["predicted_s"] > 0.0
    tel.reset()
    assert tel.snapshot()["cache_hits"] == 0 and not tel.snapshot()["decisions"]


def test_warm_for_model_emits_site_tagged_telemetry():
    from repro.configs import get_smoke_config

    tel = autotune.get_telemetry()
    tel.reset()
    cfg = get_smoke_config("phi4_mini_3_8b")
    cfg = dataclasses.replace(cfg, matmul_autotune=True)
    n = autotune.warm_for_model(cfg, tokens=(1, 64))
    assert n > 0
    sites = {e.site for e in tel.events}
    assert {"attn.wq", "mlp.up"} <= sites
    assert None not in sites
    # predicted-mode decisions dedupe to shape-only entries: equal-shape
    # sites share one cache row instead of storing identical copies
    cache = autotune.process_cache(cfg.matmul_backend.tuning_cache)
    assert cache.entries and not any("|site:" in k for k in cache.entries)


# ---------------------------------------------------------- mesh candidates
def test_mesh_enumeration_and_dispatch():
    """On a (data, model) mesh the registered strategies become candidates
    and the selected one still matches the naive product."""
    from repro.core.compat import make_mesh

    if jax.device_count() < 2:
        pytest.skip("needs the conftest multi-device host platform")
    mesh = make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    cands = enumerate_candidates(512, 512, 512, min_dim=64, max_depth=2, mesh=mesh)
    kinds = {c.kind for c in cands}
    assert {
        "naive",
        "strassen",
        "strassen_bfs_sharded",
        "strassen_2d",
        "strassen_fused_sharded",
    } <= kinds

    d = autotune.autotune(
        512, 512, 512, min_dim=64, max_depth=1, mesh=mesh,
        calibration=dataclasses.replace(CALIB, device_count=jax.device_count()),
    )
    x, w = _rand((512, 512)), _rand((512, 512))
    got = autotune.execute(d.candidate, x, w, mesh=mesh)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
    )


def test_fused_sharded_strategy_matches_matmul():
    """The shard_map'd Pallas fused leaf computes the exact product on the
    conftest host mesh (interpret mode on CPU), including shapes that need
    the M-stripe padding path."""
    from repro.core.compat import make_mesh
    from repro.core.distributed import strassen_fused_sharded

    if jax.device_count() < 2:
        pytest.skip("needs the conftest multi-device host platform")
    mesh = make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    for (m, k, n) in [(256, 128, 192), (200, 200, 200)]:
        x, w = _rand((m, k)), _rand((k, n))
        for depth in (1, 2):
            got = strassen_fused_sharded(x, w, mesh=mesh, depth=depth)
            assert got.shape == (m, n)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
            )
    cand = Candidate(kind="strassen_fused_sharded", scheme="strassen", depth=1)
    x, w = _rand((256, 128)), _rand((128, 192))
    got = autotune.execute(cand, x, w, mesh=mesh)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
    )


def test_mesh_selected_candidate_executes_on_awkward_shape():
    """The reviewer repro: a mesh decision at a shape that is divisible by
    2**depth but not by (row shards * 2**depth) must still execute."""
    from repro.core.compat import make_mesh

    if jax.device_count() < 2:
        pytest.skip("needs the conftest multi-device host platform")
    mesh = make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    calib = dataclasses.replace(
        CALIB, t_flop=1e-9, t_elem=1e-12, t_coll=1e-12,
        device_count=jax.device_count(),
    )
    d = autotune.autotune(
        200, 200, 200, min_dim=1, max_depth=2, mesh=mesh, calibration=calib
    )
    x, w = _rand((200, 200)), _rand((200, 200))
    got = autotune.execute(d.candidate, x, w, mesh=mesh)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(x @ w), atol=3e-3, rtol=3e-3
    )


# ---------------------------------------------------------- config plumbing
def test_model_config_autotune_flag_rewrites_backend():
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("phi4_mini_3_8b")
    assert cfg.matmul_backend.kind != "auto"
    cfg_auto = dataclasses.replace(cfg, matmul_autotune=True)
    assert cfg_auto.matmul_backend.kind == "auto"
    assert hash(cfg_auto) is not None  # stays usable as a static jit arg


def test_warm_for_model_counts_resolutions():
    from repro.configs import get_smoke_config

    cfg = get_smoke_config("phi4_mini_3_8b")
    cfg = dataclasses.replace(cfg, matmul_autotune=True)
    n = autotune.warm_for_model(cfg, tokens=(1, 64))
    assert n > 0
    # every warmed shape now resolves from the lru cache: no new decisions
    info_before = resolve_auto.cache_info().currsize
    autotune.warm_for_model(cfg, tokens=(1, 64))
    assert resolve_auto.cache_info().currsize == info_before


def test_reset_telemetry_and_caller_owned_log():
    """reset_telemetry() zeroes the process log (how Engine scopes its
    stats per instance), and autotune(telemetry=...) records to a
    caller-owned Telemetry, leaving the process log untouched."""
    tel = autotune.get_telemetry()
    tel.reset()
    autotune.autotune(4096, 4096, 4096, calibration=CALIB, cache=TuningCache())
    assert tel.snapshot()["cache_misses"] == 1
    assert autotune.reset_telemetry() is tel
    snap = tel.snapshot()
    assert snap["cache_hits"] == 0 and snap["cache_misses"] == 0
    assert not snap["decisions"]
    own = autotune.Telemetry()
    autotune.autotune(
        4096, 4096, 4096, calibration=CALIB, cache=TuningCache(), telemetry=own
    )
    assert own.cache_misses == 1 and len(own.events) == 1
    assert tel.snapshot()["cache_misses"] == 0  # process log untouched

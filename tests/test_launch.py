"""Launch layer: HLO analyzer, sharding specs, roofline parsing, mesh plan."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import (
    Hardware,
    collective_bytes,
    model_flops,
    roofline_terms,
)
from repro.launch.specs import (
    batch_logical_axes,
    cache_logical_axes,
    param_logical_axes,
)


# ------------------------------------------------------------ hlo analysis
def test_analyzer_counts_plain_matmul_exactly():
    m, n, k = 128, 256, 512
    f = jax.jit(lambda a, b: a @ b)
    txt = f.lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32),
    ).compile().as_text()
    assert analyze_hlo(txt).dot_flops == 2 * m * n * k


def test_analyzer_multiplies_loop_trip_counts():
    d, trips = 32, 9

    def step(x, _):
        return x @ x, None

    f = jax.jit(lambda x: jax.lax.scan(step, x, None, length=trips)[0])
    txt = f.lower(jax.ShapeDtypeStruct((d, d), jnp.float32)).compile().as_text()
    costs = analyze_hlo(txt)
    assert costs.dot_flops == trips * 2 * d**3
    assert trips in costs.while_trip_counts.values()


def test_analyzer_nested_loops():
    d = 16

    def inner(x, _):
        return x @ x, None

    def outer(x, _):
        return jax.lax.scan(inner, x, None, length=7)[0], None

    f = jax.jit(lambda x: jax.lax.scan(outer, x, None, length=5)[0])
    txt = f.lower(jax.ShapeDtypeStruct((d, d), jnp.float32)).compile().as_text()
    assert analyze_hlo(txt).dot_flops == 35 * 2 * d**3


def test_analyzer_xla_flops_undercount_demo():
    """Document WHY the analyzer exists: XLA misses the loop multiplier."""
    d, trips = 32, 50

    def step(x, _):
        return x @ x, None

    from repro.core.compat import compiled_cost_analysis

    f = jax.jit(lambda x: jax.lax.scan(step, x, None, length=trips)[0])
    compiled = f.lower(jax.ShapeDtypeStruct((d, d), jnp.float32)).compile()
    xla = float(compiled_cost_analysis(compiled).get("flops", 0.0))
    ours = analyze_hlo(compiled.as_text()).dot_flops
    assert ours == trips * 2 * d**3
    assert xla < ours  # XLA counts the body once


# ------------------------------------------------------------ logical axes
def test_param_rules_attention_flat():
    assert param_logical_axes("groups/pos0/mixer/wq/w", (8, 3072, 3072)) == (
        None, "fsdp", "heads",
    )
    assert param_logical_axes("tail/0/mixer/wo/w", (3072, 3072)) == ("heads", "fsdp")
    assert param_logical_axes("embed/embedding", (200064, 3072)) == ("vocab", "fsdp")
    assert param_logical_axes("m/embed/unembedding", (3072, 200064)) == ("fsdp", "vocab")


def test_param_rules_moe_and_norm():
    assert param_logical_axes("groups/pos1/ffn/w_gate", (4, 64, 2048, 1024)) == (
        None, "experts", "fsdp", "d_ff",
    )
    assert param_logical_axes("groups/pos0/ln1/scale", (8, 3072)) == (None, None)


def test_cache_rules():
    assert cache_logical_axes("groups/pos0/k", (8, 128, 8, 32768, 128)) == (
        None, "batch", "kv_heads", "cache_seq", None,
    )
    # slstm h stacked under groups (4D) vs rglru h (unstacked decode, 2D)
    assert cache_logical_axes("groups/pos7/h", (6, 1, 4, 512)) == (
        None, "batch", None, "state",
    )
    assert cache_logical_axes("tail/0/h", (1, 4096)) == ("batch", "state")
    assert cache_logical_axes("pos", ()) == ()


def test_batch_rules():
    assert batch_logical_axes("tokens", (256, 4096)) == ("batch", None)
    assert batch_logical_axes("positions", (32, 128, 3)) == ("batch", None, None)


# ------------------------------------------------------------ roofline
def test_collective_bytes_parsing():
    hlo = """
ENTRY %main (x: f32[16]) -> f32[16] {
  %x = f32[16]{0} parameter(0)
  %ar = f32[16]{0} all-reduce(f32[16]{0} %x), replica_groups={}
  %ag = bf16[64,8]{1,0} all-gather(bf16[8,8]{1,0} %y), dimensions={0}
  ROOT %out = f32[16]{0} copy(%ar)
}
"""
    got = collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 4
    assert got["all-gather"] == 8 * 8 * 2
    assert got["total"] == 16 * 4 + 128


def test_roofline_terms_bottleneck():
    hw = Hardware(peak_flops=100.0, hbm_bw=10.0, ici_bw=1.0)
    t = roofline_terms(
        hlo_flops=1000.0, hlo_bytes=10.0, coll_bytes=100.0,
        chips=4, per_device=True, hw=hw,
    )
    assert t["compute_s"] == pytest.approx(10.0)
    assert t["memory_s"] == pytest.approx(1.0)
    assert t["collective_s"] == pytest.approx(100.0)
    assert t["bottleneck"] == "collective"


def test_model_flops_train_vs_decode():
    assert model_flops(10, 10, 100, "train") == 6 * 10 * 100
    assert model_flops(10, 10, 100, "decode") == 2 * 10 * 100

"""Per-architecture smoke tests: reduced config, one forward + train step.

Every assigned arch instantiates a scaled-down same-family config and runs
a forward pass and one gradient step on CPU, asserting output shapes and
finiteness — the FULL configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config, skip_reason
from repro.models import model as M
from repro.models.frontends import make_stub_frames, make_stub_positions

B, S = 2, 16


def _batch(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.frontend == "audio_stub":
        batch["frames"] = make_stub_frames(cfg, B)
    if cfg.mrope:
        batch["positions"] = make_stub_positions(B, S)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)

    logits, aux = M.apply_train(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    loss, metrics = M.loss_fn(params, batch, cfg)
    assert np.isfinite(float(loss))

    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg)[0])(params)
    gnorm = sum(float(jnp.sum(g.astype(jnp.float32) ** 2)) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0.0, f"{arch}: bad grad norm {gnorm}"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    batch = _batch(cfg, key)
    batch.pop("labels")

    cache = M.init_cache(cfg, B, S + 4)
    logits_last, cache = M.apply_prefill(params, batch, cache, cfg)
    assert logits_last.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits_last)))

    nxt = jnp.argmax(logits_last, -1)[:, None]
    kwargs = {}
    if cfg.mrope:
        kwargs["positions"] = make_stub_positions(B, 1, offset=S)
    step_logits, cache = M.apply_decode(params, nxt, cache, cfg, **kwargs)
    assert step_logits.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(step_logits)))
    assert int(cache["pos"]) == S + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL config must carry the exact published dimensions."""
    spec = {
        "phi4_mini_3_8b": (32, 3072, 24, 8, 8192, 200064),
        "internlm2_20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen1_5_32b": (64, 5120, 40, 40, 27392, 152064),
        "gemma_7b": (28, 3072, 16, 16, 24576, 256000),
        "olmoe_1b_7b": (16, 2048, 16, 16, 0, 50304),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 0, 151936),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
        "recurrentgemma_9b": (38, 4096, 16, 1, 12288, 256000),
    }[arch]
    cfg = get_config(arch)
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab) == spec


def test_moe_extras():
    olmoe = get_config("olmoe_1b_7b")
    assert (olmoe.n_experts, olmoe.top_k, olmoe.d_expert) == (64, 8, 1024)
    q = get_config("qwen2_moe_a2_7b")
    assert (q.n_experts, q.top_k, q.n_shared_experts, q.d_expert) == (60, 4, 4, 1408)


def test_long500k_skip_policy():
    runnable = {a for a in ARCH_IDS if skip_reason(a, "long_500k") is None}
    assert runnable == {"xlstm_1_3b", "recurrentgemma_9b"}
    for a in ARCH_IDS:
        assert skip_reason(a, "train_4k") is None


def test_param_counts_roughly_match_names():
    """Sanity: configs land near their advertised total parameter counts."""
    expect = {
        "phi4_mini_3_8b": 3.8e9,
        "internlm2_20b": 20e9,
        "qwen1_5_32b": 32e9,
        "gemma_7b": 8.5e9,  # gemma counts embeddings once; ours ~8.5B with 256k vocab
        "olmoe_1b_7b": 7e9,
        "qwen2_vl_72b": 72e9,
        "recurrentgemma_9b": 9e9,
    }
    for arch, want in expect.items():
        got = get_config(arch).param_count()
        assert 0.5 * want < got < 1.8 * want, f"{arch}: {got:.2e} vs {want:.2e}"

"""Distributed Strassen + model sharding under multi-device host platform.

Device count is locked at jax init, so these run in SUBPROCESSES with
XLA_FLAGS=--xla_force_host_platform_device_count=N.
"""
import os
import subprocess
import sys
import textwrap


ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(n_devices: int, code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_bfs_sharded_and_2d_match_matmul():
    out = _run(8, """
        import functools, jax, jax.numpy as jnp, numpy as np
        from repro.core.compat import make_mesh
        from repro.core.distributed import strassen_bfs_sharded, strassen_2d
        rng = np.random.default_rng(0)
        a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
        mesh = make_mesh((4, 2), ("data", "model"))
        for fn, depth in ((strassen_bfs_sharded, 2), (strassen_2d, 1)):
            got = jax.jit(functools.partial(fn, mesh=mesh, depth=depth))(a, b)
            err = float(jnp.max(jnp.abs(got - a @ b)))
            assert err < 5e-4, (fn.__name__, err)
        print("OK")
    """)
    assert "OK" in out


def test_shardmap_level_single_allreduce():
    out = _run(7, """
        import functools, jax, jax.numpy as jnp, numpy as np
        from repro.core.compat import make_mesh
        from repro.core.distributed import strassen_shardmap
        rng = np.random.default_rng(1)
        a = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        b = jnp.asarray(rng.standard_normal((128, 128)), jnp.float32)
        mesh = make_mesh((7,), ("mult",))
        fn = jax.jit(functools.partial(strassen_shardmap, mesh=mesh))
        err = float(jnp.max(jnp.abs(fn(a, b) - a @ b)))
        assert err < 5e-4, err
        txt = fn.lower(a, b).compile().as_text()
        n_ar = txt.count(" all-reduce(") + txt.count(" all-reduce-start(")
        assert n_ar == 1, f"expected exactly 1 all-reduce, got {n_ar}"
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_runs_and_matches_single_device():
    """Numerical parity: mesh-sharded train step == single-device step."""
    out = _run(8, """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.launch.train import build
        from repro.optim.adamw import AdamWConfig
        from repro.launch.mesh import make_mesh_for

        cfg = get_smoke_config("phi4_mini_3_8b")
        opt = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=10)
        mesh = make_mesh_for(8, model_parallel=2)

        s1, data, f1 = build(cfg, opt, batch=8, seq=32, accum=1, mesh=None, seed=3)
        s2, _, f2 = build(cfg, opt, batch=8, seq=32, accum=1, mesh=mesh, seed=3)
        b = data(0)
        s1n, m1 = f1(s1, b)
        s2n, m2 = f2(s2, b)
        d = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - c.astype(jnp.float32)))), s1n.params, s2n.params)
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-3, worst
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        print("OK", worst)
    """)
    assert "OK" in out


def test_grad_accum_parity_under_mesh():
    out = _run(4, """
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.train import build
        from repro.optim.adamw import AdamWConfig
        from repro.launch.mesh import make_mesh_for
        cfg = get_smoke_config("gemma_7b")
        opt = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=10)
        mesh = make_mesh_for(4, model_parallel=2)
        s1, data, f1 = build(cfg, opt, batch=8, seq=16, accum=1, mesh=mesh, seed=5)
        s2, _, f4 = build(cfg, opt, batch=8, seq=16, accum=4, mesh=mesh, seed=5)
        b = data(0)
        s1n, _ = f1(s1, b)
        s2n, _ = f4(s2, b)
        d = jax.tree.map(lambda a, c: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - c.astype(jnp.float32)))), s1n.params, s2n.params)
        worst = max(jax.tree.leaves(d))
        assert worst < 5e-3, worst
        print("OK", worst)
    """)
    assert "OK" in out

"""Unified tracing & metrics layer: tracer semantics, histogram math,
Perfetto export schema, the rewired scheduler/serving telemetry, and the
disabled-mode overhead guard."""
import json
import time

import numpy as np
import pytest

from repro import obs
from repro.core.backend import MatmulBackend
from repro.obs import export as obs_export
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs_tracer


@pytest.fixture
def tracer():
    """A private enabled tracer (no global state)."""
    return obs_tracer.Tracer(enabled=True)


@pytest.fixture
def global_tracing():
    """Enable the global tracer for the test, restore disabled after."""
    obs.reset_tracing()
    obs.configure(enabled=True)
    yield obs.get_tracer()
    obs.configure(enabled=False)
    obs.reset_tracing()


# -- tracer core -----------------------------------------------------------


def test_span_nesting_and_parents(tracer):
    with tracer.span("outer") as outer:
        with tracer.span("mid", tag="012") as mid:
            with tracer.span("inner") as inner:
                assert tracer.current() is inner
            assert tracer.current() is mid
    assert tracer.current() is None
    spans = {sp.name: sp for sp in tracer.snapshot()}
    assert spans["inner"].parent_id == spans["mid"].span_id
    assert spans["mid"].parent_id == spans["outer"].span_id
    assert spans["outer"].parent_id is None
    assert spans["mid"].tag == "012"
    assert all(sp.t1 >= sp.t0 for sp in spans.values())


def test_end_tolerates_exception_unwinding(tracer):
    outer = tracer.begin("outer")
    tracer.begin("orphan")  # left open, as if an exception skipped its end
    tracer.end(outer)
    assert tracer.current() is None
    names = [sp.name for sp in tracer.snapshot()]
    assert names == ["outer"]  # the orphan was popped, not retained


def test_add_span_and_event_record_explicit_times(tracer):
    t = time.perf_counter()
    parent = tracer.begin("root")
    tracer.add_span("phase", t, t + 0.25, track="lane", parent=parent)
    tracer.event("mark")
    tracer.end(parent)
    phase = tracer.find("phase")[0]
    assert phase.duration == pytest.approx(0.25)
    assert phase.parent_id == parent.span_id
    mark = tracer.find("mark")[0]
    assert mark.cat == "instant" and mark.duration == 0.0
    assert mark.parent_id == parent.span_id


def test_disabled_mode_is_null_and_records_nothing():
    tr = obs_tracer.Tracer(enabled=False)
    s1 = tr.span("a")
    s2 = tr.span("b", tag="0", x=1)
    # zero-allocation fast path: one shared singleton, identity-equal
    assert s1 is obs_tracer.NULL_SPAN and s2 is obs_tracer.NULL_SPAN
    with tr.span("c"):
        pass
    assert tr.add_span("d", 0.0, 1.0) is None
    assert tr.event("e") is None
    # begin/end still hand back a timed span for callers that need the
    # duration (straggler watchdog), but retain nothing
    sp = tr.begin("f")
    tr.end(sp)
    assert sp.duration >= 0.0 and sp.t1 is not None
    assert tr.snapshot() == []


def test_max_spans_drops_and_counts(tracer):
    tracer.max_spans = 3
    for i in range(5):
        with tracer.span(f"s{i}"):
            pass
    assert len(tracer.snapshot()) == 3
    assert tracer.dropped == 2


def test_configure_is_identity_stable():
    tr = obs_tracer.get_tracer()
    assert obs_tracer.configure(enabled=True) is tr
    try:
        assert tr.enabled
    finally:
        obs_tracer.configure(enabled=False)


# -- histogram math --------------------------------------------------------


def test_histogram_boundary_value_lands_in_bounding_bucket():
    h = obs_metrics.Histogram("t", bounds=(1.0, 2.0, 4.0))
    h.record(2.0)  # exactly on a bound -> the bucket it bounds (le)
    h.record(1.0)
    h.record(4.0)
    h.record(5.0)  # overflow bucket
    snap = h.snapshot()
    by_le = {b["le"]: b["count"] for b in snap["buckets"]}
    assert by_le[1.0] == 1
    assert by_le[2.0] == 1
    assert by_le[4.0] == 1
    assert by_le["inf"] == 1
    assert snap["count"] == 4 and snap["min"] == 1.0 and snap["max"] == 5.0


def test_histogram_percentile_matches_numpy_exactly():
    rng = np.random.default_rng(7)
    xs = rng.exponential(0.05, size=257)
    h = obs_metrics.Histogram("t")
    for x in xs:
        h.record(float(x))
    for q in (0, 25, 50, 90, 99, 100):
        assert h.percentile(q) == float(np.percentile(xs, q))
    assert h.snapshot()["exact"] is True


def test_histogram_overflow_degrades_to_bucket_interpolation():
    h = obs_metrics.Histogram("t", bounds=(1.0, 2.0), max_samples=4)
    for v in (0.5, 0.6, 1.5, 1.6, 1.7, 1.8):
        h.record(v)
    snap = h.snapshot()
    assert snap["exact"] is False
    p50 = h.percentile(50)
    assert 0.5 <= p50 <= 2.0  # interpolated inside the matched bucket
    assert h.percentile(0) <= h.percentile(50) <= h.percentile(100)


def test_histogram_empty_and_reset():
    h = obs_metrics.Histogram("t")
    assert h.percentile(50) is None
    h.record(1.0)
    h.reset()
    assert h.count == 0 and h.percentile(50) is None


def test_metrics_registry_snapshot_is_jsonable():
    m = obs_metrics.Metrics()
    m.counter("c").inc(3)
    m.gauge("g").set(2.0)
    m.gauge("g").set(1.0)
    m.histogram("h").record(0.1)
    snap = m.snapshot()
    json.dumps(snap)  # must be plain data
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == {"value": 1.0, "max": 2.0}
    assert snap["histograms"]["h"]["count"] == 1
    assert m.counter("c") is m.counter("c")


# -- Perfetto export -------------------------------------------------------


def test_chrome_trace_schema(tracer, tmp_path):
    with tracer.span("outer", cat="oot"):
        with tracer.span("leaf", tag="03", track="oot.stage"):
            pass
    path = str(tmp_path / "trace.json")
    obs_export.write_trace(path, tracer)
    assert obs_export.validate_trace(path) == []
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"]
    xs = [e for e in events if e["ph"] == "X"]
    ms = [e for e in events if e["ph"] == "M"]
    assert xs and ms
    for e in xs:
        assert isinstance(e["ts"], (int, float)) and e["ts"] >= 0
        assert isinstance(e["dur"], (int, float)) and e["dur"] >= 0
        assert e["pid"] == obs_export.PID and isinstance(e["tid"], int)
    # the tag is folded into the event name (recursion-tree flame view)
    assert any(e["name"] == "leaf [03]" for e in xs)
    assert any(e.get("args", {}).get("tag") == "03" for e in xs)
    # named tracks get their own labeled lane
    lanes = {e["args"]["name"]: e["tid"] for e in ms}
    assert "oot.stage" in lanes
    leaf_ev = next(e for e in xs if e["name"] == "leaf [03]")
    outer_ev = next(e for e in xs if e["name"] == "outer")
    assert leaf_ev["tid"] == lanes["oot.stage"] != outer_ev["tid"]


def test_validate_trace_flags_malformed():
    assert obs_export.validate_trace({"traceEvents": []}) == ["empty traceEvents"]
    errs = obs_export.validate_trace(
        {"traceEvents": [{"ph": "X", "name": "x", "pid": 1, "tid": 1, "ts": 0}]}
    )
    assert any("X without 'dur'" in e for e in errs)
    errs = obs_export.validate_trace({"traceEvents": [{"ph": "?", "name": "x"}]})
    assert any("unknown ph" in e for e in errs)
    assert obs_export.validate_trace({}) == ["no traceEvents array"]


def test_export_cli_roundtrip(tracer, tmp_path):
    with tracer.span("a"):
        pass
    good = str(tmp_path / "good.json")
    bad = str(tmp_path / "bad.json")
    obs_export.write_trace(good, tracer)
    with open(bad, "w") as f:
        json.dump({"traceEvents": [{"ph": "X"}]}, f)
    assert obs_export.main([good]) == 0
    assert obs_export.main([good, bad]) == 1


def test_write_jsonl(tracer, tmp_path):
    with tracer.span("a", tag="1"):
        pass
    path = str(tmp_path / "spans.jsonl")
    obs_export.write_jsonl(path, tracer)
    rows = [json.loads(line) for line in open(path)]
    assert rows[0]["name"] == "a" and rows[0]["tag"] == "1"
    assert rows[0]["dur"] >= 0.0


# -- scheduler rewire: recursion-tree spans + derived OotStats -------------


def _oot_traced_run():
    from repro.blocks.scheduler import pipelined_leaf_bytes, strassen_oot_matmul

    rng = np.random.default_rng(0)
    a = rng.standard_normal((192, 192)).astype(np.float32)
    b = rng.standard_normal((192, 192)).astype(np.float32)
    budget = pipelined_leaf_bytes(192, 192, 192, 2, a.dtype)  # one slot
    out, stats = strassen_oot_matmul(
        a, b, depth=2, budget_bytes=budget, backend=MatmulBackend(kind="naive")
    )
    return out, stats


def test_scheduler_spans_cover_recursion_tree(global_tracing):
    from repro.blocks import tags

    tr = global_tracing
    _, stats = _oot_traced_run()
    root = tr.find("oot.matmul")
    assert len(root) == 1 and root[0].attrs["depth"] == 2
    # every leaf carries its base-7 tag
    mul_tags = {sp.tag for sp in tr.find("leaf.mul")}
    want = {tags.to_string(p) for p in tags.leaf_paths(2)}
    assert mul_tags == want
    # wave phases exist per wave, on their named lanes
    for name, lane in (
        ("wave.stage", "oot.stage"),
        ("wave.dispatch", "oot.dispatch"),
        ("wave.fetch", "oot.fetch"),
    ):
        spans = tr.find(name)
        assert len(spans) == stats.waves
        assert all(sp.track == lane for sp in spans)
    # async interleave: wave k+1's staging begins while wave k is still
    # in flight (before wave k's fetch ends) — the 2-deep pipeline
    stage = sorted(tr.find("wave.stage"), key=lambda s: s.attrs["wave"])
    fetch = sorted(tr.find("wave.fetch"), key=lambda s: s.attrs["wave"])
    assert stats.waves >= 2
    overlapped = sum(
        1
        for k in range(stats.waves - 1)
        if stage[k + 1].t0 < fetch[k].t1
    )
    assert overlapped == stats.waves - 1
    # in-flight compute windows: stage(k+1) sits inside compute(k)
    compute = sorted(tr.find("wave.compute"), key=lambda s: s.attrs["wave"])
    assert len(compute) == stats.waves
    for k in range(stats.waves - 1):
        assert compute[k].t0 <= stage[k + 1].t0 <= compute[k].t1


def test_oot_stats_derived_from_spans(global_tracing):
    tr = global_tracing
    _, stats = _oot_traced_run()
    root = tr.find("oot.matmul")[0]
    assert stats.total_s == pytest.approx(root.duration)
    assert stats.divide_s == pytest.approx(tr.find("oot.divide")[0].duration)
    assert stats.leaf_s == pytest.approx(tr.find("oot.leaf_waves")[0].duration)
    assert stats.stage_s == pytest.approx(
        sum(sp.duration for sp in tr.find("wave.stage"))
    )
    assert stats.fetch_s == pytest.approx(
        sum(sp.duration for sp in tr.find("wave.fetch"))
    )
    assert root.attrs["overlap_efficiency"] == stats.overlap_efficiency


def test_overlap_efficiency_parity_with_wave_events():
    """finalize_overlap's inputs are now span-derived; re-deriving the
    formula from the published wave_events must reproduce the stat."""
    _, stats = _oot_traced_run()
    ev = stats.wave_events
    assert len(ev) == stats.waves
    assert [e["wave"] for e in ev] == list(range(stats.waves))
    total = sum(
        (e["issue_end"] - e["issue_start"]) + (e["fetch_end"] - e["fetch_start"])
        for e in ev
    )
    exposed = (ev[0]["issue_end"] - ev[0]["issue_start"]) + (
        ev[-1]["fetch_end"] - ev[-1]["fetch_start"]
    )
    want = max(0.0, min(1.0, 1.0 - exposed / total))
    assert stats.overlap_efficiency == pytest.approx(want)
    assert 0.0 < stats.overlap_efficiency <= 1.0
    # phases are ordered within each wave
    for e in ev:
        assert e["issue_start"] <= e["issue_end"] <= e["dispatch_end"]
        assert e["dispatch_end"] <= e["fetch_end"] and e["fetch_start"] <= e["fetch_end"]


def test_oot_stats_ring_isolation():
    from repro.blocks.scheduler import (
        attach_stats_ring,
        recent_oot_stats,
        reset_oot_stats,
    )

    reset_oot_stats()
    mine = attach_stats_ring(maxlen=8)
    other = attach_stats_ring(maxlen=8)
    _, stats = _oot_traced_run()
    assert len(mine) == 1 and len(other) == 1
    assert recent_oot_stats()[-1]["waves"] == stats.waves
    # clearing the default ring must not clobber attached rings...
    reset_oot_stats()
    assert recent_oot_stats() == []
    assert len(mine) == 1
    # ...and clearing one attached ring leaves the others alone
    other.clear()
    assert len(other) == 0 and len(mine) == 1
    assert mine.snapshot()[-1]["overlap_efficiency"] == stats.overlap_efficiency


def test_oot_ring_is_bounded():
    from repro.blocks.scheduler import OotStatsRing

    ring = OotStatsRing(maxlen=3)
    for i in range(5):
        ring.append({"i": i})
    assert [d["i"] for d in ring.snapshot()] == [2, 3, 4]


# -- serving histograms ----------------------------------------------------


def _serve_run():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import model as M
    from repro.serving.engine import Engine, ServeConfig

    cfg = get_smoke_config("phi4_mini_3_8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    engine = Engine(
        cfg,
        params,
        ServeConfig(max_seq=64, temperature=0.0, slots=2, page_size=8,
                    sync_interval=2),
    )
    rng = np.random.default_rng(3)
    handles = [
        engine.submit(rng.integers(0, cfg.vocab, size=4 + 2 * i), 4 + i)
        for i in range(4)
    ]
    for _ in engine.stream(handles):
        pass
    return engine, handles


def test_engine_histograms_match_latency_stats(global_tracing):
    engine, handles = _serve_run()
    ttfts, tpots = [], []
    for h in handles:
        ttft, gaps = h.latency_stats()
        if ttft is not None:
            ttfts.append(ttft)
        if gaps:
            tpots.append(float(np.mean(gaps)))
    for name, xs in (("serve.ttft_s", ttfts), ("serve.tpot_s", tpots)):
        hist = engine.metrics.histogram(name)
        assert hist.count == len(xs)
        for q in (50, 99):
            assert hist.percentile(q) == pytest.approx(
                float(np.percentile(xs, q)), abs=1e-12
            )
    snap = engine.stats()
    assert set(snap) == {"serve", "autotune", "obs"}
    assert snap["obs"]["metrics"]["counters"]["serve.requests_length"] >= 1
    assert snap["obs"]["tracer"]["enabled"] is True
    # request lifecycle spans landed on per-request lanes with tags
    tr = global_tracing
    decs = tr.find("request.decoding")
    assert len(decs) == len(handles)
    assert {sp.tag for sp in decs} == {f"req{h.id}" for h in handles}
    qs = {sp.tag: sp for sp in tr.find("request.queued")}
    prefills = {sp.tag: sp for sp in tr.find("request.prefill")}
    for sp in decs:  # queued -> prefill -> decoding, back to back
        assert qs[sp.tag].t1 == prefills[sp.tag].t0
        assert prefills[sp.tag].t1 == sp.t0


def test_engine_metrics_are_per_engine():
    e1, _ = _serve_run()
    e2, _ = _serve_run()
    assert e1.metrics is not e2.metrics
    assert e1.metrics.histogram("serve.ttft_s").count > 0
    e2.metrics.reset()
    assert e1.metrics.histogram("serve.ttft_s").count > 0


# -- disabled-mode overhead guard ------------------------------------------


def test_disabled_tracer_overhead_under_5pct():
    """Tier-1 guard: instrumenting a tight matmul loop with a disabled
    tracer costs < 5% wall clock (NULL_SPAN fast path).

    Measured as per-call costs (min over repeats) rather than one
    loop-vs-loop race: BLAS run-to-run jitter on a shared CI host dwarfs
    the sub-microsecond disabled path and makes the naive comparison
    flaky in both directions.
    """
    tr = obs_tracer.Tracer(enabled=False)
    rng = np.random.default_rng(0)
    a = rng.standard_normal((128, 128)).astype(np.float32)
    b = rng.standard_normal((128, 128)).astype(np.float32)

    def span_cost(iters=20_000):
        t0 = time.perf_counter()
        for _ in range(iters):
            with tr.span("mm", m=128, k=128, n=128):
                pass
        return (time.perf_counter() - t0) / iters

    def dot_cost(iters=50):
        t0 = time.perf_counter()
        for _ in range(iters):
            np.dot(a, b)
        return (time.perf_counter() - t0) / iters

    span_cost(1000)
    dot_cost(5)  # warmup
    per_span = min(span_cost() for _ in range(3))
    per_dot = min(dot_cost() for _ in range(3))
    assert per_span <= 0.05 * per_dot, (
        f"disabled span() {per_span * 1e9:.0f} ns per call vs "
        f"{per_dot * 1e6:.1f} us matmul body ({per_span / per_dot:.1%})"
    )
    assert tr.snapshot() == []  # and it recorded nothing

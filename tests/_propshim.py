"""Tiny vendored fallback for ``hypothesis`` (given/settings/strategies).

When the real dependency is installed (see requirements-dev.txt) the test
modules import it and this file is inert. When it is absent, this shim runs
each property test over a *seeded fixed-example grid*: boundary values
first, then deterministic pseudo-random draws — same seed every run, so
failures reproduce. No shrinking, no database, no adaptive search; just
enough surface for the four property-test modules to collect and run.
"""
from __future__ import annotations

import functools
import inspect
import random
import types
import zlib

__all__ = ["given", "settings", "strategies", "HealthCheck"]

_DEFAULT_EXAMPLES = 10


class _Strategy:
    """A deterministic example stream: boundaries first, then seeded draws."""

    def __init__(self, boundary, draw):
        self._boundary = list(boundary)  # always-tried examples
        self._draw = draw  # rng -> value

    def examples(self, count: int, seed: int):
        rng = random.Random(seed)
        out = list(self._boundary[:count])
        while len(out) < count:
            out.append(self._draw(rng))
        return out


def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> _Strategy:
    bounds = [min_value, max_value] if min_value != max_value else [min_value]
    return _Strategy(bounds, lambda rng: rng.randint(min_value, max_value))


def sampled_from(options) -> _Strategy:
    opts = list(options)
    return _Strategy(opts, lambda rng: opts[rng.randrange(len(opts))])


def booleans() -> _Strategy:
    return _Strategy([False, True], lambda rng: bool(rng.getrandbits(1)))


def floats(min_value: float = 0.0, max_value: float = 1.0, **_ignored) -> _Strategy:
    bounds = [min_value, max_value]
    return _Strategy(bounds, lambda rng: rng.uniform(min_value, max_value))


strategies = types.SimpleNamespace(
    integers=integers,
    sampled_from=sampled_from,
    booleans=booleans,
    floats=floats,
)


def _stable_seed(name: str) -> int:
    # hash() is salted per-process; crc32 keeps the grid identical across runs
    return zlib.crc32(name.encode())


def given(**strats):
    def deco(fn):
        state = {"max_examples": _DEFAULT_EXAMPLES}

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            count = state["max_examples"]
            base = _stable_seed(fn.__name__)
            grids = {
                name: s.examples(count, base ^ _stable_seed(name))
                for name, s in strats.items()
            }
            for i in range(count):
                drawn = {name: grids[name][i] for name in strats}
                fn(*args, **drawn, **kwargs)

        # pytest must not see the drawn params as fixtures: drop the
        # __wrapped__ link and present only the non-strategy parameters.
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        keep = [p for name, p in sig.parameters.items() if name not in strats]
        wrapper.__signature__ = sig.replace(parameters=keep)
        wrapper._shim_state = state
        return wrapper

    return deco


def settings(max_examples: int = _DEFAULT_EXAMPLES, deadline=None, **_ignored):
    """Apply example-count to a @given-wrapped test; other knobs are no-ops."""

    def deco(fn):
        st = getattr(fn, "_shim_state", None)
        if st is not None:
            st["max_examples"] = max_examples
        return fn

    return deco


class HealthCheck:
    """Placeholder so ``suppress_health_check=[...]`` kwargs don't crash."""

    too_slow = data_too_large = filter_too_much = None
    all = classmethod(lambda cls: [])

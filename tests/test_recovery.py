"""Lineage-based fault tolerance: chaos injection, recovery, degradation.

Covers the robustness layer end to end: the deterministic chaos harness
(ChaosStore / FlakyLeaf), bit-exact lineage recompute through
RecoveringStore, the scheduler's leaf retry + degradation ladder, chaos
cleanup across store backends, checkpoint digest verification, the
straggler stop path, and per-request fault isolation in the serving
engine.
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.blocks import tags
from repro.blocks.blockmatrix import ArenaStore, DictStore, MemmapStore
from repro.blocks.recovery import (
    BlockLossError,
    ChaosConfig,
    ChaosStore,
    FlakyLeaf,
    InjectedFault,
    Lineage,
    RecoveringStore,
    block_checksum,
)
from repro.blocks.scheduler import (
    StrassenScheduler,
    leaf_bytes,
    strassen_oot_matmul,
)
from repro.core import autotune
from repro.core.autotune import Calibration
from repro.core.backend import MatmulBackend, resolve_auto
from repro.core.coefficients import get_scheme
from repro.obs import metrics as obs_metrics
from repro.runtime.checkpoint import CheckpointError, load_pytree, save_pytree
from repro.runtime.elastic import StragglerMonitor

RNG = np.random.default_rng(11)

CALIB = Calibration(
    t_flop=1e-11, t_elem=1e-9, t_coll=4e-9, t_h2d=2e-9,
    device_kind="test", device_count=1,
)

# Pin the leaves to the naive matmul so no calibration micro-bench runs.
NAIVE_LEAVES = MatmulBackend(kind="naive")


@pytest.fixture(autouse=True)
def _synthetic_calibration(monkeypatch):
    monkeypatch.setattr(autotune, "_CALIBRATION", CALIB)
    monkeypatch.setattr(autotune, "_PROCESS_CACHES", {})
    resolve_auto.cache_clear()
    # fault.* / elastic.* counter assertions below are per-test deltas
    obs_metrics.reset_metrics()


def _rand(shape, dtype=np.float32):
    return RNG.standard_normal(shape).astype(dtype)


def _rel_err(got, want):
    got = np.asarray(got, dtype=np.float32)
    want = np.asarray(want, dtype=np.float32)
    return float(np.abs(got - want).max() / (np.abs(want).max() or 1.0))


def _counters():
    return obs_metrics.get_metrics().snapshot()["counters"]


# ------------------------------------------------------- injection harness
def test_chaos_config_validation_and_flags():
    with pytest.raises(ValueError, match="drop"):
        ChaosConfig(drop=1.5)
    with pytest.raises(ValueError, match="corrupt"):
        ChaosConfig(corrupt=-0.1)
    quiet = ChaosConfig()
    assert not quiet.injects_store_faults and not quiet.injects_leaf_faults
    assert ChaosConfig(drop=0.1).injects_store_faults
    assert ChaosConfig(corrupt=0.1).injects_store_faults
    assert ChaosConfig(leaf_fail_rate=0.1).injects_leaf_faults
    assert ChaosConfig(fail_leaf_calls=(3,)).injects_leaf_faults


def test_block_checksum_is_content_addressed():
    blk = _rand((16, 16))
    ref = block_checksum(blk)
    assert block_checksum(blk.copy()) == ref
    assert block_checksum(np.asfortranarray(blk)) == ref  # layout-agnostic
    bad = blk.copy()
    bad.view(np.uint8).reshape(-1)[5] ^= 0x01  # single bit
    assert block_checksum(bad) != ref


def test_chaos_store_deterministic_fault_schedule():
    def run(seed):
        rng = np.random.default_rng(0)
        inner = DictStore()
        keys = [(0, i, "A:0") for i in range(8)]
        for k in keys:
            inner.put(k, rng.standard_normal((4, 4)).astype(np.float32))
        chaos = ChaosStore(inner, drop=0.25, corrupt=0.25, seed=seed)
        schedule = []
        for t in range(60):
            k = keys[t % 8]
            try:
                chaos.get(k)
            except KeyError:  # dropped: the reader would recompute; re-seed
                inner.put(k, rng.standard_normal((4, 4)).astype(np.float32))
            schedule.append((chaos.injected_drops, chaos.injected_corruptions))
        return schedule

    base = run(0)
    assert base == run(0)  # same seed -> identical fault schedule
    assert base != run(3)  # schedule is seed-addressed, not incidental
    drops, corruptions = base[-1]
    assert drops > 0 and corruptions > 0


def test_chaos_store_injection_counts_match_obs_counters():
    inner = DictStore()
    key = (0, 0, "A:0")
    inner.put(key, np.zeros((4, 4), np.float32))
    chaos = ChaosStore(inner, corrupt=1.0, seed=0)
    got = np.asarray(chaos.get(key))
    assert chaos.injected_corruptions == 1
    assert got.view(np.uint8).reshape(-1).max() > 0  # exactly one byte flipped
    # a drop deletes the stored block: the reader sees a plain KeyError
    chaos2 = ChaosStore(inner, drop=1.0, seed=0)
    with pytest.raises(KeyError):
        chaos2.get(key)
    assert chaos2.injected_drops == 1 and key not in inner
    snap = _counters()
    assert snap["fault.injected_corruptions"] == 1.0
    assert snap["fault.injected_drops"] == 1.0


def test_flaky_leaf_fail_calls_and_seeded_rate():
    leaf = FlakyLeaf(fail_calls=(0, 2))
    with pytest.raises(InjectedFault):
        leaf.check()
    leaf.check()
    with pytest.raises(InjectedFault):
        leaf.check()
    leaf.check()
    assert leaf.calls == 4 and leaf.injected == 2
    assert _counters()["fault.injected_leaf_failures"] == 2.0

    def pattern(seed):
        fl = FlakyLeaf(fail_rate=0.3, seed=seed)
        out = []
        for _ in range(40):
            try:
                fl.check()
                out.append(False)
            except InjectedFault:
                out.append(True)
        return out

    assert pattern(1) == pattern(1)
    assert any(pattern(1)) and not all(pattern(1))
    assert pattern(1) != pattern(2)


# ------------------------------------------------ lineage recompute/healing
def _root_lineage(a, b, bam=4, bak=4, bbn=4):
    return Lineage(
        scheme=get_scheme("strassen"), depth=1, a=a, b=b,
        pm=a.shape[0], pk=a.shape[1], pn=b.shape[1],
        bam=bam, bak=bak, bbn=bbn,
        acc_dtype=np.dtype(np.float32), stage_dtype=np.dtype(np.float32),
        leaf_matmul=lambda x, y: x @ y,
    )


def test_recovering_store_heals_lost_and_corrupt_blocks_bit_identically():
    a, b = _rand((8, 8)), _rand((8, 8))
    inner = DictStore()
    store = RecoveringStore(inner, _root_lineage(a, b))
    tag = "A:" + tags.to_string(())
    blocks = {}
    for i in range(2):
        for j in range(2):
            blk = np.ascontiguousarray(a[i * 4:(i + 1) * 4, j * 4:(j + 1) * 4])
            blocks[(i, j, tag)] = blk
            store.put((i, j, tag), blk)

    # loss: the inner store forgets a block; the read heals it in place
    inner.delete((0, 1, tag))
    healed = store.get((0, 1, tag))
    np.testing.assert_array_equal(np.asarray(healed), blocks[(0, 1, tag)])
    assert store.lost_blocks == 1 and store.recovered_blocks == 1
    assert (0, 1, tag) in inner  # re-put so later reads are clean

    # corruption: flip a stored byte; the checksum catches what the store
    # API cannot, and the recompute reproduces the put-time crc exactly
    bad = np.array(inner.get((1, 0, tag)))
    bad.view(np.uint8).reshape(-1)[0] ^= 0xFF
    inner.put((1, 0, tag), bad)
    healed = store.get((1, 0, tag))
    np.testing.assert_array_equal(np.asarray(healed), blocks[(1, 0, tag)])
    assert store.corrupt_blocks == 1 and store.recovered_blocks == 2
    assert store.recompute_mismatches == 0
    snap = _counters()
    assert snap["fault.lost_blocks"] == 1.0
    assert snap["fault.corrupt_blocks"] == 1.0
    assert snap["fault.recomputed_blocks"] == 2.0


def test_recovering_store_unrecoverable_paths_are_loud():
    # no lineage attached: a lost block is a hard error, counted
    bare = RecoveringStore(DictStore())
    bare.put((0, 0, "A:"), np.ones((2, 2), np.float32))
    bare.inner.delete((0, 0, "A:"))
    with pytest.raises(BlockLossError, match="no lineage"):
        bare.get((0, 0, "A:"))
    # lineage attached but the tag is not a lineage-addressable node
    a, b = _rand((8, 8)), _rand((8, 8))
    store = RecoveringStore(DictStore(), _root_lineage(a, b))
    store.put((0, 0, "X:junk"), np.ones((2, 2), np.float32))
    store.inner.delete((0, 0, "X:junk"))
    with pytest.raises(BlockLossError):
        store.get((0, 0, "X:junk"))
    assert _counters()["fault.unrecoverable"] == 2.0


@pytest.mark.parametrize("store_kind", ["dict", "memmap"])
def test_chaos_run_output_bit_identical_to_fault_free_run(store_kind):
    """Seeded drops + corruptions across the whole recursion tree (root
    re-ingest, deeper divides, leaf products, combine partials) must heal
    to the byte: the put-time crc re-verification (recompute_mismatches)
    proves each healed block, and the final output proves the run."""
    a, b = _rand((64, 64)), _rand((64, 64))
    budget = 4 * leaf_bytes(64, 64, 64, 2, a.dtype)
    clean, _ = strassen_oot_matmul(
        a, b, depth=2, budget_bytes=budget, backend=NAIVE_LEAVES
    )
    out, stats = strassen_oot_matmul(
        a, b, depth=2, budget_bytes=budget, backend=NAIVE_LEAVES,
        store=store_kind, chaos=ChaosConfig(drop=0.06, corrupt=0.04, seed=0),
    )
    assert np.array_equal(np.asarray(out), np.asarray(clean))
    assert stats.recovered_blocks > 0
    assert stats.recovered_blocks == stats.lost_blocks + stats.corrupt_blocks
    assert stats.unrecovered_faults == 0
    # injection happens below the recovery layer, so nested re-injections
    # during a recompute can exceed the detected count but never trail it
    assert stats.injected_faults >= stats.recovered_blocks
    assert stats.degrades == 0
    stats.assert_within_budget()


# --------------------------------------------------- retry + degradation
def test_transient_leaf_fault_is_retried_in_place():
    a, b = _rand((64, 64)), _rand((64, 64))
    budget = 4 * leaf_bytes(64, 64, 64, 1, a.dtype)
    clean, _ = strassen_oot_matmul(
        a, b, depth=1, budget_bytes=budget, backend=NAIVE_LEAVES
    )
    out, stats = strassen_oot_matmul(
        a, b, depth=1, budget_bytes=budget, backend=NAIVE_LEAVES,
        chaos=ChaosConfig(fail_leaf_calls=(1,)), retries=2, retry_backoff_s=0.0,
    )
    assert np.array_equal(np.asarray(out), np.asarray(clean))
    assert stats.leaf_retries >= 1
    assert stats.injected_faults == 1
    assert stats.degrades == 0  # absorbed by the retry, not the ladder
    assert _counters()["fault.retries"] >= 1.0


def test_exhausted_retries_walk_the_degradation_ladder():
    a, b = _rand((64, 64)), _rand((64, 64))
    budget = 4 * leaf_bytes(64, 64, 64, 1, a.dtype)
    clean, clean_stats = strassen_oot_matmul(
        a, b, depth=1, budget_bytes=budget, backend=NAIVE_LEAVES
    )
    assert clean_stats.rung == "pipeline"  # precondition: rung 0 is async
    out, stats = strassen_oot_matmul(
        a, b, depth=1, budget_bytes=budget, backend=NAIVE_LEAVES,
        chaos=ChaosConfig(fail_leaf_calls=(0,)), retries=0,
    )
    # sync rung is bit-identical to the pipeline (existing invariant), so
    # a degraded run still reproduces the fault-free bytes
    assert np.array_equal(np.asarray(out), np.asarray(clean))
    assert stats.rung == "sync" and stats.degrades == 1
    (ev,) = stats.degrade_events
    assert ev["from"] == "pipeline" and ev["to"] == "sync"
    assert "InjectedFault" in ev["cause"]
    assert _counters()["fault.degrade"] == 1.0


def test_ladder_degrades_on_oom_and_propagates_unknown_errors(monkeypatch):
    a, b = _rand((64, 64)), _rand((64, 64))
    budget = 4 * leaf_bytes(64, 64, 64, 1, a.dtype)
    real = StrassenScheduler._attempt
    calls = {"n": 0}

    def oom_once(self, *args, **kwargs):
        calls["n"] += 1
        if calls["n"] == 1:
            raise MemoryError("simulated allocator exhaustion")
        return real(self, *args, **kwargs)

    monkeypatch.setattr(StrassenScheduler, "_attempt", oom_once)
    out, stats = strassen_oot_matmul(
        a, b, depth=1, budget_bytes=budget, backend=NAIVE_LEAVES
    )
    assert _rel_err(out, a @ b) < 2e-3
    assert stats.rung == "sync" and stats.degrades == 1
    assert "MemoryError" in stats.degrade_events[0]["cause"]

    # anything that is not a fault/OOM is a bug: one attempt, no ladder
    boom_calls = {"n": 0}

    def always_boom(self, *args, **kwargs):
        boom_calls["n"] += 1
        raise RuntimeError("not a fault, a bug")

    monkeypatch.setattr(StrassenScheduler, "_attempt", always_boom)
    with pytest.raises(RuntimeError, match="not a fault"):
        strassen_oot_matmul(
            a, b, depth=1, budget_bytes=budget, backend=NAIVE_LEAVES
        )
    assert boom_calls["n"] == 1


@pytest.mark.parametrize("store_kind", ["dict", "arena", "memmap"])
def test_unrecovered_chaos_fault_cleans_stores_and_device_buffers(
    store_kind, tmp_path
):
    """An injected fault that exhausts the policy (retries=0, degrade off)
    must fail as cleanly as any other error: no device-buffer leak, every
    run-created block dropped from the caller's store, foreign runs'
    blocks — same "A:"/"B:"/"C:" tag space — untouched."""
    a, b = _rand((96, 96)), _rand((96, 96))
    if store_kind == "dict":
        store = DictStore()
    elif store_kind == "memmap":
        store = MemmapStore(str(tmp_path / "spill"))
    else:
        store = ArenaStore(slot_bytes=64 * 1024, capacity=64)
    keep = np.ones((2, 2), np.float32)
    store.put((0, 0, "keep"), keep)
    foreign = np.full((2, 2), 7.0, np.float32)
    store.put((99, 99, "A:0"), foreign)
    baseline = sum(not x.is_deleted() for x in jax.live_arrays())
    with pytest.raises(InjectedFault):
        strassen_oot_matmul(
            a, b, depth=2,
            budget_bytes=4 * leaf_bytes(96, 96, 96, 2, a.dtype),
            backend=NAIVE_LEAVES, store=store,
            chaos=ChaosConfig(fail_leaf_calls=(4,)), retries=0, degrade=False,
        )
    assert sum(not x.is_deleted() for x in jax.live_arrays()) <= baseline
    leftover = [kk for kk in store.keys() if kk[2][:2] in ("A:", "B:", "C:")]
    assert leftover == [(99, 99, "A:0")]
    np.testing.assert_array_equal(np.asarray(store.get((0, 0, "keep"))), keep)
    np.testing.assert_array_equal(np.asarray(store.get((99, 99, "A:0"))), foreign)
    if store_kind == "memmap":
        assert len(os.listdir(store.root)) == 2  # only the unrelated keys
    store.close()


# ------------------------------------------------- checkpoint verification
def test_checkpoint_digest_mismatch_raises(tmp_path):
    tree = {"w": jnp.arange(6.0), "b": jnp.ones((2, 2))}
    path = save_pytree(tree, str(tmp_path), step=1)
    npz = os.path.join(path, "arrays.npz")
    with open(npz, "rb") as f:
        raw = bytearray(f.read())
    raw[-1] ^= 0xFF
    with open(npz, "wb") as f:
        f.write(bytes(raw))
    with pytest.raises(CheckpointError, match="digest mismatch"):
        load_pytree(tree, path)


def test_checkpoint_partial_and_torn_writes_raise(tmp_path):
    tree = {"w": jnp.ones(3)}
    path = save_pytree(tree, str(tmp_path), step=1)
    os.remove(os.path.join(path, "arrays.npz"))
    with pytest.raises(CheckpointError, match="missing arrays"):
        load_pytree(tree, path)

    path2 = save_pytree(tree, str(tmp_path), step=2)
    with open(os.path.join(path2, "manifest.json"), "w") as f:
        f.write("{")  # torn mid-write
    with pytest.raises(CheckpointError, match="torn manifest"):
        load_pytree(tree, path2)

    path3 = save_pytree(tree, str(tmp_path), step=3)
    with open(os.path.join(path3, "manifest.json"), "w") as f:
        json.dump({"complete": False}, f)
    with pytest.raises(CheckpointError, match="not marked complete"):
        load_pytree(tree, path3)

    path4 = save_pytree(tree, str(tmp_path), step=4)
    os.remove(os.path.join(path4, "manifest.json"))
    with pytest.raises(CheckpointError, match="missing manifest"):
        load_pytree(tree, path4)


def test_checkpoint_save_is_atomic_on_failure(tmp_path, monkeypatch):
    """A save that dies mid-write must leave neither a step dir nor a tmp
    dir behind — the atomic-replace contract load verification rests on."""
    tree = {"w": jnp.ones(3)}

    def boom(*args, **kwargs):
        raise OSError("disk full")

    monkeypatch.setattr(np, "savez", boom)
    with pytest.raises(OSError, match="disk full"):
        save_pytree(tree, str(tmp_path), step=1)
    assert os.listdir(tmp_path) == []


def test_checkpoint_missing_key_and_digestless_back_compat(tmp_path):
    tree = {"w": jnp.arange(4.0), "b": jnp.zeros(2)}
    path = save_pytree(tree, str(tmp_path), step=1)
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    # checkpoints written before digests existed still load (skip verify)
    del manifest["digest"]
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    restored = load_pytree(tree, path)
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(4.0))
    # a payload missing one array is a partial checkpoint, not a default
    npz = os.path.join(path, "arrays.npz")
    data = dict(np.load(npz))
    data.pop(sorted(data)[0])
    np.savez(npz, **data)
    with pytest.raises(CheckpointError, match="payload missing"):
        load_pytree(tree, path)


# ----------------------------------------------------- straggler stop path
def test_straggler_monitor_gauges_reason_and_counter():
    mon = StragglerMonitor(window=8, threshold=2.0, patience=2)
    flagged = False
    for i in range(12):
        mon.start_step()
        time.sleep(0.001 if i < 8 else 0.02)
        flagged = mon.end_step() or flagged
    assert flagged
    reason = mon.flag_reason()
    assert reason["median"] > 2.0 and reason["streak"] >= 2
    snap = obs_metrics.get_metrics().snapshot()
    assert snap["gauges"]["elastic.step_over_median"]["max"] > 2.0
    assert snap["gauges"]["elastic.slow_streak"]["max"] >= 2
    assert snap["counters"]["elastic.straggler_flags"] >= 1.0


def test_train_loop_stop_on_straggler_checkpoints_and_stops(tmp_path, monkeypatch):
    from repro.configs import get_smoke_config
    from repro.launch import train as train_mod
    from repro.optim.adamw import AdamWConfig

    class FlagAtThree:
        def __init__(self):
            self._steps = 0

        def start_step(self):
            pass

        def end_step(self):
            self._steps += 1
            return self._steps >= 3

        def flag_reason(self):
            return {"median": 9.9, "streak": 3}

        @property
        def median_step_time(self):
            return 0.001

    monkeypatch.setattr(train_mod, "StragglerMonitor", FlagAtThree)
    cfg = get_smoke_config("phi4_mini_3_8b")
    opt = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)
    stats = {}
    _, history = train_mod.train_loop(
        cfg, opt, steps=10, batch=2, seq=8, ckpt_dir=str(tmp_path),
        save_every=1000, log_every=1000, stats_out=stats,
        stop_on_straggler=True,
    )
    assert stats["straggler"] == {"median": 9.9, "streak": 3}
    assert len(history) == 3  # stopped at the flag, not at steps
    # force-saved despite save_every never aligning, evidence in the manifest
    assert os.path.isdir(tmp_path / "step_00000003")
    with open(tmp_path / "step_00000003" / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["extra"]["straggler"] == {"median": 9.9, "streak": 3}
    assert train_mod.STRAGGLER_EXIT_CODE == 75

    # library default: the flag logs and training continues to completion
    stats2 = {}
    _, history2 = train_mod.train_loop(
        cfg, opt, steps=5, batch=2, seq=8, ckpt_dir=None,
        log_every=1000, stats_out=stats2,
    )
    assert len(history2) == 5 and "straggler" not in stats2


# ------------------------------------------------ serving fault isolation
@pytest.fixture(scope="module")
def serve_setup():
    from repro.configs import get_smoke_config
    from repro.models import model as M

    cfg = get_smoke_config("phi4_mini_3_8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **kw):
    from repro.serving.engine import Engine, ServeConfig

    args = dict(max_seq=64, temperature=0.0, slots=3, page_size=8, sync_interval=2)
    args.update(kw)
    return Engine(cfg, params, ServeConfig(**args))


def test_poisoned_decode_evicts_only_culprit_survivors_bit_exact(serve_setup):
    cfg, params = serve_setup
    p0 = np.arange(5) % cfg.vocab
    p1 = (np.arange(9) * 3) % cfg.vocab
    want0 = _engine(cfg, params).submit(p0, 10).result()
    want1 = _engine(cfg, params).submit(p1, 8).result()

    eng = _engine(cfg, params)
    h0 = eng.submit(p0, 10)
    h_bad = eng.submit(p1[::-1].copy(), 12, _inject_fault_at=2)
    h1 = eng.submit(p1, 8)
    eng.run()
    assert h_bad.finish_reason == "error"
    assert h_bad.state.value == "evicted"
    assert len(h_bad.tokens()) == 2  # tokens computed pre-fault still deliver
    assert h0.tokens() == want0
    assert h1.tokens() == want1
    st = eng.serve_stats()
    assert st["pages_in_use"] == 0
    assert st["requests"]["errors"] == 1
    # serving fault counters land on the engine's private registry
    snap = eng.metrics.snapshot()["counters"]
    assert snap["fault.injected_faults"] >= 1.0
    assert snap["fault.evicted_requests"] >= 1.0


def test_prefill_fault_isolated_from_survivor(serve_setup):
    cfg, params = serve_setup
    p = np.arange(6) % cfg.vocab
    want = _engine(cfg, params).submit(p, 8).result()
    eng = _engine(cfg, params)
    h_bad = eng.submit(p[::-1].copy(), 8, _inject_fault_at=0)
    h_ok = eng.submit(p, 8)
    eng.run()
    assert h_bad.finish_reason == "error" and h_bad.tokens() == []
    assert h_ok.tokens() == want
    assert eng.serve_stats()["pages_in_use"] == 0


def test_request_timeout_watchdog_evicts(serve_setup):
    cfg, params = serve_setup
    eng = _engine(cfg, params, request_timeout_s=1e-4)
    h = eng.submit(np.arange(5) % cfg.vocab, 50)
    eng.run()
    assert h.finish_reason == "timeout"
    assert h.state.value == "evicted"
    st = eng.serve_stats()
    assert st["pages_in_use"] == 0
    assert st["requests"]["timeouts"] == 1
    assert eng.metrics.snapshot()["counters"]["fault.timeouts"] >= 1.0


def test_serve_config_rejects_negative_timeout():
    from repro.serving.engine import ServeConfig

    with pytest.raises(ValueError, match="request_timeout_s"):
        ServeConfig(max_seq=64, request_timeout_s=-1.0)

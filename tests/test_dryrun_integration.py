"""End-to-end dry-run integration: one real cell on the production mesh.

Runs in a subprocess (device count locks at jax init) with 512 placeholder
devices — exactly what repro.launch.dryrun does — and asserts the cell
lowers, compiles, and yields coherent roofline artifacts.
"""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_dryrun_cell_whisper_decode_single_pod():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        import json
        from repro.launch.dryrun import run_cell
        r = run_cell("whisper_tiny", "decode_32k", "single")
        assert not r.get("skipped")
        assert r["chips"] == 256
        t = r["roofline"]
        assert t["compute_s"] > 0 and t["memory_s"] > 0
        assert t["bottleneck"] in ("compute", "memory", "collective")
        assert r["cost_analysis"]["flops_per_device"] > 0
        # decode of a 39M-param model must be far below HBM capacity
        mem = r["memory"]
        total = mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
        assert total < 4 * 2**30, total
        print("CELL_OK", json.dumps(t))
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=900,
    )
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-3000:]}"
    assert "CELL_OK" in out.stdout


def test_skip_policy_cell_returns_skip_record():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell
        r = run_cell("gemma_7b", "long_500k", "single")
        assert r.get("skipped"), r
        print("SKIP_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=300,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SKIP_OK" in out.stdout

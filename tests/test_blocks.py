"""Tagged BlockMatrix runtime: tag codec/algebra, stores, out-of-core Strassen."""
import dataclasses
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.blocks import tags
from repro.blocks.blockmatrix import (
    ArenaStore,
    BlockMatrix,
    DictStore,
    MemmapStore,
    make_store,
)
from repro.blocks.scheduler import (
    StrassenScheduler,
    leaf_bytes,
    min_depth_for_budget,
    pipelined_leaf_bytes,
    strassen_oot_matmul,
)
from repro.core import autotune
from repro.core.autotune import Calibration, Candidate
from repro.core.backend import VALID_KINDS, MatmulBackend, matmul, resolve_auto
from repro.core.coefficients import get_scheme, leaf_index_from_path, leaf_tag_path

RNG = np.random.default_rng(7)

CALIB = Calibration(
    t_flop=1e-11, t_elem=1e-9, t_coll=4e-9, t_h2d=2e-9,
    device_kind="test", device_count=1,
)

# The scheduler's leaf dispatch defaults to kind='auto'; tests pin the
# leaves to the naive matmul so no calibration micro-benchmark runs.
NAIVE_LEAVES = MatmulBackend(kind="naive")


@pytest.fixture(autouse=True)
def _synthetic_calibration(monkeypatch):
    monkeypatch.setattr(autotune, "_CALIBRATION", CALIB)
    monkeypatch.setattr(autotune, "_PROCESS_CACHES", {})
    resolve_auto.cache_clear()


def _rand(shape, dtype=np.float32):
    return RNG.standard_normal(shape).astype(dtype)


def _rel_err(got, want):
    got = np.asarray(got, dtype=np.float32)
    want = np.asarray(want, dtype=np.float32)
    return float(np.abs(got - want).max() / (np.abs(want).max() or 1.0))


# ---------------------------------------------------------------- tag codec
@pytest.mark.parametrize("scheme_name", ["strassen", "winograd"])
@pytest.mark.parametrize("depth", [1, 2, 3, 4])
def test_tag_round_trips_match_leaf_tag_path(scheme_name, depth):
    """encode/decode agree with coefficients.leaf_tag_path at every depth
    (both rank-7 schemes share the base-7 M-index alphabet)."""
    rank = get_scheme(scheme_name).n_mults
    step = max(1, rank**depth // 50)
    for index in range(0, rank**depth, step):
        path = tags.decode(index, depth, rank)
        assert path == leaf_tag_path(index, depth)
        assert tags.encode(path, rank) == index == leaf_index_from_path(path)
        assert tags.from_string(tags.to_string(path)) == path


def test_tag_codec_base4_and_bounds():
    for depth in (1, 2, 3):
        for index in range(4**depth):
            path = tags.decode(index, depth, tags.Q_BASE)
            assert tags.encode(path, tags.Q_BASE) == index
    with pytest.raises(ValueError):
        tags.decode(7, 1, 7 - 1)  # index out of range for base 6
    with pytest.raises(ValueError):
        tags.encode((7,), 7)  # digit out of range
    with pytest.raises(ValueError):
        tags.parent(())


def test_tag_child_parent_and_strings():
    p = tags.child(tags.child((), 3), 0)
    assert p == (3, 0)
    assert tags.parent(p) == (3,)
    assert tags.to_string(p) == "3,0"
    assert tags.from_string("") == ()


@pytest.mark.parametrize("scheme_name", ["strassen", "winograd", "naive8"])
def test_tag_algebra_reproduces_matmul_tensor(scheme_name):
    """The divide/combine tag expansion is exactly the block-matmul tensor
    at depth 1 and 2 — the multi-level Scheme.validate."""
    tags.validate_algebra(scheme_name, 1)
    tags.validate_algebra(scheme_name, 2)


def test_operand_terms_coefficients_multiply_down_levels():
    scheme = get_scheme("strassen")
    # M6 at level 0 uses A-coeffs (-1, 0, 1, 0): two terms per level.
    terms = tags.operand_terms((5, 5), scheme, "a")
    assert len(terms) == 4
    coeffs = sorted(c for _, c in terms)
    assert coeffs == [-1.0, -1.0, 1.0, 1.0]
    with pytest.raises(ValueError):
        tags.operand_terms((0,), scheme, "c")


# --------------------------------------------------------------- BlockMatrix
def _stores(slot_bytes, tmp_path):
    return [
        DictStore(),
        ArenaStore(slot_bytes, capacity=8),
        MemmapStore(str(tmp_path / "spill")),
    ]


@pytest.mark.parametrize("dtype_name", ["float32", "bfloat16"])
@pytest.mark.parametrize("shape", [(64, 64), (65, 33), (100, 7), (30, 50)])
def test_blockmatrix_dense_round_trip(shape, dtype_name, tmp_path):
    """from_dense/to_dense is exact for odd/padded shapes in f32 and bf16
    across every store backend."""
    dtype = jnp.dtype(dtype_name)
    arr = np.asarray(jnp.asarray(_rand(shape)).astype(dtype))
    block = (16, 16)
    for store in _stores(16 * 16 * 4, tmp_path):
        bm = BlockMatrix.from_dense(arr, block, store, tag="A:")
        assert bm.to_dense().tobytes() == arr.tobytes()
        assert bm.block(0, 0).shape == block  # padded in storage
        meta = bm.meta()
        assert meta["dtype"] == dtype_name and meta["shape"] == tuple(shape)
        store.close()


def test_blockmatrix_shape_extension_and_free(tmp_path):
    arr = _rand((40, 24))
    store = DictStore()
    bm = BlockMatrix.from_dense(arr, (16, 16), store, tag="A:", shape=(64, 32))
    dense = bm.to_dense()
    assert dense.shape == (64, 32)
    np.testing.assert_array_equal(dense[:40, :24], arr)
    assert not dense[40:].any() and not dense[:, 24:].any()
    assert store.nbytes() > 0
    bm.free()
    assert store.nbytes() == 0


def test_arena_store_reuses_slots_and_reports_footprint():
    store = ArenaStore(slot_bytes=256, capacity=2)
    blk = np.arange(64, dtype=np.float32).reshape(8, 8)
    for i in range(10):  # 10 puts through 2-slot segments with deletes
        store.put((i, 0, "A:"), blk)
        store.delete((i, 0, "A:"))
    store.put((0, 0, "B:"), blk[:4])
    np.testing.assert_array_equal(store.get((0, 0, "B:")), blk[:4])
    assert store.arena_bytes() == 2 * 256  # deletes recycled one segment
    with pytest.raises(ValueError):
        store.put((1, 0, "B:"), np.zeros((9, 9), np.float32))


def test_arena_store_mixed_dtypes():
    store = ArenaStore(slot_bytes=64 * 4, capacity=4)
    f32 = _rand((8, 8))
    bf16 = np.asarray(jnp.asarray(_rand((8, 8))).astype(jnp.bfloat16))
    store.put((0, 0, "C:"), f32)
    store.put((0, 0, "A:"), bf16)
    np.testing.assert_array_equal(store.get((0, 0, "C:")), f32)
    assert store.get((0, 0, "A:")).tobytes() == bf16.tobytes()


def test_memmap_store_spills_npy_files_and_cleans_up(tmp_path):
    root = str(tmp_path / "spill")
    store = MemmapStore(root)
    blk = _rand((8, 8))
    store.put((0, 1, "C:2,3"), blk)
    files = os.listdir(root)
    assert len(files) == 1 and files[0].endswith(".npy")
    np.testing.assert_array_equal(np.asarray(store.get((0, 1, "C:2,3"))), blk)
    assert store.nbytes() >= blk.nbytes
    store.delete((0, 1, "C:2,3"))
    assert os.listdir(root) == []
    # self-owned temp dirs are removed on close
    owned = MemmapStore()
    owned.put((0, 0, "A:"), blk)
    root2 = owned.root
    owned.close()
    assert not os.path.isdir(root2)


def test_memmap_store_preserves_bf16(tmp_path):
    store = MemmapStore(str(tmp_path / "spill"))
    blk = np.asarray(jnp.asarray(_rand((4, 4))).astype(jnp.bfloat16))
    store.put((0, 0, "A:"), blk)
    got = store.get((0, 0, "A:"))
    assert got.dtype == blk.dtype
    assert np.asarray(got).tobytes() == blk.tobytes()


def test_make_store_specs():
    assert isinstance(make_store("dict"), DictStore)
    assert isinstance(make_store("arena", slot_bytes=64), ArenaStore)
    mm = make_store("memmap")
    assert isinstance(mm, MemmapStore)
    mm.close()
    with pytest.raises(ValueError):
        make_store("s3")


# ------------------------------------------------------- out-of-core Strassen
@pytest.mark.parametrize("store_kind", ["dict", "arena", "memmap"])
def test_oot_depth2_budget_below_operands(store_kind):
    """The acceptance shape: depth 2 with a device budget smaller than
    either operand still matches jnp.matmul, in >= 2 staging waves, with
    tracked peak device bytes inside the budget."""
    m, k, n = 200, 136, 168
    a, b = _rand((m, k)), _rand((k, n))
    budget = min(a.nbytes, b.nbytes) // 2
    out, stats = strassen_oot_matmul(
        a, b, depth=2, budget_bytes=budget, backend=NAIVE_LEAVES, store=store_kind
    )
    assert _rel_err(out, a @ b) < 2e-3
    assert stats.waves >= 2
    assert stats.peak_device_bytes <= budget
    assert stats.leaves == 49
    assert stats.h2d_bytes > 0 and stats.d2h_bytes > 0


@pytest.mark.parametrize("depth", [1, 2, 3])
def test_oot_depths_and_schemes(depth):
    a, b = _rand((128, 128)), _rand((128, 128))
    for scheme in ("strassen", "winograd"):
        out, stats = strassen_oot_matmul(
            a, b, depth=depth, budget_bytes=4 * leaf_bytes(128, 128, 128, depth, a.dtype),
            scheme=scheme, backend=NAIVE_LEAVES,
        )
        assert _rel_err(out, a @ b) < 2e-3, (scheme, depth)
        assert stats.leaves == 7**depth


def test_oot_bf16_parity_within_1e2():
    """bf16 depth-2 parity vs the dense bf16 matmul stays within the CI
    gate's 1e-2 (f32 staging keeps one rounding per value)."""
    a = jnp.asarray(_rand((160, 96))).astype(jnp.bfloat16)
    b = jnp.asarray(_rand((96, 128))).astype(jnp.bfloat16)
    a_h, b_h = np.asarray(a), np.asarray(b)
    out, stats = strassen_oot_matmul(
        a_h, b_h, depth=2, budget_bytes=a_h.nbytes, backend=NAIVE_LEAVES
    )
    assert out.dtype == a_h.dtype
    assert stats.stage_dtype == "float32"
    assert _rel_err(out, jnp.matmul(a, b)) < 1e-2


def test_oot_block_grain_and_prefetch_off():
    a, b = _rand((96, 96)), _rand((96, 96))
    out, stats = strassen_oot_matmul(
        a, b, depth=2, budget_bytes=a.nbytes, block=8,
        backend=NAIVE_LEAVES, prefetch=False,
    )
    assert _rel_err(out, a @ b) < 2e-3
    assert not stats.prefetch


def test_oot_budget_too_small_raises_with_min_depth():
    a, b = _rand((256, 256)), _rand((256, 256))
    with pytest.raises(ValueError, match="use depth >="):
        strassen_oot_matmul(a, b, depth=1, budget_bytes=4096, backend=NAIVE_LEAVES)
    assert min_depth_for_budget(256, 256, 256, 3 * 64 * 64 * 4, np.float32) == 2
    with pytest.raises(ValueError):
        min_depth_for_budget(2**20, 2**20, 2**20, 1, np.float32, max_depth=4)


def test_oot_scheduler_validates_config():
    with pytest.raises(ValueError):
        StrassenScheduler(depth=0, budget_bytes=1 << 20)
    with pytest.raises(ValueError):
        StrassenScheduler(depth=1, budget_bytes=0)


# ------------------------------------------------- async wave pipeline
@pytest.mark.parametrize("store_kind", ["dict", "arena", "memmap"])
def test_oot_pipelined_matches_sync_bitexact_f32(store_kind):
    """The async 2-deep pipeline runs the identical leaf schedule as the
    synchronous loop — f32 results are bit-exact across every store, and
    both runs' modeled peaks respect the budget."""
    m, k, n = 200, 136, 168
    a, b = _rand((m, k)), _rand((k, n))
    # one pipelined wave slot — still smaller than either operand
    budget = pipelined_leaf_bytes(m, k, n, 2, np.float32)
    assert budget < min(a.nbytes, b.nbytes)
    kw = dict(depth=2, budget_bytes=budget, backend=NAIVE_LEAVES, store=store_kind)
    out_pipe, st_pipe = strassen_oot_matmul(a, b, **kw)
    out_sync, st_sync = strassen_oot_matmul(a, b, prefetch=False, **kw)
    assert st_pipe.prefetch and st_pipe.waves >= 2
    assert not st_sync.prefetch
    assert np.array_equal(out_pipe, out_sync)
    assert _rel_err(out_pipe, a @ b) < 2e-3
    st_pipe.assert_within_budget()
    st_sync.assert_within_budget()
    assert st_sync.overlap_efficiency == 0.0


@pytest.mark.parametrize("store_kind", ["dict", "arena", "memmap"])
def test_oot_pipelined_bf16_parity_all_stores(store_kind):
    """bf16 pipelined == bf16 sync bit-for-bit, and both stay inside the
    CI gate's 1e-2 vs the dense bf16 matmul."""
    a = jnp.asarray(_rand((160, 96))).astype(jnp.bfloat16)
    b = jnp.asarray(_rand((96, 128))).astype(jnp.bfloat16)
    a_h, b_h = np.asarray(a), np.asarray(b)
    budget = pipelined_leaf_bytes(160, 96, 128, 2, a_h.dtype)
    kw = dict(depth=2, budget_bytes=budget, backend=NAIVE_LEAVES, store=store_kind)
    out_pipe, st_pipe = strassen_oot_matmul(a_h, b_h, **kw)
    out_sync, _ = strassen_oot_matmul(a_h, b_h, prefetch=False, **kw)
    assert st_pipe.prefetch and st_pipe.waves >= 2
    assert out_pipe.dtype == a_h.dtype
    assert out_pipe.tobytes() == out_sync.tobytes()
    assert _rel_err(out_pipe, jnp.matmul(a, b)) < 1e-2


def test_oot_overlap_telemetry_on_forced_multiwave_run():
    """A forced multi-wave pipelined run reports strictly positive
    overlap_efficiency, carries ordered per-wave timestamps that show the
    interleave (wave k+1 staged before wave k's fetch), and lands in the
    process's recent-stats ring."""
    from repro.blocks.scheduler import recent_oot_stats, reset_oot_stats

    reset_oot_stats()
    a, b = _rand((192, 192)), _rand((192, 192))
    budget = pipelined_leaf_bytes(192, 192, 192, 2, a.dtype)  # one pipelined slot
    out, stats = strassen_oot_matmul(
        a, b, depth=2, budget_bytes=budget, backend=NAIVE_LEAVES
    )
    assert _rel_err(out, a @ b) < 2e-3
    assert stats.prefetch and stats.wave_size == 1 and stats.waves == 49
    # the modeled peak charges both in-flight waves in full plus the
    # prefetch, saturating a one-slot budget exactly
    assert stats.peak_device_bytes == budget
    assert 0.0 < stats.overlap_efficiency <= 1.0
    assert len(stats.wave_events) == stats.waves
    for e in stats.wave_events:
        assert (
            e["issue_start"] <= e["issue_end"] <= e["dispatch_end"]
            <= e["fetch_start"] <= e["fetch_end"]
        )
    # the pipeline interleave: wave 1's staging is issued before wave 0's
    # D2H fence, so its transfer overlaps wave 0's in-flight compute
    assert stats.wave_events[1]["issue_start"] < stats.wave_events[0]["fetch_start"]
    ring = recent_oot_stats()
    assert ring and ring[-1]["overlap_efficiency"] == stats.overlap_efficiency
    assert ring[-1]["wave_events"] == stats.wave_events
    reset_oot_stats()
    assert recent_oot_stats() == []


def test_oot_budget_counts_inflight_pipeline_slot():
    """Wave sizing charges the full in-flight pipeline: the slot is two
    whole leaf working sets (the previous wave's operands stay pinned by
    its unfenced executions) plus one more wave of operand prefetch —
    budgets below that degrade to synchronous staging instead of
    exceeding the budget, and the pipelined depth picker deepens until
    the slot fits."""
    m = k = n = 192
    per_leaf = leaf_bytes(m, k, n, 2, np.float32)
    slot = pipelined_leaf_bytes(m, k, n, 2, np.float32)
    # the slot exceeds 2x one leaf by exactly one wave of operand bytes
    assert 2 * per_leaf < slot < 3 * per_leaf
    a, b = _rand((m, k)), _rand((k, n))
    # Regression (review): a 2x-leaf budget — the old slot size — cannot
    # hold the pipelined peak; the scheduler must run synchronously.
    out, stats = strassen_oot_matmul(
        a, b, depth=2, budget_bytes=2 * per_leaf, backend=NAIVE_LEAVES
    )
    assert _rel_err(out, a @ b) < 2e-3
    assert not stats.prefetch and stats.wave_size == 2
    assert stats.overlap_efficiency == 0.0
    stats.assert_within_budget()
    assert min_depth_for_budget(m, k, n, 2 * per_leaf, np.float32) == 2
    assert min_depth_for_budget(m, k, n, 2 * per_leaf, np.float32, pipelined=True) == 3
    assert min_depth_for_budget(m, k, n, slot, np.float32, pipelined=True) == 2
    # a doctored peak trips the budget assertion
    stats.peak_device_bytes = stats.budget_bytes + 1
    with pytest.raises(AssertionError, match="exceeded the budget"):
        stats.assert_within_budget()


def _inject_failing_leaf(monkeypatch, fail_at: int) -> dict:
    """Make the fail_at-th leaf multiply raise, mid-pipeline."""
    calls = {"n": 0}
    real = StrassenScheduler._leaf_matmul

    def boom(self, a_dev, b_dev):
        calls["n"] += 1
        if calls["n"] == fail_at:
            raise RuntimeError("injected leaf failure")
        return real(self, a_dev, b_dev)

    monkeypatch.setattr(StrassenScheduler, "_leaf_matmul", boom)
    return calls


@pytest.mark.parametrize("store_kind", ["dict", "memmap"])
def test_oot_failing_leaf_cleans_caller_store_and_device_buffers(
    store_kind, tmp_path, monkeypatch
):
    """A leaf failure mid-pipeline (prefetched wave in flight) must not
    leak: every block the run created is dropped from a caller-provided
    store (spilled npy files included), unrelated keys survive — other
    runs' blocks under the same "A:"/"B:"/"C:" tag space included, since
    tags are not run-scoped — and the in-flight device buffers are
    released even while the exception's traceback still pins the
    scheduler frame."""
    import jax

    a, b = _rand((96, 96)), _rand((96, 96))
    store = (
        DictStore() if store_kind == "dict"
        else MemmapStore(str(tmp_path / "spill"))
    )
    keep = np.ones((2, 2), np.float32)
    store.put((0, 0, "keep"), keep)
    # another (interleaved/earlier) scheduler run's block: tag-prefix
    # matching would destroy it, per-run key tracking must not
    foreign = np.full((2, 2), 7.0, np.float32)
    store.put((99, 99, "A:0"), foreign)
    _inject_failing_leaf(monkeypatch, fail_at=5)
    baseline = sum(not x.is_deleted() for x in jax.live_arrays())
    with pytest.raises(RuntimeError, match="injected leaf failure") as excinfo:
        strassen_oot_matmul(
            a, b, depth=2, budget_bytes=4 * leaf_bytes(96, 96, 96, 2, a.dtype),
            backend=NAIVE_LEAVES, store=store,
        )
    # excinfo still holds the traceback here, so the frame's device
    # references are alive — release must have been explicit
    assert excinfo.traceback
    assert sum(not x.is_deleted() for x in jax.live_arrays()) <= baseline
    leftover = [kk for kk in store.keys() if kk[2][:2] in ("A:", "B:", "C:")]
    assert leftover == [(99, 99, "A:0")]
    np.testing.assert_array_equal(np.asarray(store.get((0, 0, "keep"))), keep)
    np.testing.assert_array_equal(np.asarray(store.get((99, 99, "A:0"))), foreign)
    if store_kind == "memmap":
        assert len(os.listdir(store.root)) == 2  # only the unrelated keys
    store.close()


def test_oot_failing_leaf_removes_owned_memmap_spill_dir(monkeypatch):
    """When the scheduler built the memmap store itself, a failing run
    removes the whole temp spill directory."""
    roots = []
    real_init = MemmapStore.__init__

    def spying_init(self, root=None):
        real_init(self, root)
        roots.append(self.root)

    monkeypatch.setattr(MemmapStore, "__init__", spying_init)
    _inject_failing_leaf(monkeypatch, fail_at=3)
    a, b = _rand((96, 96)), _rand((96, 96))
    with pytest.raises(RuntimeError, match="injected leaf failure"):
        strassen_oot_matmul(
            a, b, depth=1, budget_bytes=a.nbytes, backend=NAIVE_LEAVES,
            store="memmap",
        )
    assert roots and not os.path.isdir(roots[0])


# ------------------------------------------- autotune strassen_oot family
def test_oot_candidates_enumerate_only_with_budget():
    cands = autotune.enumerate_candidates(512, 512, 512, min_dim=64, max_depth=2)
    assert not any(c.kind == "strassen_oot" for c in cands)
    cands = autotune.enumerate_candidates(
        512, 512, 512, min_dim=64, max_depth=2, oot_budget=16 << 20
    )
    oot = [c for c in cands if c.kind == "strassen_oot"]
    assert {(c.scheme, c.depth) for c in oot} == {
        ("strassen", 1), ("strassen", 2), ("winograd", 1), ("winograd", 2),
    }


def test_oot_respects_min_dim_crossover_when_dense_fits():
    """Below min_dim the out-of-core family must not enumerate (measured
    24x slower than naive at n=128) — unless the dense working set cannot
    fit the budget, where out-of-core is feasibility, not preference."""
    cands = autotune.enumerate_candidates(
        128, 128, 128, min_dim=192, max_depth=2, oot_budget=2 << 20
    )
    assert not any(c.kind == "strassen_oot" for c in cands)
    d = autotune.autotune(
        128, 128, 128, min_dim=192, max_depth=2, calibration=CALIB, oot_budget=2 << 20
    )
    assert d.kind == "naive"
    # dense infeasible: oot enumerates even below min_dim
    tiny = 3 * 48 * 48 * 4  # < 128^2 dense working set, >= one depth-2 leaf
    cands = autotune.enumerate_candidates(
        128, 128, 128, min_dim=192, max_depth=2, oot_budget=tiny
    )
    assert cands and all(c.kind == "strassen_oot" for c in cands)


def test_oot_infeasibility_filter_covers_mesh_candidates():
    """The dense-infeasible filter must drop mesh strategies too — the
    budget models each device's memory, and a row-sharded fused leaf still
    materializes blocks the filter declared impossible."""
    import jax

    from repro.core.compat import make_mesh

    if jax.device_count() < 2:
        pytest.skip("needs the conftest multi-device host platform")
    mesh = make_mesh((jax.device_count() // 2, 2), ("data", "model"))
    budget = 2 << 20  # < 3*512^2*4 dense working set
    cands = autotune.enumerate_candidates(
        512, 512, 512, min_dim=64, max_depth=2, mesh=mesh, oot_budget=budget
    )
    assert cands and all(c.kind == "strassen_oot" for c in cands)
    # with a budget the dense set fits, mesh candidates stay enumerable
    cands = autotune.enumerate_candidates(
        512, 512, 512, min_dim=64, max_depth=2, mesh=mesh, oot_budget=16 << 20
    )
    kinds = {c.kind for c in cands}
    assert "strassen_oot" in kinds and "strassen_bfs_sharded" in kinds


def test_oot_only_candidates_when_dense_exceeds_budget():
    """When A+B+C cannot fit the budget at once, every on-device candidate
    is infeasible and enumeration keeps only the out-of-core family."""
    budget = 64 << 20
    cands = autotune.enumerate_candidates(
        8192, 8192, 8192, min_dim=1024, max_depth=2, oot_budget=budget
    )
    assert cands and all(c.kind == "strassen_oot" for c in cands)
    for c in cands:
        assert leaf_bytes(8192, 8192, 8192, c.depth, np.float32) <= budget
    d = autotune.autotune(
        8192, 8192, 8192, min_dim=1024, max_depth=2,
        calibration=CALIB, oot_budget=budget,
    )
    assert d.kind == "strassen_oot"


def test_oot_predicted_terms_include_t_h2d():
    cand = Candidate(kind="strassen_oot", scheme="strassen", depth=2)
    terms = autotune.predict_cost_terms(cand, 4096, 4096, 4096, CALIB)
    assert set(terms) == {"t_flop", "t_elem", "t_coll", "t_h2d"}
    assert terms["t_h2d"] > 0 and terms["t_coll"] == 0.0
    assert autotune.predict_seconds(cand, 4096, 4096, 4096, CALIB) == pytest.approx(
        sum(terms.values())
    )
    # staging term scales with t_h2d — checked with the async pipeline's
    # overlap discount off, since the discount is piecewise in flop time
    # and deliberately non-linear in t_h2d; local/naive candidates never
    # touch the term either way
    raw = autotune.predict_cost_terms(
        cand, 4096, 4096, 4096, CALIB, oot_overlap=False
    )
    hot = dataclasses.replace(CALIB, t_h2d=CALIB.t_h2d * 10)
    assert autotune.predict_cost_terms(
        cand, 4096, 4096, 4096, hot, oot_overlap=False
    )["t_h2d"] == pytest.approx(raw["t_h2d"] * 10)
    for other in (Candidate(kind="naive"), Candidate(kind="strassen", depth=2)):
        assert autotune.predict_cost_terms(other, 4096, 4096, 4096, CALIB)[
            "t_h2d"
        ] == 0.0


def test_oot_overlap_discount_hides_staged_transfer_cost():
    """Default cost prediction models the 2-deep wave pipeline: H2D time
    covered by leaf compute shrinks to the exposed fraction; transfer
    beyond the compute stays fully priced on top of it."""
    cand = Candidate(kind="strassen_oot", scheme="strassen", depth=2)
    raw = autotune.predict_cost_terms(
        cand, 4096, 4096, 4096, CALIB, oot_overlap=False
    )
    dft = autotune.predict_cost_terms(cand, 4096, 4096, 4096, CALIB)
    assert dft["t_flop"] == pytest.approx(raw["t_flop"])
    assert 0.0 < dft["t_h2d"] < raw["t_h2d"]
    frac = autotune.OOT_OVERLAP_EXPOSED_FRACTION
    hidden = min(raw["t_h2d"], raw["t_flop"])
    assert dft["t_h2d"] == pytest.approx(
        max(raw["t_h2d"] - raw["t_flop"], 0.0) + frac * hidden
    )
    # transfer-bound regime: only the compute-covered slice is discounted
    hot = dataclasses.replace(CALIB, t_h2d=CALIB.t_h2d * 100)
    raw_hot = autotune.predict_cost_terms(
        cand, 4096, 4096, 4096, hot, oot_overlap=False
    )
    assert raw_hot["t_h2d"] > raw_hot["t_flop"]
    assert autotune.predict_cost_terms(cand, 4096, 4096, 4096, hot)[
        "t_h2d"
    ] == pytest.approx(
        raw_hot["t_h2d"] - raw_hot["t_flop"] + frac * raw_hot["t_flop"]
    )
    # the discounted prediction still decomposes exactly
    assert autotune.predict_seconds(cand, 4096, 4096, 4096, hot) == pytest.approx(
        sum(autotune.predict_cost_terms(cand, 4096, 4096, 4096, hot).values())
    )


def test_predict_terms_decomposition_sums_for_all_kinds():
    calib = dataclasses.replace(CALIB, device_count=8)
    for cand in [
        Candidate(kind="naive"),
        Candidate(kind="strassen", scheme="strassen", depth=2),
        Candidate(kind="strassen_fused", scheme="strassen", depth=2),
        Candidate(kind="strassen_bfs_sharded", scheme="strassen", depth=2),
        Candidate(kind="strassen_fused_sharded", scheme="strassen", depth=1),
        Candidate(kind="strassen_oot", scheme="winograd", depth=3),
    ]:
        terms = autotune.predict_cost_terms(cand, 2048, 2048, 2048, calib, device_count=8)
        assert autotune.predict_seconds(
            cand, 2048, 2048, 2048, calib, device_count=8
        ) == pytest.approx(sum(terms.values()))


def test_oot_execute_and_telemetry_terms():
    tel = autotune.get_telemetry()
    tel.reset()
    d = autotune.autotune(
        4096, 4096, 4096, min_dim=1024, max_depth=2,
        calibration=CALIB, oot_budget=8 << 20,
    )
    (event,) = tel.events
    assert event.terms is not None and set(event.terms) == {
        "t_flop", "t_elem", "t_coll", "t_h2d",
    }
    # run the candidate small (same kind) to keep suite time sane
    cand = Candidate(kind="strassen_oot", scheme=d.scheme, depth=1)
    a, b = _rand((96, 96)), _rand((96, 96))
    got = autotune.execute(cand, jnp.asarray(a), jnp.asarray(b))
    assert _rel_err(got, a @ b) < 2e-3


def test_cache_key_oot_budget_separates():
    kw = dict(device_kind="cpu", device_count=1, schemes=("strassen",),
              min_dim=1024, max_depth=2)
    k_plain = autotune.cache_key(512, 512, 512, jnp.float32, **kw)
    k_oot = autotune.cache_key(512, 512, 512, jnp.float32, oot_budget=1 << 20, **kw)
    assert k_plain != k_oot
    assert autotune.cache_key(512, 512, 512, jnp.float32, oot_budget=None, **kw) == k_plain


# ----------------------------------------------------- backend kind routing
def test_backend_kind_validation_lists_registered_kinds():
    with pytest.raises(ValueError) as err:
        MatmulBackend(kind="strassen_typo")
    for kind in VALID_KINDS:
        assert kind in str(err.value)
    for kind in VALID_KINDS:  # every registered kind constructs
        MatmulBackend(kind=kind)


def test_backend_oot_kind_routes_through_block_runtime():
    a, b = _rand((120, 88)), _rand((88, 96))
    be = MatmulBackend(
        kind="strassen_oot", depth=2, min_dim=1, device_budget=a.nbytes
    )
    got = matmul(jnp.asarray(a), jnp.asarray(b), be)
    assert _rel_err(got, a @ b) < 2e-3
    # leading batch dims flatten/restore like every other kind
    x = _rand((2, 4, 88))
    got = matmul(jnp.asarray(x), jnp.asarray(b), be)
    assert _rel_err(got, x @ b) < 2e-3


def test_backend_oot_deepens_when_budget_demands():
    a, b = _rand((256, 256)), _rand((256, 256))
    budget = 3 * 64 * 64 * 4 + 1  # fits depth-2 leaves only
    be = MatmulBackend(kind="strassen_oot", depth=1, min_dim=1, device_budget=budget)
    got = matmul(jnp.asarray(a), jnp.asarray(b), be)
    assert _rel_err(got, a @ b) < 2e-3


def test_backend_oot_rejects_jit():
    import jax

    be = MatmulBackend(kind="strassen_oot", depth=1, min_dim=1)
    a, b = jnp.zeros((64, 64)), jnp.zeros((64, 64))
    with pytest.raises(ValueError, match="cannot run under jit"):
        jax.jit(lambda x, y: matmul(x, y, be))(a, b)


def test_auto_with_budget_never_picks_oot_under_jit():
    """kind='auto' + device_budget inside jit resolves without the
    eager-only family (the decision would otherwise crash the trace) —
    even at shapes where the eager resolution WOULD pick strassen_oot."""
    import jax

    m = k = n = 256
    budget = 3 * 32 * 32 * 4  # dense working set infeasible at 256^2
    be = MatmulBackend(kind="auto", depth=3, min_dim=1, device_budget=budget)
    a, b = _rand((m, k)), _rand((k, n))
    # eagerly, the budget forces the out-of-core family...
    d = autotune.autotune(
        m, k, n, min_dim=1, max_depth=3, calibration=CALIB, oot_budget=budget
    )
    assert d.kind == "strassen_oot"
    # ...but under jit the same backend resolves to a traceable plan.
    got = jax.jit(lambda x, y: matmul(x, y, be))(jnp.asarray(a), jnp.asarray(b))
    assert _rel_err(got, a @ b) < 3e-3


def test_resolve_auto_routes_oot_decision(monkeypatch):
    from repro.core.autotune import Decision

    decision = Decision(kind="strassen_oot", scheme="strassen", depth=2, predicted_s=1e-3)
    monkeypatch.setattr(autotune, "autotune", lambda *a, **k: decision)
    be = MatmulBackend(kind="auto", depth=2, min_dim=1, device_budget=1 << 20)
    resolved = resolve_auto(4096, 4096, 4096, "float32", be)
    assert resolved.kind == "strassen_oot" and resolved.depth == 2
    assert resolved.device_budget == 1 << 20


def test_resolve_auto_preserves_oot_decision_scheme(monkeypatch):
    """A winograd oot decision must execute winograd — the scheme rides
    along as the resolved backend's single schemes entry."""
    from repro.core.autotune import Decision

    decision = Decision(kind="strassen_oot", scheme="winograd", depth=1, predicted_s=1e-3)
    real = autotune.autotune

    def fake(m, *a, **k):  # only the outer shape resolves out-of-core —
        # the scheduler's own leaf dispatch must keep resolving normally
        return decision if m == 2048 else real(m, *a, **k)

    monkeypatch.setattr(autotune, "autotune", fake)
    be = MatmulBackend(kind="auto", depth=2, min_dim=1, device_budget=1 << 20)
    resolved = resolve_auto(2048, 2048, 2048, "float32", be)
    assert resolved.scheme_name == "winograd"
    a, b = _rand((96, 96)), _rand((96, 96))
    got = matmul(jnp.asarray(a), jnp.asarray(b), resolved)
    assert _rel_err(got, a @ b) < 2e-3


def test_jitted_launchers_exclude_oot_from_backend_choices():
    """train/serve/dryrun run every matmul under jit, where the eager-only
    kind can never execute — their --backend menus must not offer it."""
    import importlib.util

    from repro.core.backend import EAGER_ONLY_KINDS, JIT_SAFE_KINDS

    assert "strassen_oot" in EAGER_ONLY_KINDS
    assert "strassen_oot" not in JIT_SAFE_KINDS
    assert set(JIT_SAFE_KINDS) | set(EAGER_ONLY_KINDS) == set(VALID_KINDS)
    for mod_name in ("train", "serve", "dryrun"):
        spec = importlib.util.find_spec(f"repro.launch.{mod_name}")
        with open(spec.origin) as f:
            assert "JIT_SAFE_KINDS" in f.read(), mod_name


def test_calibration_round_trips_t_h2d():
    d = CALIB.to_dict()
    assert d["t_h2d"] == CALIB.t_h2d
    assert Calibration.from_dict(d) == CALIB
    # pre-t_h2d cache entries still load (field defaults to 0.0)
    legacy = {k: v for k, v in d.items() if k != "t_h2d"}
    assert Calibration.from_dict(legacy).t_h2d == 0.0


def test_calibration_snapshot_reports_without_running():
    snap = autotune.calibration_snapshot()
    assert snap is not None and snap["t_h2d"] == CALIB.t_h2d

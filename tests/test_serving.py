"""Serving engine: determinism, temperature, cache accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("phi4_mini_3_8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    return cfg, params, prompts


def test_greedy_generation_deterministic(setup):
    cfg, params, prompts = setup
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=0.0))
    t1, s1 = eng.generate(prompts, 8)
    t2, _ = eng.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 8)
    assert s1["cache_pos"] == 8 + 8 - 1  # prompt + generated - last not written


def test_temperature_sampling_varies_by_seed(setup):
    cfg, params, prompts = setup
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=5.0))
    t1, _ = eng.generate(prompts, 12, seed=0)
    t2, _ = eng.generate(prompts, 12, seed=1)
    assert not np.array_equal(np.asarray(t1), np.asarray(t2))


def test_greedy_matches_manual_argmax_rollout(setup):
    cfg, params, prompts = setup
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=0.0))
    toks, _ = eng.generate(prompts, 4)
    # manual rollout through full forward passes
    cur = prompts
    manual = []
    for _ in range(4):
        logits, _ = M.apply_train(params, {"tokens": cur, "labels": cur}, cfg)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        manual.append(nxt)
        cur = jnp.concatenate([cur, nxt], axis=1)
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.concatenate(manual, axis=1))
    )


def test_engine_scopes_autotune_telemetry(setup):
    """Engine construction zeroes the process autotune telemetry, so each
    instance's stats cover its own resolutions instead of interleaving
    with a previous engine's, and autotune_stats() surfaces the
    out-of-core scheduler's recent runs under "oot"."""
    from repro.core import autotune
    from repro.core.autotune import Calibration, TuningCache

    cfg, params, _ = setup
    calib = Calibration(
        t_flop=1e-11, t_elem=1e-9, t_coll=4e-9, t_h2d=2e-9,
        device_kind="test", device_count=1,
    )
    # pollute the process log the way a previous engine's resolutions would
    autotune.autotune(4096, 4096, 4096, calibration=calib, cache=TuningCache())
    assert autotune.get_telemetry().snapshot()["cache_misses"] >= 1
    # ... and the process-global oot ring the way a previous engine's
    # strassen_oot runs would
    from repro.blocks.scheduler import recent_oot_stats, strassen_oot_matmul
    from repro.core.backend import MatmulBackend

    a = np.ones((64, 64), np.float32)
    strassen_oot_matmul(
        a, a, depth=1, budget_bytes=a.nbytes * 4,
        backend=MatmulBackend(kind="naive"),
    )
    assert recent_oot_stats()
    eng = Engine(cfg, params, ServeConfig(max_seq=64))
    snap = eng.autotune_stats()
    assert snap["cache_hits"] == 0 and snap["cache_misses"] == 0
    assert snap["decisions"] == []
    assert snap["oot"] == []


# ------------------------------------------------- request-based engine API


@pytest.fixture(scope="module")
def cont_setup():
    cfg = get_smoke_config("phi4_mini_3_8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(cfg, params, **kw):
    args = dict(max_seq=64, temperature=0.0, slots=3, page_size=8, sync_interval=2)
    args.update(kw)
    return Engine(cfg, params, ServeConfig(**args))


def test_generate_shim_matches_legacy_static_path(setup):
    """The compat shim on the request loop is token-exact with the
    pre-redesign static loop, including the eos truncation rule."""
    cfg, params, prompts = setup
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=0.0))
    t_old, s_old = eng._generate_static(prompts, 8)
    t_new, s_new = eng.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(t_old), np.asarray(t_new))
    assert s_new["cache_pos"] == s_old["cache_pos"]
    # eos case: pick a token the greedy run actually emits mid-stream
    eos = int(np.asarray(t_old)[0, 4])
    eng2 = Engine(cfg, params, ServeConfig(max_seq=64, temperature=0.0, eos_id=eos))
    t_old2, _ = eng2._generate_static(prompts, 8)
    t_new2, _ = eng2.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(t_old2), np.asarray(t_new2))


def test_generate_shim_parity_recurrent_arch():
    """Parity must also hold for archs with no paged KV at all (pure
    slot-indexed recurrent state)."""
    cfg = get_smoke_config("xlstm_1_3b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=0.0))
    t_old, _ = eng._generate_static(prompts, 6)
    t_new, _ = eng.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(t_old), np.asarray(t_new))


def test_mid_decode_admission_keeps_survivor_tokens_exact(cont_setup):
    """A request admitted while another is mid-decode must not perturb
    the resident request's greedy tokens (vs running it alone)."""
    cfg, params = cont_setup
    p0 = np.arange(5) % cfg.vocab
    p1 = (np.arange(9) * 3) % cfg.vocab

    solo = _engine(cfg, params)
    want0 = solo.submit(p0, 10).result()
    solo2 = _engine(cfg, params)
    want1 = solo2.submit(p1, 6).result()

    eng = _engine(cfg, params)
    h0 = eng.submit(p0, 10)
    for _ in range(3):  # h0 several steps into decode
        eng.step()
    h1 = eng.submit(p1, 6)  # admitted mid-decode
    eng.run()
    assert h0.tokens() == want0
    assert h1.tokens() == want1


def test_eviction_frees_pages_and_keeps_survivors(cont_setup):
    cfg, params = cont_setup
    eng = _engine(cfg, params)
    p = np.arange(6) % cfg.vocab
    solo = _engine(cfg, params)
    want = solo.submit(p, 12).result()

    h_keep = eng.submit(p, 12)
    h_evict = eng.submit(p[::-1].copy(), 12)
    for _ in range(3):
        eng.step()
    pages_mid = eng.serve_stats()["pages_in_use"]
    assert pages_mid > 0
    h_evict.cancel()
    assert h_evict.state.value == "evicted"
    assert h_evict.finish_reason == "evicted"
    assert eng.serve_stats()["pages_in_use"] < pages_mid
    eng.run()
    assert h_keep.tokens() == want
    assert eng.serve_stats()["pages_in_use"] == 0


def test_page_accounting_no_leak_over_churn(cont_setup):
    """N submit/finish/evict cycles must return the pool to exactly
    full-free every time (the double-free guard makes leaks loud)."""
    cfg, params = cont_setup
    eng = _engine(cfg, params, slots=2)
    rng = np.random.default_rng(2)
    for cycle in range(4):
        hs = [
            eng.submit(rng.integers(0, cfg.vocab, size=4 + i), 5 + i)
            for i in range(3)
        ]
        if cycle % 2:
            eng.step()
            hs[0].cancel()
        eng.run()
        st = eng.serve_stats()
        assert st["pages_in_use"] == 0, (cycle, st)
        assert st["pages_free"] == st["page_budget"], (cycle, st)
        assert st["slots_active"] == 0 and st["queue_depth"] == 0


def test_admission_reject_on_exhausted_budget(cont_setup):
    cfg, params = cont_setup
    # budget: one request's worth of pages -> second concurrent submit rejected
    eng = _engine(cfg, params, slots=2, page_budget=2, admission="reject")
    h0 = eng.submit(np.arange(4), 8)  # needs ceil(11/8)=2 pages
    h1 = eng.submit(np.arange(4), 8)
    assert h0.state.value != "rejected"
    assert h1.state.value == "rejected" and h1.finish_reason == "rejected"
    assert eng.serve_stats()["requests"]["rejected"] == 1
    eng.run()
    assert h0.finish_reason == "length"
    # budget free again -> next submit admitted
    h2 = eng.submit(np.arange(4), 8)
    assert h2.state.value != "rejected"
    eng.run()
    assert h2.finish_reason == "length"


def test_admission_queue_waits_for_capacity(cont_setup):
    cfg, params = cont_setup
    eng = _engine(cfg, params, slots=1)
    h0 = eng.submit(np.arange(4), 6)
    h1 = eng.submit(np.arange(4), 6)
    assert h1.state.value == "queued"  # one slot, h0 holds it
    assert eng.serve_stats()["queue_depth"] == 1
    eng.run()
    assert h0.finish_reason == "length" and h1.finish_reason == "length"
    assert len(h1.tokens()) == 6


def test_submit_never_fit_raises(cont_setup):
    cfg, params = cont_setup
    eng = _engine(cfg, params)
    with pytest.raises(ValueError):
        eng.submit(np.arange(60), 10)  # beyond max_seq=64


def test_streaming_callback_and_event_ordering(cont_setup):
    cfg, params = cont_setup
    eng = _engine(cfg, params, slots=2, sync_interval=3)
    events = []
    hs = [
        eng.submit(np.arange(3 + i), 7, on_token=lambda h, ev: events.append(ev))
        for i in range(3)
    ]
    streamed = list(eng.stream(hs))
    # callbacks fired once per token, in per-request index order
    byreq = {}
    for ev in events:
        byreq.setdefault(ev.request_id, []).append(ev)
    assert set(byreq) == {h.id for h in hs}
    for h in hs:
        evs = byreq[h.id]
        assert [e.index for e in evs] == list(range(7))
        assert [e.token for e in evs] == h.tokens()
    # stream() yields the same events
    assert sorted((e.request_id, e.index, e.token) for e in streamed) == sorted(
        (e.request_id, e.index, e.token) for e in events
    )
    # per-request TTFT/latency telemetry populated
    ttft, gaps = hs[0].latency_stats()
    assert ttft is not None and ttft >= 0
    assert len(gaps) == 6


def test_static_gang_batching_mode(cont_setup):
    """batching='static' (the benchmark baseline) gang-schedules: no
    admission while any request is resident, same tokens as continuous."""
    cfg, params = cont_setup
    prompts = [np.arange(4), np.arange(5), np.arange(6)]
    want = []
    for p in prompts:
        want.append(_engine(cfg, params).submit(p, 6).result())

    eng = _engine(cfg, params, slots=2, batching="static")
    hs = [eng.submit(p, 6) for p in prompts]
    assert hs[2].state.value == "queued"  # gang of 2 admitted, third waits
    eng.step()
    assert hs[2].state.value == "queued"  # still: gang must drain first
    eng.run()
    assert [h.tokens() for h in hs] == want
    assert eng.serve_stats()["requests"]["finished"] == 3


def test_serve_config_apply_to_and_validation(cont_setup):
    import dataclasses as dc

    cfg, _ = cont_setup
    sc = ServeConfig(tuning_cache="/tmp/tc.json")
    auto_cfg = dc.replace(
        cfg, matmul_backend=dc.replace(cfg.matmul_backend, kind="auto")
    )
    out = sc.apply_to(auto_cfg)
    assert out.matmul_backend.tuning_cache == "/tmp/tc.json"
    # non-auto backends and explicit caches are left alone
    assert sc.apply_to(cfg).matmul_backend.tuning_cache == cfg.matmul_backend.tuning_cache
    pre = dc.replace(auto_cfg, matmul_backend=dc.replace(auto_cfg.matmul_backend, tuning_cache="x"))
    assert sc.apply_to(pre).matmul_backend.tuning_cache == "x"
    with pytest.raises(ValueError):
        ServeConfig(admission="maybe")
    with pytest.raises(ValueError):
        ServeConfig(batching="dynamic")
    with pytest.raises(ValueError):
        ServeConfig(slots=0)

"""Serving engine: determinism, temperature, cache accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.serving.engine import Engine, ServeConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("phi4_mini_3_8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab)
    return cfg, params, prompts


def test_greedy_generation_deterministic(setup):
    cfg, params, prompts = setup
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=0.0))
    t1, s1 = eng.generate(prompts, 8)
    t2, _ = eng.generate(prompts, 8)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
    assert t1.shape == (2, 8)
    assert s1["cache_pos"] == 8 + 8 - 1  # prompt + generated - last not written


def test_temperature_sampling_varies_by_seed(setup):
    cfg, params, prompts = setup
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=5.0))
    t1, _ = eng.generate(prompts, 12, seed=0)
    t2, _ = eng.generate(prompts, 12, seed=1)
    assert not np.array_equal(np.asarray(t1), np.asarray(t2))


def test_greedy_matches_manual_argmax_rollout(setup):
    cfg, params, prompts = setup
    eng = Engine(cfg, params, ServeConfig(max_seq=64, temperature=0.0))
    toks, _ = eng.generate(prompts, 4)
    # manual rollout through full forward passes
    cur = prompts
    manual = []
    for _ in range(4):
        logits, _ = M.apply_train(params, {"tokens": cur, "labels": cur}, cfg)
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        manual.append(nxt)
        cur = jnp.concatenate([cur, nxt], axis=1)
    np.testing.assert_array_equal(
        np.asarray(toks), np.asarray(jnp.concatenate(manual, axis=1))
    )


def test_engine_scopes_autotune_telemetry(setup):
    """Engine construction zeroes the process autotune telemetry, so each
    instance's stats cover its own resolutions instead of interleaving
    with a previous engine's, and autotune_stats() surfaces the
    out-of-core scheduler's recent runs under "oot"."""
    from repro.core import autotune
    from repro.core.autotune import Calibration, TuningCache

    cfg, params, _ = setup
    calib = Calibration(
        t_flop=1e-11, t_elem=1e-9, t_coll=4e-9, t_h2d=2e-9,
        device_kind="test", device_count=1,
    )
    # pollute the process log the way a previous engine's resolutions would
    autotune.autotune(4096, 4096, 4096, calibration=calib, cache=TuningCache())
    assert autotune.get_telemetry().snapshot()["cache_misses"] >= 1
    # ... and the process-global oot ring the way a previous engine's
    # strassen_oot runs would
    from repro.blocks.scheduler import recent_oot_stats, strassen_oot_matmul
    from repro.core.backend import MatmulBackend

    a = np.ones((64, 64), np.float32)
    strassen_oot_matmul(
        a, a, depth=1, budget_bytes=a.nbytes * 4,
        backend=MatmulBackend(kind="naive"),
    )
    assert recent_oot_stats()
    eng = Engine(cfg, params, ServeConfig(max_seq=64))
    snap = eng.autotune_stats()
    assert snap["cache_hits"] == 0 and snap["cache_misses"] == 0
    assert snap["decisions"] == []
    assert snap["oot"] == []

"""Core Strassen: scheme identities, pipelines, tags, cost model, hypothesis."""
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored grid shim
    from _propshim import given, settings, strategies as st

from repro.core import (
    NAIVE8,
    STRASSEN,
    WINOGRAD,
    MatmulBackend,
    divide_level,
    leaf_count,
    matmul,
    merge_quadrants,
    split_quadrants,
    strassen_matmul,
    strassen_recursive,
)
from repro.core.coefficients import leaf_index_from_path, leaf_tag_path
from repro.core.cost_model import (
    CostModel,
    marlin_stages,
    mllib_stages,
    paper_stage_count,
    stark_stages,
    total_cost,
)

RNG = np.random.default_rng(7)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


# ---------------------------------------------------------------- schemes
@pytest.mark.parametrize("scheme", [STRASSEN, WINOGRAD, NAIVE8])
def test_scheme_bilinear_identity(scheme):
    scheme.validate()


def test_scheme_rank():
    assert STRASSEN.n_mults == 7 and WINOGRAD.n_mults == 7 and NAIVE8.n_mults == 8
    assert abs(STRASSEN.exponent() - 2.807) < 1e-3


# ---------------------------------------------------------------- pipeline
@pytest.mark.parametrize("scheme", ["strassen", "winograd", "naive8"])
@pytest.mark.parametrize("depth", [0, 1, 2, 3])
def test_strassen_matmul_square(scheme, depth):
    a, b = _rand((64, 64)), _rand((64, 64))
    got = strassen_matmul(a, b, depth=depth, scheme=scheme)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("m,k,n", [(128, 64, 32), (32, 96, 64), (256, 32, 128)])
def test_strassen_matmul_rectangular(m, k, n):
    a, b = _rand((m, k)), _rand((k, n))
    got = strassen_matmul(a, b, depth=2, scheme="strassen")
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), atol=2e-3, rtol=2e-3)


def test_strassen_recursive_matches_paper_alg1():
    a, b = _rand((128, 128)), _rand((128, 128))
    got = strassen_recursive(a, b, threshold=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), atol=2e-3, rtol=2e-3)


def test_divide_combine_roundtrip_identity_scheme():
    """combine(c_coef) after divide must invert for the naive8 scheme.

    naive8's C row-space reproduces each quadrant from disjoint products, so
    divide->(identity leaf on matching pairs)->combine equals plain matmul.
    """
    a, b = _rand((32, 32)), _rand((32, 32))
    got = strassen_matmul(a, b, depth=3, scheme="naive8")
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), atol=2e-3, rtol=2e-3)


def test_quadrant_roundtrip():
    x = _rand((5, 64, 48))
    np.testing.assert_array_equal(np.asarray(merge_quadrants(split_quadrants(x))), np.asarray(x))


def test_leaf_count_matches_paper():
    # paper: b^log2(7) leaf multiplications for b = 2^depth splits
    for depth in range(5):
        b = 2**depth
        assert leaf_count(STRASSEN, depth) == 7**depth
        assert abs(leaf_count(STRASSEN, depth) - b ** np.log2(7)) < 1e-6 * 7**depth


# ---------------------------------------------------------------- tags
def test_tag_bijection():
    for depth in (1, 2, 3):
        seen = set()
        for i in range(7**depth):
            path = leaf_tag_path(i, depth)
            assert len(path) == depth and all(0 <= d < 7 for d in path)
            assert leaf_index_from_path(path) == i
            seen.add(path)
        assert len(seen) == 7**depth


def test_divide_level_ordering_matches_tags():
    """Leaf index base-7 digits must equal the per-level M-index path."""
    a = _rand((1, 16, 16))
    coef = jnp.asarray(STRASSEN.a_coef)
    lvl1 = divide_level(a, coef)  # (7, 8, 8)
    lvl2 = divide_level(lvl1, coef)  # (49, 4, 4)
    # Recompute leaf (i, j) directly from the tag path and compare.
    for idx in (0, 8, 13, 48):
        i, j = leaf_tag_path(idx, 2)
        q1 = split_quadrants(a[0])
        step1 = jnp.einsum("q,qij->ij", coef[i].astype(a.dtype), q1)
        q2 = split_quadrants(step1)
        want = jnp.einsum("q,qij->ij", coef[j].astype(a.dtype), q2)
        np.testing.assert_allclose(np.asarray(lvl2[idx]), np.asarray(want), atol=1e-5)


# ---------------------------------------------------------------- backend
def test_backend_fallback_below_min_dim():
    x, w = _rand((8, 64)), _rand((64, 32))
    be = MatmulBackend(kind="strassen", depth=2, min_dim=4096)
    assert be.effective_depth(8, 64, 32) == 0
    np.testing.assert_allclose(np.asarray(matmul(x, w, be)), np.asarray(x @ w), atol=1e-5)


def test_backend_effective_depth_divisibility():
    be = MatmulBackend(kind="strassen", depth=3, min_dim=2)
    assert be.effective_depth(12, 12, 12) == 2  # 12 -> 6 -> 3 (odd stops)
    assert be.effective_depth(16, 16, 16) == 3


# ---------------------------------------------------------------- hypothesis
@settings(max_examples=25, deadline=None)
@given(
    depth=st.integers(min_value=0, max_value=2),
    scheme=st.sampled_from(["strassen", "winograd"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    logm=st.integers(min_value=2, max_value=5),
    logk=st.integers(min_value=2, max_value=5),
    logn=st.integers(min_value=2, max_value=5),
)
def test_property_strassen_equals_matmul(depth, scheme, seed, logm, logk, logn):
    rng = np.random.default_rng(seed)
    m, k, n = 2**logm, 2**logk, 2**logn
    a = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    b = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    got = strassen_matmul(a, b, depth=depth, scheme=scheme)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b), atol=3e-3, rtol=3e-3)


@settings(max_examples=25, deadline=None)
@given(
    scheme=st.sampled_from([STRASSEN, WINOGRAD, NAIVE8]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_property_linearity_of_levels(scheme, seed):
    """divide/combine are linear: divide(x+y) == divide(x) + divide(y)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 8, 8)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((2, 8, 8)).astype(np.float32))
    coef = jnp.asarray(scheme.a_coef)
    lhs = divide_level(x + y, coef)
    rhs = divide_level(x, coef) + divide_level(y, coef)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), atol=1e-5)


# ---------------------------------------------------------------- cost model
def test_paper_stage_count_eq25():
    assert paper_stage_count(2**14, 2**4) == 2 * 4 + 2  # p=14, q=10
    assert paper_stage_count(4096, 2) == 2 * 1 + 2


def test_cost_model_orders_systems_like_paper():
    """Paper Fig. 8: Stark < Marlin <= MLLib at large sizes, any b."""
    for b in (8, 16, 32):
        stark = total_cost("stark", 16384, b, cores=25)
        marlin = total_cost("marlin", 16384, b, cores=25)
        mllib = total_cost("mllib", 16384, b, cores=25)
        assert stark < marlin and stark < mllib, (b, stark, marlin, mllib)


def test_cost_model_u_curve():
    """Paper Fig. 9: running time vs partition count is U-shaped."""
    costs = [total_cost("stark", 8192, b, cores=25) for b in (2, 4, 8, 16, 32, 64)]
    mins = int(np.argmin(costs))
    assert 0 < mins < len(costs) - 1, costs  # interior minimum


def test_cost_model_leaf_dominates_small_b():
    """Paper §V-E: leaf multiplication dominates at small partition counts."""
    model = CostModel()
    sections = model.by_section(stark_stages(8192, 4), cores=25)
    assert sections["leaf"] > sections["divide"]
    assert sections["leaf"] > sections["combine"]


def test_cost_model_overlap_prices_stages_at_max_not_sum():
    """overlap=True models latency-hidden transfers (the oot scheduler's
    async wave pipeline): each stage costs max(comp, comm) instead of
    comp + comm, so the overlapped total is never larger and strictly
    smaller whenever a stage carries both streams."""
    model = CostModel()
    stages = stark_stages(8192, 16)
    seq = model.total(stages, cores=25)
    ovl = model.total(stages, cores=25, overlap=True)
    assert ovl < seq
    for s in stages:
        both = s.wall_clock(25, model.t_flop, model.t_elem)
        hid = s.wall_clock(25, model.t_flop, model.t_elem, overlap=True)
        assert hid <= both
        pf = max(min(s.parallelization, 25), 1.0)
        assert hid == pytest.approx(
            max(s.computation * model.t_flop, s.communication * model.t_elem) / pf
        )
    # by_section sums respect the same discount
    sec_seq = model.by_section(stages, cores=25)
    sec_ovl = model.by_section(stages, cores=25, overlap=True)
    assert set(sec_ovl) == set(sec_seq)
    assert sum(sec_ovl.values()) == pytest.approx(ovl)
    assert all(sec_ovl[k] <= sec_seq[k] for k in sec_seq)


def test_cost_model_stark_fewer_leaf_flops():
    """Stark does b^2.807 leaf multiplies vs b^3 (the paper's core claim)."""
    n, b = 8192, 16
    stark_leaf = sum(s.computation for s in stark_stages(n, b) if s.section == "leaf")
    marlin_leaf = sum(s.computation for s in marlin_stages(n, b) if s.section == "leaf")
    mllib_leaf = sum(s.computation for s in mllib_stages(n, b) if s.section == "leaf")
    assert stark_leaf < marlin_leaf == mllib_leaf
    np.testing.assert_allclose(stark_leaf / marlin_leaf, 7**4 / 16**3, rtol=1e-6)

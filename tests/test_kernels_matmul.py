"""Shape/dtype sweeps for the matmul Pallas kernels vs the pure-jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.matmul.ops import batched_matmul, matmul
from repro.kernels.matmul.ref import batched_matmul_ref, matmul_ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    x = RNG.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-1}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "m,k,n,bm,bn,bk",
    [
        (128, 128, 128, 128, 128, 128),
        (256, 128, 64, 128, 64, 64),
        (64, 192, 128, 32, 128, 64),
        (512, 256, 256, 256, 256, 128),
        (8, 16, 8, 8, 8, 16),  # tiny, interpret-only shapes
    ],
)
def test_matmul_sweep(m, k, n, bm, bn, bk, dtype):
    a, b = _rand((m, k), dtype), _rand((k, n), dtype)
    got = matmul(a, b, block_m=bm, block_n=bn, block_k=bk)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=TOL[dtype], rtol=TOL[dtype]
    )
    assert got.dtype == a.dtype


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mb,m,k,n", [(7, 64, 64, 64), (49, 32, 32, 32), (1, 128, 64, 128)])
def test_batched_matmul_sweep(mb, m, k, n, dtype):
    a, b = _rand((mb, m, k), dtype), _rand((mb, k, n), dtype)
    got = batched_matmul(a, b, block_m=64, block_n=64, block_k=64)
    want = batched_matmul_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=TOL[dtype], rtol=TOL[dtype]
    )


def test_matmul_nondivisible_blocks_fall_back():
    # pick_block must find a divisor; result still correct.
    a, b = _rand((96, 80), jnp.float32), _rand((80, 112), jnp.float32)
    got = matmul(a, b, block_m=128, block_n=128, block_k=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(matmul_ref(a, b)), atol=2e-4, rtol=2e-4)

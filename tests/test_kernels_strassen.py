"""Fused Strassen kernels vs oracles: divide/combine/fused-matmul sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coefficients import get_scheme
from repro.core.strassen import merge_quadrants, split_quadrants
from repro.kernels.strassen.ops import (
    strassen_matmul_fused,
    strassen_matmul_fused_padded,
    strassen_matmul_stages,
)
from repro.kernels.strassen.ref import (
    combine_ref,
    divide_ref,
    strassen1_full_ref,
    strassen1_matmul_ref,
)
from repro.kernels.strassen.strassen import (
    combine_pallas,
    divide_pallas,
    strassen1_matmul_pallas,
)

RNG = np.random.default_rng(1)
TOL = {jnp.float32: 5e-4, jnp.bfloat16: 5e-1}


def _rand(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32), dtype)


@pytest.mark.parametrize("scheme_name", ["strassen", "winograd", "naive8"])
@pytest.mark.parametrize("m,h,w", [(1, 64, 64), (7, 32, 64), (4, 128, 128)])
def test_divide_kernel(scheme_name, m, h, w):
    scheme = get_scheme(scheme_name)
    x = _rand((m, 4, h, w))
    got = divide_pallas(x, scheme.a_coef, block=64)
    want = divide_ref(x, scheme.a_coef)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("scheme_name", ["strassen", "winograd"])
@pytest.mark.parametrize("m,h,w", [(1, 64, 64), (7, 32, 32)])
def test_combine_kernel(scheme_name, m, h, w):
    scheme = get_scheme(scheme_name)
    x = _rand((m, scheme.n_mults, h, w))
    got = combine_pallas(x, scheme.c_coef, block=32)
    want = combine_ref(x, scheme.c_coef)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mb,m2,k2,n2", [(1, 64, 64, 64), (7, 32, 64, 32), (2, 128, 128, 128)])
def test_strassen1_kernel_vs_ref(mb, m2, k2, n2, dtype):
    aq = _rand((mb, 4, m2, k2), dtype)
    bq = _rand((mb, 4, k2, n2), dtype)
    got = strassen1_matmul_pallas(aq, bq, block_m=32, block_n=32, block_k=32)
    want = strassen1_matmul_ref(aq, bq)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("depth", [1, 2])
@pytest.mark.parametrize("pipeline", [strassen_matmul_stages, strassen_matmul_fused])
def test_full_pipelines_vs_plain_matmul(depth, pipeline):
    a, b = _rand((128, 128)), _rand((128, 128))
    got = pipeline(a, b, depth=depth)
    want = strassen1_full_ref(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=5e-4, rtol=5e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("depth", [1, 2])
def test_fused_vs_ref_dtypes(depth, dtype):
    """Fused leaf vs the pure-jnp oracle across dtypes (bf16 accumulates in
    fp32 inside the kernel, so the oracle's fp32 pipeline is the target)."""
    a, b = _rand((128, 96), dtype), _rand((96, 64), dtype)
    got = strassen_matmul_fused(a, b, depth=depth)
    want = strassen1_full_ref(a, b)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,k,n", [(33, 65, 17), (100, 60, 36), (127, 129, 64)])
def test_fused_padded_odd_shapes(m, k, n, dtype):
    """Odd/non-divisible dims route through the zero-padded fused pipeline
    and stay exact on the unpadded block."""
    a, b = _rand((m, k), dtype), _rand((k, n), dtype)
    for depth in (1, 2):
        got = strassen_matmul_fused_padded(a, b, depth=depth)
        assert got.shape == (m, n) and got.dtype == a.dtype
        want = strassen1_full_ref(a, b)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            atol=TOL[dtype], rtol=TOL[dtype],
        )


def test_fused_padded_noop_on_divisible_shapes():
    a, b = _rand((64, 64)), _rand((64, 64))
    got = strassen_matmul_fused_padded(a, b, depth=2)
    want = strassen_matmul_fused(a, b, depth=2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


def test_fused_winograd_scheme():
    a, b = _rand((64, 64)), _rand((64, 64))
    got = strassen_matmul_fused(a, b, depth=1, scheme_name="winograd")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(strassen1_full_ref(a, b)), atol=5e-4, rtol=5e-4
    )


def test_quadrant_roundtrip_kernel_layout():
    x = _rand((3, 64, 48))
    assert np.allclose(np.asarray(merge_quadrants(split_quadrants(x))), np.asarray(x))

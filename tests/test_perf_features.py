"""Perf-feature correctness: chunkwise mLSTM, grouped MoE, fp8 cache, ring."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored grid shim
    from _propshim import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.models import model as M
from repro.models.xlstm import mlstm_chunkwise, _mlstm_step

RNG = np.random.default_rng(21)


def _rand(shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


# ------------------------------------------------- chunkwise mLSTM == scan
def _mlstm_sequential(q, k, v, ip, fp, state):
    S = q.shape[2]
    hs = []
    st_ = dict(state)
    for t in range(S):
        st_, h = _mlstm_step(st_, (q[:, :, t], k[:, :, t], v[:, :, t], ip[:, :, t], fp[:, :, t]))
        hs.append(h)
    return st_, jnp.stack(hs, axis=2)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_mlstm_chunkwise_exact(chunk):
    B, H, S, dk, dv = 2, 2, 32, 8, 12
    q, k, v = _rand((B, H, S, dk)), _rand((B, H, S, dk)), _rand((B, H, S, dv))
    ip, fp = _rand((B, H, S)) * 2, _rand((B, H, S)) * 2
    state = {
        "C": jnp.zeros((B, H, dk, dv)),
        "n": jnp.zeros((B, H, dk)),
        "m": jnp.full((B, H), -1e30),
    }
    st_seq, h_seq = _mlstm_sequential(q, k, v, ip, fp, state)
    st_ch, h_ch = mlstm_chunkwise(q, k, v, ip, fp, state, chunk)
    np.testing.assert_allclose(np.asarray(h_ch), np.asarray(h_seq), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(st_ch["C"]), np.asarray(st_seq["C"]), atol=5e-5, rtol=5e-5)
    np.testing.assert_allclose(np.asarray(st_ch["m"]), np.asarray(st_seq["m"]), atol=1e-5)


def test_mlstm_chunkwise_carried_state():
    B, H, S, dk, dv = 1, 2, 16, 4, 4
    q, k, v = _rand((B, H, S, dk)), _rand((B, H, S, dk)), _rand((B, H, S, dv))
    ip, fp = _rand((B, H, S)), _rand((B, H, S))
    state0 = {
        "C": jnp.zeros((B, H, dk, dv)),
        "n": jnp.zeros((B, H, dk)),
        "m": jnp.full((B, H), -1e30),
    }
    mid, _ = _mlstm_sequential(q, k, v, ip, fp, state0)
    _, h_seq = _mlstm_sequential(q, k, v, ip, fp, mid)
    _, h_ch = mlstm_chunkwise(q, k, v, ip, fp, mid, 8)
    np.testing.assert_allclose(np.asarray(h_ch), np.asarray(h_seq), atol=5e-5, rtol=5e-5)


def test_mlstm_chunk_config_model_level():
    cfg = get_smoke_config("xlstm_1_3b")
    cfg_ch = dataclasses.replace(cfg, mlstm_chunk=8)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    l1, _ = M.apply_train(params, {"tokens": tokens, "labels": tokens}, cfg)
    l2, _ = M.apply_train(params, {"tokens": tokens, "labels": tokens}, cfg_ch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3, rtol=2e-3)


# ------------------------------------------------- grouped MoE == global
@pytest.mark.parametrize("arch", ["olmoe_1b_7b", "qwen2_moe_a2_7b"])
@pytest.mark.parametrize("ep", [False, True])
def test_grouped_moe_matches_global_with_ample_capacity(arch, ep):
    cfg = dataclasses.replace(get_smoke_config(arch), capacity_factor=4.0)
    cfg_g = dataclasses.replace(cfg, moe_group_dispatch=True, moe_expert_parallel=ep)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    l1, a1 = M.apply_train(params, {"tokens": tokens, "labels": tokens}, cfg)
    l2, a2 = M.apply_train(params, {"tokens": tokens, "labels": tokens}, cfg_g)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-3, rtol=2e-3)
    assert abs(float(a1) - float(a2)) < 1e-4


def test_grouped_moe_grad_flows():
    cfg = dataclasses.replace(
        get_smoke_config("olmoe_1b_7b"), moe_group_dispatch=True, capacity_factor=2.0
    )
    key = jax.random.PRNGKey(2)
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    g = jax.grad(lambda p: M.loss_fn(p, {"tokens": tokens, "labels": tokens}, cfg)[0])(params)
    gnorm = sum(float(jnp.sum(x.astype(jnp.float32) ** 2)) for x in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0


# ------------------------------------------------- quantized KV cache
def test_fp8_cache_decode_close_to_full_precision():
    cfg = get_smoke_config("phi4_mini_3_8b")
    cfg8 = dataclasses.replace(cfg, cache_dtype="float8_e4m3fn")
    key = jax.random.PRNGKey(3)
    params = M.init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)

    outs = {}
    for name, c in (("full", cfg), ("fp8", cfg8)):
        cache = M.init_cache(c, B, S + 4)
        lp, cache = M.apply_prefill(params, {"tokens": tokens}, cache, c)
        outs[name] = lp
        assert bool(jnp.all(jnp.isfinite(lp)))
    # fp8 shifts logits but must preserve the argmax most of the time
    agree = float(jnp.mean(
        (jnp.argmax(outs["full"], -1) == jnp.argmax(outs["fp8"], -1)).astype(jnp.float32)
    ))
    assert agree >= 0.5, agree


def test_fp8_cache_halves_cache_bytes():
    cfg = get_smoke_config("phi4_mini_3_8b")
    cfg8 = dataclasses.replace(cfg, cache_dtype="float8_e4m3fn")
    c_full = M.init_cache(cfg, 2, 64)
    c_fp8 = M.init_cache(cfg8, 2, 64)
    b_full = sum(x.nbytes for x in jax.tree.leaves(c_full))
    b_fp8 = sum(x.nbytes for x in jax.tree.leaves(c_fp8))
    assert b_fp8 < 0.3 * b_full  # fp8 vs fp32 smoke dtype


# ------------------------------------------------- strassen backend in-model
@pytest.mark.parametrize("kind", ["strassen", "winograd", "strassen_fused"])
def test_strassen_backend_model_equivalence(kind):
    from repro.core.backend import MatmulBackend

    cfg = get_smoke_config("internlm2_20b")
    cfg_s = dataclasses.replace(
        cfg, matmul_backend=MatmulBackend(kind=kind, depth=1, min_dim=16)
    )
    key = jax.random.PRNGKey(4)
    params = M.init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 16), 0, cfg.vocab)
    l1, _ = M.apply_train(params, {"tokens": tokens, "labels": tokens}, cfg)
    l2, _ = M.apply_train(params, {"tokens": tokens, "labels": tokens}, cfg_s)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=5e-3, rtol=5e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_property_ring_buffer_decode_matches_full(seed):
    """Ring-buffer local attention == full-cache attention with same window."""
    cfg = get_smoke_config("recurrentgemma_9b")
    key = jax.random.PRNGKey(seed)
    params = M.init_params(cfg, key)
    B, S = 1, 24  # window is 16 -> ring wraps
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    full_logits, _ = M.apply_train(params, {"tokens": tokens, "labels": tokens}, cfg)
    cache = M.init_cache(cfg, B, 40)
    lp, cache = M.apply_prefill(params, {"tokens": tokens}, cache, cfg)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(full_logits[:, -1]), atol=3e-3, rtol=3e-3
    )

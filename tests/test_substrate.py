"""Substrate tests: optimizer, train step, data pipeline, checkpoint, elastic."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored grid shim
    from _propshim import given, settings, strategies as st

from repro.configs import get_smoke_config
from repro.data.pipeline import DataConfig, SyntheticLM, shard_for_host
from repro.optim.adamw import AdamWConfig, cosine_schedule
from repro.runtime.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.runtime.elastic import (
    ElasticError,
    StragglerMonitor,
    plan_mesh,
    rebalance_accum,
)
from repro.training.train_step import init_train_state, make_train_step


def _tiny_setup(accum=1):
    cfg = get_smoke_config("phi4_mini_3_8b")
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)
    key = jax.random.PRNGKey(0)
    state = init_train_state(cfg, opt_cfg, key)
    data = SyntheticLM(cfg, DataConfig(batch=4, seq_len=16, seed=1))
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, accum_steps=accum))
    return cfg, state, data, step_fn


def test_train_step_decreases_loss():
    cfg, state, data, step_fn = _tiny_setup()
    losses = []
    for i in range(10):
        state, metrics = step_fn(state, data(i % 2))  # repeat 2 batches -> memorize
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses
    assert int(state.opt.step) == 10


def test_grad_accumulation_matches_full_batch():
    cfg, state, data, step1 = _tiny_setup(accum=1)
    _, state2, _, step4 = _tiny_setup(accum=4)
    batch = data(0)
    s1, m1 = step1(state, batch)
    s4, m4 = step4(state2, batch)
    # same initial params -> near-identical updated params
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1.params, s4.params,
    )
    assert max(jax.tree.leaves(diffs)) < 5e-3, max(jax.tree.leaves(diffs))


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(cosine_schedule(jnp.asarray(s), cfg)) for s in (0, 5, 10, 55, 100, 200)]
    assert lrs[1] == pytest.approx(0.5, rel=1e-3)  # mid-warmup
    assert lrs[2] == pytest.approx(1.0, rel=1e-3)  # peak
    assert lrs[4] == pytest.approx(0.1, rel=1e-2)  # min ratio
    assert lrs[5] == pytest.approx(0.1, rel=1e-2)  # clamped past end


def test_data_pipeline_deterministic_and_shifted():
    cfg = get_smoke_config("phi4_mini_3_8b")
    pipe = SyntheticLM(cfg, DataConfig(batch=2, seq_len=32, seed=7))
    b1, b2 = pipe(3), pipe(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(pipe(4)["tokens"]), np.asarray(b1["tokens"]))
    # labels are tokens shifted by one
    np.testing.assert_array_equal(
        np.asarray(b1["tokens"][:, 1:]), np.asarray(b1["labels"][:, :-1])
    )


def test_shard_for_host_partitions_exactly():
    for gb, hosts in [(256, 32), (100, 8), (7, 3)]:
        total = sum(shard_for_host(gb, i, hosts) for i in range(hosts))
        assert total == gb


def test_checkpoint_roundtrip(tmp_path):
    cfg, state, data, step_fn = _tiny_setup()
    state, _ = step_fn(state, data(0))
    path = save_pytree(state, str(tmp_path), step=1)
    restored = load_pytree(state, path)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_manager_resume_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep_last=2)
    tree = {"w": jnp.arange(4.0)}
    for s in (1, 2, 3, 4):
        mgr.maybe_save({"w": tree["w"] * s}, s)
    assert mgr.latest_step() == 4
    step, restored = mgr.restore_latest(tree)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(4.0) * 4)
    # gc kept only last 2
    assert len(mgr._steps()) == 2


def test_checkpoint_atomicity_torn_write(tmp_path):
    """A directory without a complete manifest must be ignored on restore."""
    mgr = CheckpointManager(str(tmp_path), save_every=1, keep_last=5)
    tree = {"w": jnp.ones(3)}
    mgr.maybe_save(tree, 1)
    # simulate a torn write: step dir exists but manifest is junk
    os.makedirs(tmp_path / "step_00000002", exist_ok=True)
    with open(tmp_path / "step_00000002" / "manifest.json", "w") as f:
        f.write("{")  # truncated
    assert mgr.latest_step() == 1


def test_plan_mesh_elasticity():
    assert plan_mesh(512, model_parallel=16, pods=2) == ((2, 16, 16), ("pod", "data", "model"))
    assert plan_mesh(256, model_parallel=16) == ((16, 16), ("data", "model"))
    # lose a host (8 devices): data axis absorbs it if divisible
    assert plan_mesh(496, model_parallel=16) == ((31, 16), ("data", "model"))
    with pytest.raises(ElasticError):
        plan_mesh(500, model_parallel=16)


@settings(max_examples=30, deadline=None)
@given(
    gb=st.sampled_from([64, 128, 256]),
    shards=st.integers(min_value=1, max_value=32),
)
def test_property_rebalance_preserves_global_batch(gb, shards):
    accum = rebalance_accum(gb, 128, shards, per_shard_tokens_budget=4096)
    assert accum >= 1
    assert gb % (accum * shards) == 0 or accum == gb


def test_straggler_monitor_flags_sustained_slowdown():
    mon = StragglerMonitor(window=16, threshold=2.0, patience=3)
    import time as _t

    flagged = False
    for i in range(20):
        mon.start_step()
        _t.sleep(0.001 if i < 12 else 0.02)  # 12 fast steps then sustained slow
        flagged = mon.end_step() or flagged
    assert flagged

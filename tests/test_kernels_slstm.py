"""Fused sLSTM sequence kernel vs the scan oracle: shape sweeps + state carry."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.slstm.ops import slstm_seq
from repro.kernels.slstm.ref import slstm_seq_ref

RNG = np.random.default_rng(31)


def _setup(b, s, h, dh):
    wx = jnp.asarray(RNG.standard_normal((b, s, 4, h, dh)), jnp.float32)
    r = jnp.asarray(RNG.standard_normal((4, h, dh, dh)) * 0.3, jnp.float32)
    state = {k: jnp.zeros((b, h, dh)) for k in ("c", "n", "h")}
    state["m"] = jnp.full((b, h, dh), -1e30)
    return wx, r, state


@pytest.mark.parametrize("b,s,h,dh", [(1, 8, 1, 4), (2, 16, 2, 8), (2, 32, 4, 16)])
def test_slstm_kernel_matches_scan(b, s, h, dh):
    wx, r, state = _setup(b, s, h, dh)
    st_ref, hs_ref = slstm_seq_ref(wx, r, state)
    st_k, hs_k = slstm_seq(wx, r, state)
    np.testing.assert_allclose(np.asarray(hs_k), np.asarray(hs_ref), atol=2e-5, rtol=2e-5)
    for key in ("c", "n", "m", "h"):
        np.testing.assert_allclose(
            np.asarray(st_k[key]), np.asarray(st_ref[key]), atol=2e-5, rtol=2e-5,
            err_msg=key,
        )


def test_slstm_kernel_state_carry():
    """Running two halves with carried state == one full pass."""
    wx, r, state = _setup(2, 16, 2, 8)
    st_full, hs_full = slstm_seq(wx, r, state)
    st_mid, hs_a = slstm_seq(wx[:, :8], r, state)
    st_end, hs_b = slstm_seq(wx[:, 8:], r, st_mid)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([hs_a, hs_b], axis=1)),
        np.asarray(hs_full), atol=2e-5, rtol=2e-5,
    )
    np.testing.assert_allclose(np.asarray(st_end["c"]), np.asarray(st_full["c"]), atol=2e-5)

"""Recursive-plan layer: schema algebra round-trips (hypothesis), the
bit-identical matmul plan extraction, registry semantics, and validation."""
import itertools

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # fall back to the vendored grid shim
    from _propshim import given, settings, strategies as st

from repro.blocks import plan as planmod
from repro.blocks import tags
from repro.blocks.plan import (
    BilinearPlan,
    DataflowPlan,
    SPIN_INVERSE,
    Step,
    TRSM_LOWER,
    TRSM_UPPER,
    apply_combine_schema,
    apply_divide_schema,
    as_bilinear_plan,
    expand_terms,
    get_plan,
    matmul_plan,
    plan_names,
    register_plan,
    select_part,
)
from repro.core.coefficients import get_scheme, leaf_tag_path


# -- schema round-trips (property) ----------------------------------------
#
# Strategy: build an integer *unimodular* divide schema as a product of
# elementary row operations on I_4 and track its exact integer inverse.
# On integer-valued f32 inputs (exact in f32 well below 2**24) the
# divide -> combine round trip is then bit-exact, which is precisely the
# algebraic well-formedness contract the scheduler relies on.


def _elementary_schema(seed: int, n_ops: int):
    """(divide, combine) integer 4x4 tables with combine @ divide == I."""
    rng = np.random.default_rng(seed)
    fwd = np.eye(4, dtype=np.float64)
    ops = []
    for _ in range(n_ops):
        i, j = rng.choice(4, size=2, replace=False)
        c = float(rng.choice([-2, -1, 1, 2]))
        fwd[i] += c * fwd[j]
        ops.append((int(i), int(j), c))
    inv = np.eye(4, dtype=np.float64)
    for i, j, c in reversed(ops):
        inv[i] -= c * inv[j]
    return fwd, inv


@given(
    seed=st.integers(0, 2**20),
    n_ops=st.integers(0, 6),
    half=st.sampled_from([1, 2, 4, 8]),
)
@settings(max_examples=20, deadline=None)
def test_integer_schema_round_trip_is_bit_exact(seed, n_ops, half):
    divide, combine = _elementary_schema(seed, n_ops)
    assert np.array_equal(combine @ divide, np.eye(4))
    rng = np.random.default_rng(seed ^ 0x5EED)
    x = rng.integers(-64, 64, size=(2 * half, 2 * half)).astype(np.float32)
    children = apply_divide_schema(x, divide.astype(np.float32))
    back = apply_combine_schema(children, combine.astype(np.float32))
    # Bit-exact, not allclose: elementary integer schemas on
    # integer-valued f32 inputs never round.
    assert back.dtype == x.dtype
    assert np.array_equal(back, x)


@given(seed=st.integers(0, 2**20), depth=st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_expand_terms_matches_repeated_divide(seed, depth):
    """The closed-form tag expansion equals actually dividing ``depth`` times."""
    scheme = get_scheme("strassen")
    rng = np.random.default_rng(seed)
    half = 1 << depth
    x = rng.integers(-8, 8, size=(2 * half, 2 * half)).astype(np.float32)
    m_path = tuple(int(d) for d in rng.integers(0, scheme.rank, size=depth))
    # Walk the divide stages level by level.
    block = x
    for digit in m_path:
        block = apply_divide_schema(block, scheme.a_coef)[digit]
    # Closed form: signed sum of root quadrant-path blocks.
    acc = np.zeros_like(block)
    for q_path, coef in expand_terms(m_path, scheme.a_coef):
        sub = x
        for q in q_path:
            sub = planmod._quadrants(sub)[q]
        acc = acc + np.float32(coef) * sub
    assert np.array_equal(acc, block)


# -- bit-identical extraction of the matmul plans --------------------------


def _reference_terms(coef, m_path):
    """Pre-refactor tensor-product expansion, reimplemented inline."""
    terms = [((), 1.0)]
    for digit in m_path:
        terms = [
            (qp + (q,), c * float(coef[digit, q]))
            for qp, c in terms
            for q in range(4)
            if float(coef[digit, q]) != 0.0
        ]
    return terms


@pytest.mark.parametrize("scheme_name", ["strassen", "winograd", "naive8"])
def test_matmul_plan_reproduces_tag_streams_verbatim(scheme_name):
    """Every leaf path's operand/combine term stream is unchanged by the
    plan refactor — same order, same paths, same coefficients."""
    scheme = get_scheme(scheme_name)
    p = matmul_plan(scheme)
    depth = 2
    for m_path in itertools.product(range(scheme.rank), repeat=depth):
        for side, coef, operand in (
            ("a", scheme.a_coef, "A"),
            ("b", scheme.b_coef, "B"),
        ):
            want = _reference_terms(coef, m_path)
            assert p.operand_terms(m_path, operand) == want
            assert tags.operand_terms(m_path, scheme, side) == want
        want_c = _reference_terms(scheme.c_coef.T, m_path)
        assert p.combine_terms(m_path) == want_c
        assert tags.combine_terms(m_path, scheme) == want_c


def test_strassen_leaf_tag_paths_enumerate_plan_rank():
    scheme = get_scheme("strassen")
    p = matmul_plan(scheme)
    depth = 2
    paths = [leaf_tag_path(i, depth) for i in range(scheme.rank**depth)]
    assert sorted(paths) == sorted(
        itertools.product(range(p.rank), repeat=depth)
    )


def test_matmul_plan_shares_scheme_arrays():
    """Shared, not copied: the guarantee behind bit-identical refactor."""
    scheme = get_scheme("strassen")
    p = matmul_plan("strassen")
    assert p.divide_coef["A"] is scheme.a_coef
    assert p.divide_coef["B"] is scheme.b_coef
    assert p.combine_coef is scheme.c_coef
    assert p.scheme is scheme


def test_scheduler_accepts_plan_and_matches_scheme_path():
    from repro.blocks.scheduler import strassen_oot_matmul

    rng = np.random.default_rng(0)
    a = rng.standard_normal((128, 128)).astype(np.float32)
    b = rng.standard_normal((128, 128)).astype(np.float32)
    kwargs = dict(depth=2, budget_bytes=1 << 20)
    via_scheme, _ = strassen_oot_matmul(a, b, scheme="strassen", **kwargs)
    via_plan, stats = strassen_oot_matmul(
        a, b, plan=matmul_plan("strassen"), **kwargs
    )
    assert np.array_equal(via_scheme, via_plan)
    assert stats.op == "matmul"


# -- registry & coercion ---------------------------------------------------


def test_registry_has_matmul_and_solver_plans():
    names = plan_names()
    for want in (
        "strassen", "winograd", "naive8",
        "spin_inverse", "spin_trsm_lower", "spin_trsm_upper",
    ):
        assert want in names
    assert get_plan("spin_inverse") is SPIN_INVERSE
    assert get_plan("spin_trsm_lower") is TRSM_LOWER
    assert get_plan("spin_trsm_upper") is TRSM_UPPER


def test_get_plan_unknown_name():
    with pytest.raises(ValueError, match="unknown recursive plan"):
        get_plan("lu_decomposition")


def test_as_bilinear_plan_rejects_dataflow_plans():
    with pytest.raises(ValueError, match="not wave-schedulable"):
        as_bilinear_plan("spin_inverse")


def test_as_bilinear_plan_accepts_scheme_and_name():
    scheme = get_scheme("winograd")
    assert as_bilinear_plan("winograd").scheme is scheme
    assert as_bilinear_plan(scheme).scheme is scheme
    p = matmul_plan("naive8")
    assert as_bilinear_plan(p) is p


# -- validation ------------------------------------------------------------


def test_bilinear_plan_validate_rejects_bad_shapes():
    scheme = get_scheme("strassen")
    bad = BilinearPlan(
        name="bad", op="matmul", operands=("A", "B"), result="C",
        leaf_kind="matmul", scheme=scheme,
        divide_coef={"A": scheme.a_coef, "B": scheme.b_coef[:, :3]},
        combine_coef=scheme.c_coef,
    )
    with pytest.raises(ValueError, match="divide schema"):
        bad.validate()
    mismatched = BilinearPlan(
        name="bad2", op="matmul", operands=("A", "B"), result="C",
        leaf_kind="matmul", scheme=scheme,
        divide_coef={"A": scheme.a_coef},
        combine_coef=scheme.c_coef,
    )
    with pytest.raises(ValueError, match="must match operands"):
        mismatched.validate()


def test_dataflow_plan_validate_rejects_undefined_symbols():
    bad = DataflowPlan(
        name="bad_flow", op="inverse", operands=("A",), result="X",
        leaf_kind="inv",
        divide=(("A11", ("A", "q0")),),
        program=(Step("matmul", out="T", args=("A11", "GHOST")),),
        combine=(("q0", "T"),),
    )
    with pytest.raises(ValueError, match="undefined symbols"):
        bad.validate()
    # register_plan validates before inserting, so the name never lands.
    with pytest.raises(ValueError, match="undefined symbols"):
        register_plan(bad)
    with pytest.raises(ValueError, match="unknown recursive plan"):
        get_plan("bad_flow")


def test_dataflow_plan_validate_rejects_bad_selector():
    bad = DataflowPlan(
        name="bad_sel", op="inverse", operands=("A",), result="X",
        leaf_kind="inv",
        divide=(("A11", ("A", "q7")),),
        program=(),
        combine=(("q0", None),),
    )
    with pytest.raises(ValueError, match="unknown .*selector"):
        bad.validate()


def test_spin_plans_are_well_formed():
    for p in (SPIN_INVERSE, TRSM_LOWER, TRSM_UPPER):
        p.validate()
    assert SPIN_INVERSE.recursions == 2
    assert TRSM_LOWER.recursions == 2
    assert SPIN_INVERSE.leaf_kind == "inv"
    assert TRSM_LOWER.operands == ("L", "B")


def test_select_part_quadrants_and_row_halves():
    x = np.arange(16, dtype=np.float32).reshape(4, 4)
    assert np.array_equal(select_part(x, "q0"), x[:2, :2])
    assert np.array_equal(select_part(x, "q3"), x[2:, 2:])
    assert np.array_equal(select_part(x, "r1"), x[2:])
    with pytest.raises(ValueError, match="unknown part selector"):
        select_part(x, "z9")


def test_spin_inverse_program_algebra_on_dense_blocks():
    """Execute SPIN_INVERSE's step program with plain numpy at one level
    and compare against the dense inverse — the plan *description* is
    the algorithm, independent of any scheduler."""
    rng = np.random.default_rng(3)
    n = 64
    g = rng.standard_normal((n, n)).astype(np.float64)
    a = g @ g.T / n + 2.0 * np.eye(n)
    syms = {
        sym: select_part(a, sel).copy()
        for sym, (_, sel) in SPIN_INVERSE.divide
    }
    for step in SPIN_INVERSE.program:
        if step.kind == "recurse":
            syms[step.out] = np.linalg.inv(syms[step.args[0]])
        elif step.kind == "matmul":
            syms[step.out] = step.alpha * (syms[step.args[0]] @ syms[step.args[1]])
        else:
            syms[step.out] = sum(c * syms[s] for s, c in step.terms)
    out = np.zeros_like(a)
    for sel, sym in SPIN_INVERSE.combine:
        select_part(out, sel)[...] = syms[sym]
    np.testing.assert_allclose(out, np.linalg.inv(a), rtol=0, atol=1e-9)

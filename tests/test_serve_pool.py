"""Paged KV pool: free-list accounting, layout classification, round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.serving.kv_pool import SCRATCH_PAGE, CacheLayout, PagePool, PoolExhausted


# ------------------------------------------------------------- PagePool


def test_pool_alloc_free_roundtrip():
    pool = PagePool(capacity=8, page_size=16)
    assert pool.available == 8 and pool.in_use == 0
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert len(a) == 3 and len(b) == 2
    assert not set(a) & set(b)
    assert SCRATCH_PAGE not in a + b  # id 0 is never handed out
    assert pool.available == 3 and pool.in_use == 5
    pool.free(a)
    assert pool.available == 6 and pool.in_use == 2
    c = pool.alloc(6)  # reuses the freed pages
    assert pool.available == 0
    pool.free(b + c)
    assert pool.available == 8 and pool.in_use == 0


def test_pool_exhaustion_raises_and_leaves_state_intact():
    pool = PagePool(capacity=4, page_size=16)
    pool.alloc(3)
    with pytest.raises(PoolExhausted):
        pool.alloc(2)
    assert pool.available == 1  # failed alloc took nothing


def test_pool_double_free_guard():
    pool = PagePool(capacity=4, page_size=16)
    pages = pool.alloc(2)
    pool.free(pages)
    with pytest.raises(ValueError):
        pool.free(pages)
    with pytest.raises(ValueError):
        pool.free([SCRATCH_PAGE])


def test_pages_for_tokens():
    pool = PagePool(capacity=4, page_size=16)
    assert pool.pages_for_tokens(0) == 0
    assert pool.pages_for_tokens(1) == 1
    assert pool.pages_for_tokens(16) == 1
    assert pool.pages_for_tokens(17) == 2


# ----------------------------------------------------------- CacheLayout


def _layout(name, **kw):
    cfg = get_smoke_config(name)
    args = dict(cfg=cfg, n_slots=2, page_size=8, max_seq=32)
    args.update(kw)
    return CacheLayout(**args)


def test_layout_classifies_by_block_pattern():
    # phi4 smoke: pure full attention -> every node paged
    lay = _layout("phi4_mini_3_8b")
    assert lay.has_paged
    assert all(n.paged for n in lay.nodes)
    assert all(n.kind == "attn" for n in lay.nodes)

    # recurrentgemma smoke: rglru + windowed local_attn -> nothing paged
    # (ring buffers and recurrent state stay slot-indexed dense)
    lay = _layout("recurrentgemma_9b")
    cfg = lay.cfg
    assert cfg.local_window > 0
    assert not any(n.paged for n in lay.nodes)
    kinds = {n.kind for n in lay.nodes}
    assert "rglru" in kinds and "local_attn" in kinds

    # xlstm smoke: recurrent only -> no paged nodes at all
    lay = _layout("xlstm_1_3b")
    assert not lay.has_paged
    assert {n.kind for n in lay.nodes} <= {"mlstm", "slstm"}


def test_layout_node_count_covers_all_layers():
    for name in ("phi4_mini_3_8b", "recurrentgemma_9b", "xlstm_1_3b"):
        lay = _layout(name)
        cfg = lay.cfg
        period = len(cfg.block_pattern)
        n_groups = cfg.n_layers // period
        # stacked nodes carry n_groups layers each; tail nodes one each
        covered = sum(n_groups if n.stacked else 1 for n in lay.nodes)
        assert covered == cfg.n_layers


def test_gather_scatter_insert_roundtrip():
    """Prefill -> insert -> gather must reproduce the dense cache, and
    scatter_token must land one column in the right page at the right
    offset while routing dead slots to the scratch page."""
    lay = _layout("phi4_mini_3_8b", n_slots=2, page_size=8, max_seq=32)
    cfg = lay.cfg
    pool = PagePool(capacity=lay.table_width * 2, page_size=8)
    kv = lay.init_kv_state(pool.capacity)

    # fake a filled prefill cache: capacity 16 = 2 pages, distinct values
    capacity = 16
    pre = lay.init_prefill_cache(capacity)
    rng = np.random.default_rng(0)
    pre = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype)
        if x.ndim > 1
        else x,
        pre,
    )
    pre["pos"] = jnp.asarray(12, jnp.int32)  # 12 real tokens in 2 pages

    pages = pool.alloc(2)
    kv = lay.insert_request(kv, pre, jnp.int32(0), jnp.asarray(pages, jnp.int32))
    table = jnp.zeros((2, lay.table_width), jnp.int32)
    table = table.at[0, :2].set(jnp.asarray(pages))

    pos = jnp.asarray([12, 0], jnp.int32)
    dense = lay.gather(kv, table, pos, bucket_pages=2)
    # slot 0's gathered view equals the prefill cache contents
    for node in lay.nodes:
        sub_pre = pre[node.where][node.key]
        sub_dense = dense[node.where][node.key]
        for name in ("k", "v"):
            got = np.asarray(sub_dense[name])
            want = np.asarray(sub_pre[name])
            if node.stacked:
                np.testing.assert_allclose(got[:, 0], want[:, 0], rtol=0, atol=0)
            else:
                np.testing.assert_allclose(got[0], want[0], rtol=0, atol=0)

    # scatter one token at pos 12 (page 1, offset 4) for live slot 0;
    # slot 1 is dead and must only touch the scratch page
    new_dense = jax.tree.map(lambda x: x + 1.0 if x.ndim > 1 else x, dense)
    new_dense["pos"] = pos + 1
    kv2 = lay.scatter_token(kv, new_dense, table, pos, jnp.asarray([True, False]))
    for node in lay.nodes:
        old_sub = kv[node.where][node.key]
        new_sub = kv2[node.where][node.key]
        for name in ("k", "v"):
            o, n = np.asarray(old_sub[name]), np.asarray(new_sub[name])
            if node.stacked:
                page_axis_old = o[:, pages[1]]
                page_axis_new = n[:, pages[1]]
                # only offset 4 of slot 0's second page changed
                diff = page_axis_new != page_axis_old
                assert diff.any()
                assert not diff[:, :, :4].any() and not diff[:, :, 5:].any()
                # scratch page took slot 1's (masked) write; real pages of
                # other slots untouched
                untouched = [p for p in range(1, o.shape[1]) if p != pages[1]]
                np.testing.assert_array_equal(n[:, untouched], o[:, untouched])
            else:
                diff = n[pages[1]] != o[pages[1]]
                assert diff.any()
                assert not diff[:, :4].any() and not diff[:, 5:].any()
                untouched = [p for p in range(1, o.shape[0]) if p != pages[1]]
                np.testing.assert_array_equal(n[untouched], o[untouched])


def test_scatter_freezes_dead_slot_state():
    """Slot-indexed (non-paged) state must keep dead slots bit-identical."""
    lay = _layout("xlstm_1_3b", n_slots=3, page_size=8, max_seq=32)
    kv = lay.init_kv_state(0)
    rng = np.random.default_rng(1)
    kv = jax.tree.map(
        lambda x: jnp.asarray(rng.normal(size=x.shape), x.dtype), kv
    )
    new = jax.tree.map(lambda x: x + 1.0, kv)
    new_model = lay._as_model_cache(new, jnp.asarray([1, 1, 1], jnp.int32))
    live = jnp.asarray([True, False, True])
    out = lay.scatter_token(kv, new_model, jnp.zeros((3, 4), jnp.int32),
                            jnp.asarray([0, 0, 0], jnp.int32), live)
    for node in lay.nodes:
        o = kv[node.where][node.key]
        n = out[node.where][node.key]
        for ol, nl in zip(jax.tree.leaves(o), jax.tree.leaves(n)):
            ol, nl = np.asarray(ol), np.asarray(nl)
            if node.stacked:
                np.testing.assert_array_equal(nl[:, 1], ol[:, 1])  # dead frozen
                np.testing.assert_array_equal(nl[:, 0], ol[:, 0] + 1.0)
            else:
                np.testing.assert_array_equal(nl[1], ol[1])
                np.testing.assert_array_equal(nl[0], ol[0] + 1.0)

"""JAX version compatibility shims.

The repo targets the current JAX API (``jax.shard_map``,
``jax.sharding.AxisType``, dict-returning ``Compiled.cost_analysis``) but
must also run on jax 0.4.x, where shard_map lives in ``jax.experimental``,
meshes have no axis types, and cost_analysis returns a one-element list.
Everything that touches one of those surfaces goes through this module so
the version probe happens in exactly one place.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Sequence

import jax

__all__ = [
    "make_mesh",
    "shard_map",
    "compiled_cost_analysis",
    "has_axis_types",
    "pallas_leaf_mode",
]

# jax < 0.5 defaults to the legacy non-partitionable threefry, whose values
# change when the consuming computation is sharded under GSPMD — a jitted
# sharded init then disagrees with the same init on one device. Newer jax
# defaults this flag on; pin it so both versions behave identically.
try:
    jax.config.update("jax_threefry_partitionable", True)
except Exception:  # flag removed once the legacy path is gone
    pass

# jax >= 0.5 exposes explicit/auto axis types; 0.4.x meshes are untyped
# (equivalent to Auto everywhere, which is what this repo uses).
_AXIS_TYPE = getattr(jax.sharding, "AxisType", None)


def has_axis_types() -> bool:
    return _AXIS_TYPE is not None


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str], **kwargs):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if _AXIS_TYPE is not None:
        kwargs.setdefault("axis_types", (_AXIS_TYPE.Auto,) * len(tuple(axis_names)))
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs):
    """Replication-unchecked shard_map across the 0.4/0.5+ API split.

    The Strassen shardmap bodies psum partial products whose replication
    XLA cannot infer, so both the new ``check_vma`` and the old
    ``check_rep`` verifier must be off.
    """
    new_sm = getattr(jax, "shard_map", None)
    if new_sm is not None:
        return new_sm(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_vma=False
        )
    from jax.experimental.shard_map import shard_map as old_sm

    return old_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False)


@functools.lru_cache(None)
def pallas_leaf_mode() -> str:
    """How the fused Strassen Pallas leaf can run on this host.

    Returns one of:
      'compiled'  — a TPU backend is present; the kernel compiles via Mosaic.
      'interpret' — no TPU, but interpret-mode ``pallas_call`` works (CPU
                    hosts, including host-platform multi-device test meshes).
      'none'      — pallas is unavailable or broken in this jax build;
                    callers must use the jnp reference path.

    The probe actually executes a tiny fused kernel rather than sniffing
    versions: autotune enumeration gates ``strassen_fused`` candidates on
    this answer, so "the leaf compiles" must mean a real end-to-end run.
    Cached per process (device topology is fixed after jax init).
    """
    try:
        import jax.numpy as jnp

        from repro.kernels.strassen.strassen import strassen1_matmul_pallas

        on_tpu = jax.default_backend() == "tpu"
        x = jnp.ones((1, 4, 128, 128), jnp.float32)
        jax.block_until_ready(
            strassen1_matmul_pallas(
                x, x, block_m=128, block_n=128, block_k=128, interpret=not on_tpu
            )
        )
        return "compiled" if on_tpu else "interpret"
    except Exception:
        return "none"


def compiled_cost_analysis(compiled: Any) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` as a flat dict on every jax version.

    jax 0.4.x returns ``[{...}]`` (one dict per partition), newer versions
    return the dict directly, and some backends return None.
    """
    cost: Optional[Any] = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)

"""Shape-aware autotuning dispatcher behind ``MatmulBackend(kind="auto")``.

The paper's core empirical result (§V-C) is a *crossover*: Strassen's
7-multiplication scheme only beats the naive path once matrix dims are
large relative to the leaf block, and the §IV stage-wise model predicts
where. This module is the JAX analogue of that calibration + prediction
loop, turned into a dispatcher:

1. :func:`calibrate` runs two on-device micro-benchmarks — a leaf batched
   matmul and a divide-level einsum, mirroring the paper's implicit
   block-matmul / block-add calibration — and fits the environment
   constants ``t_flop`` (seconds per scalar multiply-add) and ``t_elem``
   (seconds per element through a divide/combine level).

2. :func:`enumerate_candidates` lists every strategy that can legally run
   a given (M, K, N): the naive XLA matmul, batched-BFS Strassen/Winograd
   at each usable depth, and — when a mesh is supplied — every registered
   strategy in :data:`repro.core.distributed.MESH_STRATEGIES` whose mesh
   requirement holds.

3. :func:`predict_seconds` costs each candidate with the calibrated
   stage model (divide/combine element traffic * t_elem + leaf flops *
   t_flop / leaf parallelism); :func:`autotune` picks the argmin, or with
   ``measure=True`` times the top-k candidates on device and records the
   measured winner.

4. :class:`TuningCache` persists decisions as JSON keyed by
   (shape, dtype, device kind+count, scheme set, min_dim, max_depth, and
   optionally a call-site tag), so jit-traced call sites resolve
   statically from the cache on reuse — no re-calibration, no
   re-measurement. Call-site tags let same-shape projections (e.g. a QKV
   and an MLP projection of equal width) diverge under measured mode.

Four constants, three regimes: ``t_flop``/``t_elem`` come from intra-device
micro-benchmarks; ``t_coll`` is fit separately by
:func:`calibrate_collective` (an all-gather + reduce-scatter round trip
over every addressable device) and prices the *interconnect* element
traffic of the mesh strategies — divide/combine resharding, combine psums,
SUMMA panel broadcasts; ``t_h2d`` is fit by :func:`calibrate_h2d` (a
device_put + fetch round trip) and prices the *host<->device staging*
traffic of the out-of-core ``strassen_oot`` family
(:mod:`repro.blocks`), whose candidates enumerate when the caller passes
a device-memory budget. Every resolution is logged to the process
:class:`Telemetry` (cache hit/miss, chosen kind, predicted-vs-measured
seconds), which the serving engine exposes in its stats and
``benchmarks/autotune_sweep.py`` dumps. Real-TPU measured-mode calibration
remains a ROADMAP follow-on.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import tempfile
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.coefficients import get_scheme
from repro.core.strassen import divide_level, strassen_matmul
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs_tracer

__all__ = [
    "Candidate",
    "Decision",
    "Calibration",
    "TuningCache",
    "Telemetry",
    "TelemetryEvent",
    "calibrate",
    "calibrate_collective",
    "calibrate_h2d",
    "get_calibration",
    "calibration_snapshot",
    "get_telemetry",
    "reset_telemetry",
    "enumerate_candidates",
    "predict_seconds",
    "predict_cost_terms",
    "measure_seconds",
    "execute",
    "autotune",
    "autotune_solver",
    "predict_solver_terms",
    "predict_solver_seconds",
    "cache_key",
    "model_call_sites",
    "warm_for_model",
]

# Local (single-program) strategies the backend can dispatch without a mesh.
LOCAL_SCHEMES: Tuple[str, ...] = ("strassen", "winograd")
# The Pallas fused-leaf pipeline: local, but gated on the leaf running
# (compat.pallas_leaf_mode) rather than always-legal like the einsum BFS.
FUSED_KIND = "strassen_fused"
# The out-of-core tagged-block pipeline (repro.blocks): host-resident
# operands staged through device memory in budgeted waves. Enumerated only
# when the caller supplies a device-memory budget (``oot_budget``).
OOT_KIND = "strassen_oot"

# Fraction of the overlappable h2d traffic the async wave pipeline still
# exposes: the pipeline fill (first wave's stage has nothing to hide
# behind) and drain (last fetch) bubbles, roughly one wave each way out of
# the ~8 the scheduler needs before fill/drain amortizes. Used by
# predict_cost_terms when ``oot_overlap`` is on.
OOT_OVERLAP_EXPOSED_FRACTION = 0.125


def _oot_pipeline_fits(
    m: int, k: int, n: int, depth: int, dtype, oot_budget: Optional[int]
) -> bool:
    """Whether the oot scheduler can actually run its async pipeline.

    The 2-deep wave pipeline needs one pipelined wave slot
    (:func:`repro.blocks.scheduler.pipelined_leaf_bytes`) inside the
    budget at this depth; with less room the scheduler silently degrades
    to synchronous staging, so predictions must not take the overlap
    discount. A ``None``/0 budget means :func:`execute` will default the
    budget to exactly one pipelined slot, so the pipeline runs.
    """
    if not oot_budget:
        return True
    from repro.blocks.scheduler import pipelined_leaf_bytes

    return pipelined_leaf_bytes(m, k, n, depth, dtype) <= oot_budget


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One executable strategy instance for a fixed (M, K, N)."""

    kind: str  # 'naive' | scheme name (local BFS) | registered mesh strategy
    scheme: str = "strassen"
    depth: int = 0

    @property
    def is_naive(self) -> bool:
        return self.kind == "naive"

    @property
    def is_local(self) -> bool:
        return self.kind in ("naive", FUSED_KIND) + LOCAL_SCHEMES


@dataclasses.dataclass(frozen=True)
class Decision:
    """A routing decision plus the evidence it was made on."""

    kind: str
    scheme: str
    depth: int
    predicted_s: float
    measured_s: Optional[float] = None
    source: str = "predicted"  # predicted | measured | cache

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict) -> "Decision":
        return Decision(**d)

    @property
    def candidate(self) -> Candidate:
        return Candidate(kind=self.kind, scheme=self.scheme, depth=self.depth)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-environment constants, the JAX analogue of the paper's §IV fit."""

    t_flop: float  # seconds per scalar multiply-add in the leaf matmul
    t_elem: float  # seconds per element through a divide/combine einsum
    device_kind: str = "cpu"
    device_count: int = 1
    # seconds per element through an interconnect collective (all-gather /
    # reduce-scatter); 0.0 means "not calibrated" (single device or a
    # pre-t_coll cache) and predictions fall back to t_elem, the old model.
    t_coll: float = 0.0
    # seconds per element through host<->device staging (device_put + fetch
    # round trip) — prices the out-of-core pipeline's leaf-wave traffic.
    # 0.0 means "not calibrated" (pre-t_h2d cache); falls back to t_elem.
    t_h2d: float = 0.0

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @staticmethod
    def from_dict(d: Dict) -> "Calibration":
        return Calibration(**d)


def _time_best(fn, repeats: int = 3) -> float:
    """Best-of-N wall clock for a blocking thunk (compile excluded by warmup)."""
    fn()  # warmup / compile
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate_collective(sample_dim: int = 512, repeats: int = 3) -> float:
    """Fit ``t_coll`` from an all-gather + reduce-scatter micro-benchmark.

    A row-sharded (devices * rows, sample_dim) f32 array makes one
    all-gather and one reduce-scatter round trip over a 1-D mesh of every
    addressable device — the two collectives GSPMD lowers the mesh
    strategies' divide/combine reshards and combine psums into. The fit is
    seconds per element through a collective, the interconnect analogue of
    ``t_elem`` (which measures an intra-device einsum pass and badly
    underprices cross-chip traffic). Returns 0.0 on a single device.
    """
    d = jax.device_count()
    if d < 2:
        return 0.0
    from jax.sharding import PartitionSpec as P

    from repro.core.compat import make_mesh, shard_map

    mesh = make_mesh((d,), ("coll",))
    rows = max(1, sample_dim // d) * d
    x = jnp.ones((rows, sample_dim), jnp.float32)

    def body(x_loc):
        g = jax.lax.all_gather(x_loc, "coll", tiled=True)
        return jax.lax.psum_scatter(g, "coll", scatter_dimension=0, tiled=True)

    fn = jax.jit(
        shard_map(body, mesh=mesh, in_specs=(P("coll", None),), out_specs=P("coll", None))
    )
    t = _time_best(lambda: jax.block_until_ready(fn(x)), repeats)
    # Two full passes of the array through the interconnect (gather + scatter).
    return t / (2.0 * rows * sample_dim)


def calibrate_h2d(sample_dim: int = 1024, repeats: int = 3) -> float:
    """Fit ``t_h2d`` from a host->device + device->host staging round trip.

    One ``jax.device_put`` of a host f32 array plus one ``np.asarray``
    fetch — exactly the per-leaf traffic of the out-of-core scheduler's
    staging waves (operands up, product down). The fit is seconds per
    element through the host<->device boundary, the PCIe/ICI analogue of
    ``t_elem``. On hosts where the "device" is host RAM (CPU jax) this is
    close to a memcpy — correctly tiny, so the model only penalizes
    staging where staging actually costs.
    """
    import numpy as np

    x = np.ones((sample_dim, sample_dim), np.float32)
    # A jitted identity, not a bare device_put: calibration can trigger at
    # jit-trace time (resolve_auto runs while a train step traces), and
    # device_put binds under the ambient trace — a jit call with concrete
    # args escapes it, like the other micro-benchmarks.
    identity = jax.jit(lambda v: v)

    def roundtrip():
        dev = identity(x)
        jax.block_until_ready(dev)
        np.asarray(dev)

    t = _time_best(roundtrip, repeats)
    # One pass up, one pass down.
    return t / (2.0 * sample_dim * sample_dim)


def calibrate(sample_dim: int = 256, repeats: int = 3) -> Calibration:
    """Fit (t_flop, t_elem, t_coll) from on-device micro-benchmarks.

    Leaf benchmark: a rank-7 batched matmul — exactly the shape of the BFS
    leaf stage. Divide benchmark: one :func:`divide_level` einsum — exactly
    the divide/combine stage. Both mirror the paper's implicit calibration
    (it plots theory and experiment in matching units). The collective
    benchmark (:func:`calibrate_collective`) fits the separate interconnect
    constant the mesh-strategy terms use.
    """
    d = sample_dim
    scheme = get_scheme("strassen")
    rank = scheme.n_mults
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (rank, d, d), jnp.float32)
    b = jax.random.normal(key, (rank, d, d), jnp.float32)

    leaf = jax.jit(lambda x, y: jnp.einsum("mij,mjk->mik", x, y))
    t_leaf = _time_best(lambda: jax.block_until_ready(leaf(a, b)), repeats)
    t_flop = t_leaf / (rank * 2.0 * d**3)

    coef = jnp.asarray(scheme.a_coef)
    div = jax.jit(lambda x: divide_level(x, coef))
    t_div = _time_best(lambda: jax.block_until_ready(div(a)), repeats)
    # divide_level: (rank, d, d) -> (rank*rank, d/2, d/2) output elements.
    out_elems = rank * rank * (d // 2) * (d // 2)
    t_elem = t_div / out_elems

    dev = jax.devices()[0]
    return Calibration(
        t_flop=float(t_flop),
        t_elem=float(t_elem),
        device_kind=dev.platform,
        device_count=jax.device_count(),
        t_coll=float(calibrate_collective(repeats=repeats)),
        t_h2d=float(calibrate_h2d(repeats=repeats)),
    )


_CALIBRATION: Optional[Calibration] = None


def get_calibration() -> Calibration:
    """Process-cached calibration (one micro-benchmark pair per process).

    Runs under ``ensure_compile_time_eval``: the first resolution usually
    fires at jit-trace time (resolve_auto inside a traced train step, even
    inside scan bodies), where the micro-benchmarks' jit/device_put calls
    would otherwise stage into the ambient trace instead of executing.
    """
    global _CALIBRATION
    if _CALIBRATION is None:
        with jax.ensure_compile_time_eval():
            _CALIBRATION = calibrate()
    return _CALIBRATION


def calibration_snapshot() -> Optional[Dict]:
    """The current calibration as a dict, or None if none has run yet.

    Never triggers the micro-benchmarks — stats surfaces (e.g.
    ``Engine.autotune_stats``) use this to report t_flop/t_elem/t_coll/
    t_h2d without paying device time on an engine that resolved every
    decision from a warm cache.
    """
    return _CALIBRATION.to_dict() if _CALIBRATION is not None else None


# --------------------------------------------------------------------------
# Candidate enumeration
# --------------------------------------------------------------------------


def _usable_depth(m: int, k: int, n: int, depth: int, min_dim: int) -> bool:
    """depth levels are usable iff dims stay even and above the crossover floor
    at every level — the same rule as MatmulBackend.effective_depth."""
    for _ in range(depth):
        if m % 2 or k % 2 or n % 2 or min(m, k, n) < min_dim:
            return False
        m, k, n = m // 2, k // 2, n // 2
    return depth > 0


def enumerate_candidates(
    m: int,
    k: int,
    n: int,
    *,
    schemes: Sequence[str] = LOCAL_SCHEMES,
    max_depth: int = 3,
    min_dim: int = 1024,
    mesh=None,
    oot_budget: Optional[int] = None,
    dtype=jnp.float32,
) -> List[Candidate]:
    """All strategies that can legally run this shape (naive always can).

    ``strassen_fused`` (the Pallas fused-leaf pipeline) enumerates whenever
    the leaf actually runs on this host — compiled on TPU, interpret mode
    on CPU — per :func:`repro.core.compat.pallas_leaf_mode`.

    ``oot_budget`` (device bytes) enables the ``strassen_oot`` out-of-core
    family: one candidate per scheme at every depth whose single leaf fits
    the budget — including depths the in-core rules reject (odd dims: the
    block runtime pads), which is the whole point of the pipeline.
    """
    from repro.core import compat

    cands = [Candidate(kind="naive")]
    depths = [d for d in range(1, max_depth + 1) if _usable_depth(m, k, n, d, min_dim)]
    for scheme in schemes:
        for d in depths:
            cands.append(Candidate(kind=scheme, scheme=scheme, depth=d))
    if depths and "strassen" in schemes and compat.pallas_leaf_mode() != "none":
        for d in depths:
            cands.append(Candidate(kind=FUSED_KIND, scheme="strassen", depth=d))
    if mesh is not None and depths:
        from repro.core.distributed import available_strategies

        for scheme in schemes:
            for name in available_strategies(mesh, scheme):
                if name.startswith("strassen_shardmap"):
                    # explicit one-level renditions
                    cands.append(Candidate(kind=name, scheme=scheme, depth=1))
                else:
                    for d in depths:
                        cands.append(Candidate(kind=name, scheme=scheme, depth=d))
    if oot_budget:
        from repro.blocks.scheduler import leaf_bytes, min_depth_for_budget

        # A dense on-device multiply needs A + B + C resident at once.
        dense_bytes = (m * k + k * n + m * n) * jnp.dtype(dtype).itemsize
        dense_fits = dense_bytes <= oot_budget
        try:
            d0 = min_depth_for_budget(m, k, n, oot_budget, dtype)
        except ValueError:
            d0 = None
        # Crossover guard: below min_dim the divide/combine + staging
        # overhead dominates exactly as it does for the in-core pipelines
        # (measured 24x at n=128 on the smoke constants) — unless the
        # dense working set cannot fit the budget, where out-of-core is
        # feasibility, not preference.
        if d0 is not None and (min(m, k, n) >= min_dim or not dense_fits):
            # Depths run from the shallowest that fits to max_depth — or
            # deeper when the budget demands it (an out-of-core plan may
            # legally exceed the in-core depth cap; that cap exists to
            # bound divide overhead, not feasibility).
            for scheme in schemes:
                for d in range(d0, max(max_depth, d0) + 1):
                    if leaf_bytes(m, k, n, d, dtype) <= oot_budget and min(
                        m, k, n
                    ) >= 2**d:
                        cands.append(Candidate(kind=OOT_KIND, scheme=scheme, depth=d))
        # When the dense working set exceeds the budget every on-device
        # candidate (mesh strategies included: the budget models each
        # device's memory) is infeasible, not merely slow — drop them so
        # the planner cannot pick an impossible plan. Runs LAST so the
        # invariant holds over the full candidate set. (Falls back to the
        # unfiltered list if no oot depth fits either, so callers still
        # get a best-effort decision.)
        if not dense_fits:
            oot_only = [c for c in cands if c.kind == OOT_KIND]
            cands = oot_only or cands
    return cands


# --------------------------------------------------------------------------
# Stage-wise prediction (paper §IV generalized to rectangular JAX stages)
# --------------------------------------------------------------------------


def predict_cost_terms(
    cand: Candidate,
    m: int,
    k: int,
    n: int,
    calib: Calibration,
    *,
    device_count: int = 1,
    oot_overlap: bool = True,
) -> Dict[str, float]:
    """Per-constant cost decomposition of one candidate's predicted seconds.

    Returns ``{"t_flop": ..., "t_elem": ..., "t_coll": ..., "t_h2d": ...}``
    — the seconds attributed to each calibrated constant, summing to
    :func:`predict_seconds`. The split is what telemetry and the sweep
    report: it shows *why* a candidate wins (compute vs local traffic vs
    interconnect vs host<->device staging).

    ``oot_overlap`` models the scheduler's async wave pipeline (its
    default): staging traffic that fits under the leaf compute is hidden,
    so the ``t_h2d`` term only charges the *exposed* part — the traffic
    exceeding compute plus the fill/drain bubble
    (:data:`OOT_OVERLAP_EXPOSED_FRACTION` of the hidden portion). Pass
    ``oot_overlap=False`` to price the synchronous loop (``prefetch=False``),
    where every staged byte is on the critical path.
    """
    flops_naive = 2.0 * m * k * n
    t_coll = calib.t_coll if calib.t_coll > 0.0 else calib.t_elem
    terms = {"t_flop": 0.0, "t_elem": 0.0, "t_coll": 0.0, "t_h2d": 0.0}
    if cand.is_naive:
        # On a mesh the naive matmul 2D-parallelizes fully (MLLib regime),
        # but pays the SUMMA panel broadcasts — the JAX analogue of MLLib's
        # 2bn^2 coGroup shuffle (paper Table I), and the term Strassen's
        # fewer leaves undercut at scale.
        terms["t_flop"] = flops_naive * calib.t_flop / max(device_count, 1)
        if device_count > 1:
            terms["t_coll"] = k * (m + n) * math.sqrt(device_count) * t_coll
        return terms

    rank = get_scheme(cand.scheme).n_mults
    l = cand.depth
    fused = cand.kind in (FUSED_KIND, "strassen_fused_sharded")
    # Levels whose intermediates are materialized: all l for the einsum
    # pipelines, l-1 when the last level runs fused in VMEM.
    lm = l - 1 if fused else l
    elem_cost = 0.0
    # Divide levels i = 0..lm-1: outputs rank^(i+1) quarter-blocks of A and B.
    for i in range(lm):
        e_a = rank ** (i + 1) * (m * k) / 4.0 ** (i + 1)
        e_b = rank ** (i + 1) * (k * n) / 4.0 ** (i + 1)
        elem_cost += e_a + e_b
    # Combine levels i = lm-1..0: outputs rank^i blocks of C at level i.
    for i in range(lm):
        elem_cost += rank**i * (m * n) / 4.0**i
    if fused:
        # The fused level reads its operands once and writes C once; the
        # 7/4x M-term blowup never touches HBM.
        elem_cost += rank ** (l - 1) * (m * k + k * n + m * n) / 4.0 ** (l - 1)
    leaf_flops = flops_naive * (rank / 8.0) ** l

    if cand.kind == OOT_KIND:
        # Out-of-core: divide/combine adds are host-side element traffic;
        # leaf waves run sequentially on one device (PF=1) and every leaf's
        # operands cross the host<->device boundary once each way.
        t_h2d = calib.t_h2d if calib.t_h2d > 0.0 else calib.t_elem
        flop_s = leaf_flops * calib.t_flop
        h2d_s = rank**l * (m * k + k * n + m * n) / 4.0**l * t_h2d
        if oot_overlap:
            # Async pipeline: staging overlaps leaf compute, so only the
            # traffic exceeding compute is on the critical path — plus the
            # fill/drain bubble, a fixed fraction of the hidden portion.
            hidden = min(h2d_s, flop_s)
            h2d_s = max(h2d_s - flop_s, 0.0) + OOT_OVERLAP_EXPOSED_FRACTION * hidden
        terms["t_flop"] = flop_s
        terms["t_elem"] = elem_cost * calib.t_elem
        terms["t_h2d"] = h2d_s
        return terms

    coll_cost = 0.0
    if cand.is_local:
        leaf_pf = 1.0
        elem_pf = 1.0
        elem_key = "t_elem"
        t_comm = calib.t_elem
    elif cand.kind == "strassen_fused_sharded":
        # Row-parallel over every mesh axis (the strategy row-shards across
        # the full device grid): every stage runs per-device on local
        # stripes; the only interconnect term is replicating B to every
        # row shard.
        leaf_pf = float(device_count)
        elem_pf = float(device_count)
        elem_key = "t_elem"
        t_comm = calib.t_elem
        coll_cost = k * n * t_coll
    elif cand.kind == "strassen_2d":
        # 2D-parallel leaves spread each block product over the mesh;
        # the leaf batch stays replicated so combine is collective-free,
        # but divide/combine traffic reshards across the grid.
        leaf_pf = float(device_count)
        elem_pf = 1.0
        elem_key = "t_coll"
        t_comm = t_coll
    elif cand.kind.startswith("strassen_shardmap"):
        # one explicit BFS level over the whole grid (mult times rows /
        # rb*cb axes all carry leaf work); combine is a single psum of C.
        leaf_pf = float(device_count)
        elem_pf = 1.0
        elem_key = "t_coll"
        t_comm = t_coll
    else:  # strassen_bfs_sharded and future BFS-batch strategies
        leaf_pf = float(min(rank**l, device_count))
        elem_pf = 1.0
        elem_key = "t_coll"
        t_comm = t_coll
    terms["t_flop"] = leaf_flops * calib.t_flop / leaf_pf
    terms[elem_key] += elem_cost * t_comm / elem_pf
    terms["t_coll"] += coll_cost
    return terms


def predict_seconds(
    cand: Candidate,
    m: int,
    k: int,
    n: int,
    calib: Calibration,
    *,
    device_count: int = 1,
    oot_overlap: bool = True,
) -> float:
    """Predicted wall-clock for one multiply under the calibrated model.

    Mirrors :mod:`repro.core.cost_model`: each divide/combine level costs
    its output-element traffic * a per-element constant; the leaf stage
    costs its flops * t_flop divided by the leaf parallelization factor
    (paper's PF, min'd with the device count). Single-program candidates
    have PF = 1: XLA already uses the whole device, which is what t_flop
    measures. Element traffic that crosses the interconnect — mesh-strategy
    resharding, combine psums, SUMMA panel broadcasts — is priced at
    ``t_coll`` (falling back to ``t_elem`` for pre-t_coll calibrations);
    local HBM traffic stays at ``t_elem``. Fused-leaf candidates skip the
    last level's materialized traffic. Out-of-core candidates add the
    host<->device staging term priced at ``t_h2d`` — discounted to the
    exposed traffic when ``oot_overlap`` is on (the scheduler's async
    pipeline default). See :func:`predict_cost_terms` for the per-constant
    decomposition.
    """
    return sum(
        predict_cost_terms(
            cand, m, k, n, calib, device_count=device_count, oot_overlap=oot_overlap
        ).values()
    )


# --------------------------------------------------------------------------
# Execution + measurement
# --------------------------------------------------------------------------


def execute(
    cand: Candidate,
    a: jax.Array,
    b: jax.Array,
    *,
    precision=None,
    mesh=None,
    oot_budget: Optional[int] = None,
) -> jax.Array:
    """Run one candidate. Raises KeyError for unknown mesh strategy names.

    ``strassen_oot`` candidates run the host-resident block pipeline
    eagerly (they cannot trace under jit); ``oot_budget`` caps their
    device bytes, defaulting to one single-leaf pipelined wave slot.
    """
    if cand.is_naive:
        return jnp.matmul(a, b, precision=precision)
    if cand.kind == OOT_KIND:
        import numpy as np

        from repro.blocks.scheduler import pipelined_leaf_bytes, strassen_oot_matmul

        a_h, b_h = np.asarray(a), np.asarray(b)
        m, k = a_h.shape
        n = b_h.shape[1]
        dtype = np.result_type(a_h.dtype, b_h.dtype)
        budget = oot_budget or pipelined_leaf_bytes(m, k, n, cand.depth, dtype)
        leaf_backend = None
        if precision is not None:
            # Thread the caller's precision into the leaf waves — measured
            # comparisons must price every candidate at the same precision.
            from repro.core.backend import MatmulBackend

            leaf_backend = MatmulBackend(kind="auto", depth=2, precision=precision)
        out, _ = strassen_oot_matmul(
            a_h, b_h, depth=cand.depth, budget_bytes=budget, scheme=cand.scheme,
            backend=leaf_backend,
        )
        return jnp.asarray(out)
    if cand.kind == FUSED_KIND:
        from repro.kernels.strassen.ops import strassen_matmul_fused

        return strassen_matmul_fused(
            a, b, depth=cand.depth, scheme_name=cand.scheme, precision=precision
        )
    if cand.kind in LOCAL_SCHEMES:
        return strassen_matmul(
            a, b, depth=cand.depth, scheme=cand.scheme, precision=precision
        )
    from repro.core.distributed import get_strategy

    fn = get_strategy(cand.kind)
    kwargs = {"mesh": mesh, "scheme": cand.scheme, "precision": precision}
    if not cand.kind.startswith("strassen_shardmap"):
        kwargs["depth"] = cand.depth
    return fn(a, b, **kwargs)


def measure_seconds(
    cand: Candidate,
    m: int,
    k: int,
    n: int,
    dtype=jnp.float32,
    *,
    mesh=None,
    precision=None,
    repeats: int = 2,
    oot_budget: Optional[int] = None,
) -> float:
    """Time one candidate end-to-end on device (compile excluded)."""
    ka, kb = jax.random.split(jax.random.PRNGKey(0))
    a = jax.random.normal(ka, (m, k), jnp.float32).astype(dtype)
    b = jax.random.normal(kb, (k, n), jnp.float32).astype(dtype)
    if cand.kind == OOT_KIND:
        # Host-resident pipeline: eager by construction, warmup still
        # excludes the leaf dispatch's trace/compile cost.
        import numpy as np

        a_h, b_h = np.asarray(a), np.asarray(b)
        return _time_best(
            lambda: jax.block_until_ready(
                execute(cand, a_h, b_h, precision=precision, oot_budget=oot_budget)
            ),
            repeats,
        )
    fn = jax.jit(lambda x, y: execute(cand, x, y, precision=precision, mesh=mesh))
    return _time_best(lambda: jax.block_until_ready(fn(a, b)), repeats)


# --------------------------------------------------------------------------
# Persistent tuning cache
# --------------------------------------------------------------------------


def cache_key(
    m: int,
    k: int,
    n: int,
    dtype,
    *,
    device_kind: str,
    device_count: int,
    schemes: Sequence[str],
    min_dim: int,
    max_depth: int,
    topo: str = "local",
    site: Optional[str] = None,
    oot_budget: Optional[int] = None,
) -> str:
    """``topo`` separates local from mesh resolutions: the candidate sets and
    cost models differ, so a mesh decision must never answer a local lookup
    (or vice versa) even at equal device counts.

    ``site`` is an optional call-site tag (e.g. ``"attn.wq"``) threaded from
    the model stack: tagged entries are keyed per call site, so same-shape
    projections can hold different (measured) decisions. ``site=None``
    yields the shape-only key, which tagged lookups also fall back to in
    predicted mode (the prediction is shape-only anyway).

    ``oot_budget`` keys budget-gated resolutions separately: the candidate
    set (and the right answer) changes with the device-memory cap, and a
    budget-free decision must never answer a budgeted lookup. ``None``
    reproduces the historical key, so existing caches stay valid.
    """
    dt = jnp.dtype(dtype).name
    key = (
        f"{m}x{k}x{n}|{dt}|{device_kind}:{device_count}|{topo}"
        f"|{','.join(schemes)}|min{min_dim}|d{max_depth}"
    )
    if oot_budget:
        key += f"|oot{oot_budget}"
    if site:
        key += f"|site:{site}"
    return key


class TuningCache:
    """JSON-backed decision store: key -> Decision (+ the calibration used).

    Load-then-lookup is the startup path for serving: the engine resolves
    every projection shape from here, so jit tracing never re-measures.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.entries: Dict[str, Decision] = {}
        self.calibration: Optional[Calibration] = None
        self._suspended = False
        if path and os.path.exists(path):
            self.load(path)

    @contextlib.contextmanager
    def deferred(self):
        """Batch many put/save cycles into one file write (warm-up loops)."""
        self._suspended = True
        try:
            yield self
        finally:
            self._suspended = False
            self.save()

    def load(self, path: str) -> "TuningCache":
        with open(path) as f:
            raw = json.load(f)
        self.entries = {
            k: Decision.from_dict(v) for k, v in raw.get("decisions", {}).items()
        }
        if raw.get("calibration"):
            self.calibration = Calibration.from_dict(raw["calibration"])
        return self

    def save(self, path: Optional[str] = None) -> None:
        path = path or self.path
        if not path or self._suspended:
            return
        payload = {
            "decisions": {k: d.to_dict() for k, d in self.entries.items()},
            "calibration": self.calibration.to_dict() if self.calibration else None,
        }
        # atomic: decisions may be read by a concurrently starting engine
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)

    def get(self, key: str) -> Optional[Decision]:
        return self.entries.get(key)

    def put(self, key: str, decision: Decision) -> None:
        self.entries[key] = decision


# --------------------------------------------------------------------------
# Decision telemetry
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TelemetryEvent:
    """One autotune resolution: where it came from and what it chose."""

    key: str
    site: Optional[str]
    kind: str
    scheme: str
    depth: int
    source: str  # predicted | measured | cache
    cache_hit: bool
    predicted_s: float
    measured_s: Optional[float] = None
    # Per-constant decomposition of predicted_s (t_flop/t_elem/t_coll/t_h2d
    # seconds, see predict_cost_terms). None on cache hits: the stored
    # decision predates this resolution and its calibration may differ.
    terms: Optional[Dict[str, float]] = None

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)


class Telemetry:
    """Process-wide autotune decision log.

    Every :func:`autotune` call records one event — cache hit or miss, the
    chosen kind, and the predicted (and, under measure mode, measured)
    seconds — so a serving engine or benchmark can report exactly which
    matmul strategy each traced shape resolved to and on what evidence.
    The event log is a ring buffer (``max_events``, default 4096): a
    long-running server with churning prefill shapes keeps the newest
    decisions while the hit/miss counters stay exact totals.
    """

    def __init__(self, max_events: int = 4096) -> None:
        self.max_events = max_events
        self.cache_hits = 0
        self.cache_misses = 0
        self.events: List[TelemetryEvent] = []

    def record(self, event: TelemetryEvent) -> None:
        if event.cache_hit:
            self.cache_hits += 1
        else:
            self.cache_misses += 1
        self.events.append(event)
        if len(self.events) > self.max_events:
            del self.events[: len(self.events) - self.max_events]

    def kind_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.events:
            out[e.kind] = out.get(e.kind, 0) + 1
        return out

    def snapshot(self) -> Dict:
        return {
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "kinds": self.kind_counts(),
            "decisions": [e.to_dict() for e in self.events],
        }

    def reset(self) -> None:
        self.cache_hits = 0
        self.cache_misses = 0
        self.events = []


_TELEMETRY = Telemetry()


def get_telemetry() -> Telemetry:
    """The process telemetry instance (reset() it between experiments)."""
    return _TELEMETRY


def reset_telemetry() -> Telemetry:
    """Zero the process telemetry and return it.

    Resolutions fire at jit-trace time, so per-engine attribution is
    impossible to scope structurally — instead every surface that owns a
    run (``Engine.__init__``, the benchmark sweeps) resets the process log
    up front so its snapshot reflects only its own resolutions, not a
    previous engine's (the counters used to leak across instances).
    """
    _TELEMETRY.reset()
    return _TELEMETRY


_PROCESS_CACHES: Dict[str, TuningCache] = {}


def process_cache(path: Optional[str]) -> TuningCache:
    """One shared TuningCache per path (or one anonymous in-memory cache)."""
    key = path or ""
    if key not in _PROCESS_CACHES:
        _PROCESS_CACHES[key] = TuningCache(path)
    return _PROCESS_CACHES[key]


# --------------------------------------------------------------------------
# The dispatcher
# --------------------------------------------------------------------------


def autotune(
    m: int,
    k: int,
    n: int,
    dtype=jnp.float32,
    *,
    min_dim: int = 1024,
    max_depth: int = 3,
    schemes: Sequence[str] = LOCAL_SCHEMES,
    cache: Optional[TuningCache] = None,
    calibration: Optional[Calibration] = None,
    measure: bool = False,
    top_k: int = 3,
    mesh=None,
    precision=None,
    site: Optional[str] = None,
    oot_budget: Optional[int] = None,
    telemetry: Optional[Telemetry] = None,
) -> Decision:
    """Pick the predicted- (or measured-) fastest strategy for this shape.

    Cache hits return immediately (source='cache') — before calibration, so
    a warm cache costs zero device time. ``measure=True`` times the top-k
    predicted candidates and records the measured winner, the
    theory-vs-practice loop of the paper's §V.

    ``site`` keys the decision per call site (see :func:`cache_key`). In
    predicted mode a tagged miss falls back to the shape-only entry — the
    prediction cannot differ per site — but measured mode never does: a
    measured site decision must come from measuring *that* site's key, so
    e.g. same-width QKV and MLP projections can diverge.

    ``telemetry`` records the resolution to a caller-owned log instead of
    the process one — experiments that must not interleave with a live
    engine's counters pass their own :class:`Telemetry`.
    """
    tel = telemetry if telemetry is not None else _TELEMETRY
    # Every resolution is a span: cache hits close immediately with
    # cache_hit=True; fresh decisions carry the predicted cost-term
    # breakdown (t_flop/t_elem/t_coll/t_h2d) next to any measured time —
    # the predicted-vs-measured feed the TPU recalibration item needs.
    tr = obs_tracer.get_tracer()
    res_span = tr.begin(
        "autotune.resolve", cat="autotune", site=site, m=m, k=k, n=n,
    )
    dev = jax.devices()[0]
    if mesh is not None:
        device_count = len(mesh.devices.flatten())
        topo = "mesh" + "x".join(str(s) for s in mesh.devices.shape)
    else:
        device_count = 1
        topo = "local"
    key_kwargs = dict(
        device_kind=dev.platform,
        device_count=device_count,
        schemes=schemes,
        min_dim=min_dim,
        max_depth=max_depth,
        topo=topo,
        oot_budget=oot_budget,
    )
    key = cache_key(m, k, n, dtype, site=site, **key_kwargs)
    if cache is not None:
        hit = cache.get(key)
        if hit is None and site and not measure:
            hit = cache.get(cache_key(m, k, n, dtype, **key_kwargs))
        if hit is not None and hit.kind in (FUSED_KIND, "strassen_fused_sharded"):
            # Re-validate fused decisions against THIS host: a cache warmed
            # where the Pallas leaf ran must not route to it on a build
            # where it cannot (enumeration would have excluded it).
            from repro.core import compat

            if compat.pallas_leaf_mode() == "none":
                hit = None
        if hit is not None:
            decision = dataclasses.replace(hit, source="cache")
            tel.record(
                TelemetryEvent(
                    key=key,
                    site=site,
                    kind=decision.kind,
                    scheme=decision.scheme,
                    depth=decision.depth,
                    source="cache",
                    cache_hit=True,
                    predicted_s=decision.predicted_s,
                    measured_s=decision.measured_s,
                )
            )
            obs_metrics.get_metrics().counter("autotune.cache_hit").inc()
            tr.end(
                res_span, cache_hit=True, kind=decision.kind,
                scheme=decision.scheme, depth=decision.depth, source="cache",
                predicted_s=decision.predicted_s,
                measured_s=decision.measured_s,
            )
            return decision

    calib = calibration or (cache.calibration if cache else None) or get_calibration()
    cands = enumerate_candidates(
        m, k, n, schemes=schemes, max_depth=max_depth, min_dim=min_dim, mesh=mesh,
        oot_budget=oot_budget, dtype=dtype,
    )

    def _overlap(c: Candidate) -> bool:
        # Price an oot candidate's overlap discount only when the budget
        # actually leaves the scheduler its pipelined wave slot at that
        # depth — otherwise it silently degrades to synchronous staging
        # and every staged byte is on the critical path.
        return c.kind != OOT_KIND or _oot_pipeline_fits(
            m, k, n, c.depth, dtype, oot_budget
        )

    scored = sorted(
        cands,
        key=lambda c: predict_seconds(
            c, m, k, n, calib, device_count=device_count, oot_overlap=_overlap(c)
        ),
    )
    best = scored[0]
    predicted = predict_seconds(
        best, m, k, n, calib, device_count=device_count, oot_overlap=_overlap(best)
    )
    measured = None
    if measure:
        timed = [
            (
                measure_seconds(
                    c, m, k, n, dtype, mesh=mesh, precision=precision,
                    oot_budget=oot_budget,
                ),
                c,
            )
            for c in scored[: max(top_k, 1)]
        ]
        measured, best = min(timed, key=lambda t: t[0])
        predicted = predict_seconds(
            best, m, k, n, calib, device_count=device_count,
            oot_overlap=_overlap(best),
        )

    decision = Decision(
        kind=best.kind,
        scheme=best.scheme,
        depth=best.depth,
        predicted_s=float(predicted),
        measured_s=None if measured is None else float(measured),
        source="measured" if measure else "predicted",
    )
    if cache is not None:
        cache.calibration = cache.calibration or calib
        # Predicted decisions are shape-only by construction, so a tagged
        # resolution stores under the shape-only key — every other site of
        # the same shape then hits via the fallback instead of duplicating
        # identical entries. Only measured decisions are site-specific.
        store_key = (
            key if (measure or not site) else cache_key(m, k, n, dtype, **key_kwargs)
        )
        cache.put(store_key, decision)
        cache.save()
    terms = predict_cost_terms(
        best, m, k, n, calib, device_count=device_count,
        oot_overlap=_overlap(best),
    )
    tel.record(
        TelemetryEvent(
            key=key,
            site=site,
            kind=decision.kind,
            scheme=decision.scheme,
            depth=decision.depth,
            source=decision.source,
            cache_hit=False,
            predicted_s=decision.predicted_s,
            measured_s=decision.measured_s,
            terms=terms,
        )
    )
    obs_metrics.get_metrics().counter("autotune.cache_miss").inc()
    tr.end(
        res_span, cache_hit=False, kind=decision.kind,
        scheme=decision.scheme, depth=decision.depth, source=decision.source,
        predicted_s=decision.predicted_s, measured_s=decision.measured_s,
        **{f"terms.{t}": v for t, v in terms.items()},
    )
    return decision


# --------------------------------------------------------------------------
# Solver families (SPIN block-recursive inversion / triangular solve)
# --------------------------------------------------------------------------

# Candidate families of the solver ops. Priced with the same calibrated
# constants as the matmul families: t_flop for dense-leaf and recursive
# multiply flops, t_h2d for every staged byte (with the wave pipeline's
# overlap discount where the budget leaves pipeline headroom), t_elem for
# the host-side axpy chains.
INVERSE_OOT_KIND = "inverse_oot"
SOLVE_OOT_KIND = "solve_oot"
_SOLVER_FAMILIES = {"inverse": INVERSE_OOT_KIND, "solve": SOLVE_OOT_KIND}


def predict_solver_terms(
    op: str,
    n: int,
    depth: int,
    calib: Calibration,
    *,
    nrhs: Optional[int] = None,
    oot_budget: Optional[int] = None,
    oot_overlap: bool = True,
) -> Dict[str, float]:
    """Per-constant cost decomposition of one solver run at a given depth.

    The recursion does, per node at level i (2^i nodes, half-size h =
    n / 2^(i+1)): for ``inverse`` six h-sized multiplies and two axpys
    (SPIN's Schur-complement program); for ``solve`` one (h x h) @
    (h x nrhs) multiply and one axpy. The 2^depth dense leaves run one
    device inv (~2 s^3 flops) or trsm (~s^2 nrhs flops). Multiply staging
    is priced at t_h2d with the wave pipeline's exposed-fraction discount
    (:data:`OOT_OVERLAP_EXPOSED_FRACTION`) when ``oot_overlap``.
    """
    if op not in _SOLVER_FAMILIES:
        raise ValueError(
            f"unknown solver op {op!r}; have {sorted(_SOLVER_FAMILIES)}"
        )
    r = n if nrhs is None else nrhs
    t_h2d = calib.t_h2d or calib.t_elem
    flop_s = 0.0
    h2d_elems = 0.0
    elem_s = 0.0
    s = max(1, n >> depth)
    leaves = 1 << depth
    if op == "inverse":
        flop_s += leaves * 2.0 * s**3 * calib.t_flop
        h2d_elems += leaves * 2.0 * s * s
    else:
        flop_s += leaves * float(s) * s * r * calib.t_flop
        h2d_elems += leaves * (s * s + 2.0 * s * r)
    mul_flop_s = 0.0
    for level in range(depth):
        nodes = 1 << level
        h = max(1, n >> (level + 1))
        if op == "inverse":
            mul_flop_s += nodes * 6 * 2.0 * h**3 * calib.t_flop
            h2d_elems += nodes * 6 * 3.0 * h * h
            elem_s += nodes * 2.0 * h * h * calib.t_elem
        else:
            mul_flop_s += nodes * 2.0 * h * h * r * calib.t_flop
            h2d_elems += nodes * (h * h + 2.0 * h * r)
            elem_s += nodes * float(h) * r * calib.t_elem
    flop_s += mul_flop_s
    h2d_s = h2d_elems * t_h2d
    if oot_overlap:
        # The staged traffic rides the scheduler's async pipeline: only the
        # non-overlappable remainder plus the fill/drain bubbles stay on
        # the critical path (same shape as the strassen_oot discount).
        h2d_s = max(h2d_s - mul_flop_s, 0.0) + OOT_OVERLAP_EXPOSED_FRACTION * min(
            h2d_s, mul_flop_s
        )
    return {"flop_s": flop_s, "elem_s": elem_s, "h2d_s": h2d_s}


def predict_solver_seconds(
    op: str,
    n: int,
    depth: int,
    calib: Calibration,
    *,
    nrhs: Optional[int] = None,
    oot_budget: Optional[int] = None,
    oot_overlap: bool = True,
) -> float:
    terms = predict_solver_terms(
        op, n, depth, calib, nrhs=nrhs, oot_budget=oot_budget,
        oot_overlap=oot_overlap,
    )
    return sum(terms.values())


def autotune_solver(
    op: str,
    n: int,
    dtype=jnp.float32,
    *,
    nrhs: Optional[int] = None,
    oot_budget: Optional[int] = None,
    max_depth: int = 10,
    scheme: str = "strassen",
    cache: Optional[TuningCache] = None,
    calibration: Optional[Calibration] = None,
    site: Optional[str] = None,
    telemetry: Optional[Telemetry] = None,
) -> Decision:
    """Pick the predicted-fastest recursion depth for one solver shape.

    ``op`` is 'inverse' or 'solve'. Candidate depths run from the
    smallest whose dense leaf fits ``oot_budget`` (every level halves the
    leaf side) up a few levels — deeper trades dense-leaf cubic work for
    more recursive-multiply traffic, and the calibrated terms arbitrate.
    Decisions cache and telemetry exactly like matmul resolutions, with
    ``topo`` set to the solver family so a solver entry can never answer
    a matmul lookup.
    """
    from repro.blocks.solve import solver_min_depth_for_budget

    family = _SOLVER_FAMILIES.get(op)
    if family is None:
        raise ValueError(
            f"unknown solver op {op!r}; have {sorted(_SOLVER_FAMILIES)}"
        )
    tel = telemetry if telemetry is not None else _TELEMETRY
    tr = obs_tracer.get_tracer()
    res_span = tr.begin(
        "autotune.resolve", cat="autotune", site=site, family=family, n=n,
    )
    dev = jax.devices()[0]
    leaf_kind = "inv" if op == "inverse" else "trsm_lower"
    key = cache_key(
        n, n, n if nrhs is None else nrhs, dtype,
        device_kind=dev.platform, device_count=1,
        schemes=(scheme,), min_dim=0, max_depth=max_depth,
        topo=family, site=site, oot_budget=oot_budget,
    )
    if cache is not None:
        hit = cache.get(key)
        if hit is not None:
            decision = dataclasses.replace(hit, source="cache")
            tel.record(
                TelemetryEvent(
                    key=key, site=site, kind=decision.kind,
                    scheme=decision.scheme, depth=decision.depth,
                    source="cache", cache_hit=True,
                    predicted_s=decision.predicted_s,
                    measured_s=decision.measured_s,
                )
            )
            obs_metrics.get_metrics().counter("autotune.cache_hit").inc()
            tr.end(
                res_span, cache_hit=True, kind=decision.kind,
                depth=decision.depth, source="cache",
            )
            return decision

    calib = calibration or (cache.calibration if cache else None) or get_calibration()
    if oot_budget:
        d_min = solver_min_depth_for_budget(
            n, oot_budget, dtype, nrhs=nrhs, leaf_kind=leaf_kind,
            max_depth=max_depth,
        )
    else:
        d_min = 0
    depths = range(d_min, min(d_min + 3, max_depth) + 1)
    best_depth = min(
        depths,
        key=lambda d: predict_solver_seconds(
            op, n, d, calib, nrhs=nrhs, oot_budget=oot_budget
        ),
    )
    predicted = predict_solver_seconds(
        op, n, best_depth, calib, nrhs=nrhs, oot_budget=oot_budget
    )
    decision = Decision(
        kind=family, scheme=scheme, depth=best_depth,
        predicted_s=float(predicted), source="predicted",
    )
    if cache is not None:
        cache.calibration = cache.calibration or calib
        cache.put(key, decision)
        cache.save()
    terms = predict_solver_terms(
        op, n, best_depth, calib, nrhs=nrhs, oot_budget=oot_budget
    )
    tel.record(
        TelemetryEvent(
            key=key, site=site, kind=family, scheme=scheme, depth=best_depth,
            source="predicted", cache_hit=False,
            predicted_s=decision.predicted_s, terms=terms,
        )
    )
    obs_metrics.get_metrics().counter("autotune.cache_miss").inc()
    tr.end(
        res_span, cache_hit=False, kind=family, depth=best_depth,
        source="predicted", predicted_s=decision.predicted_s,
        **{f"terms.{t}": v for t, v in terms.items()},
    )
    return decision


def model_call_sites(cfg) -> List[Tuple[str, int, int]]:
    """(site, d_in, d_out) for every tagged dense projection of a model.

    These are exactly the tags :mod:`repro.models.attention` /
    :mod:`repro.models.mlp` thread through ``linear`` — keep the two lists
    in sync so warmed cache keys match runtime lookups.
    """
    hd = cfg.head_dim or (cfg.d_model // max(cfg.n_heads, 1))
    sites = [
        ("attn.wq", cfg.d_model, cfg.n_heads * hd),
        ("attn.wk", cfg.d_model, cfg.n_kv_heads * hd),
        ("attn.wv", cfg.d_model, cfg.n_kv_heads * hd),
        ("attn.wo", cfg.n_heads * hd, cfg.d_model),
        ("mlp.up", cfg.d_model, cfg.d_ff),
        ("mlp.down", cfg.d_ff, cfg.d_model),
    ]
    if cfg.glu:
        sites.append(("mlp.gate", cfg.d_model, cfg.d_ff))
    return [(s, i, o) for s, i, o in sites if i > 0 and o > 0]


def warm_for_model(
    cfg, *, tokens: Sequence[int] = (1, 128, 2048), batches: Sequence[int] = (1, 8)
) -> int:
    """Pre-resolve decisions for a model's dense-projection call sites.

    Serving startup path: the flattened M a projection sees is batch*seq at
    prefill and batch at decode, so we resolve every (batch * tokens) x
    call-site combination up front, under the same site tags the layers
    pass at trace time. Shapes outside this grid (odd batch sizes,
    untagged call sites) still resolve lazily at trace time — the warm-up
    narrows the cold path, it doesn't guarantee its absence. Returns the
    number of resolutions performed.
    """
    from repro.core import backend as _backend

    be = cfg.matmul_backend
    if be.kind != "auto":
        return 0
    ms = sorted({b * t for b in batches for t in tokens} | set(batches))
    count = 0
    with process_cache(be.tuning_cache).deferred():
        for m in ms:
            for site, d_in, d_out in model_call_sites(cfg):
                _backend.resolve_auto(m, d_in, d_out, cfg.dtype, be, site)
                count += 1
    return count

"""Strassen matrix multiplication in JAX — serial and batched-BFS forms.

This is the paper's algorithm (Stark) re-expressed TPU-natively:

* :func:`strassen_recursive` — Algorithm 1 of the paper (single node,
  driver-side recursion). Reference implementation.
* :func:`divide_level` / :func:`combine_level` — one *level* of the
  distributed recursion. These are the JAX analogue of Stark's
  flatMapToPair/groupByKey/flatMap divide stage and its combine stage:
  a whole level is processed in parallel as one einsum against a constant
  coefficient matrix. The batch index plays the role of the paper's
  M-index tag (base-7 digits = tag path, see coefficients.leaf_tag_path).
* :func:`strassen_matmul` — the full pipeline: ``depth`` divide levels,
  one batched leaf-multiplication stage (the paper's Algorithm 4 —
  "multiply blocks serially [in parallel executors]" becomes one batched
  einsum or a Pallas MXU kernel), and ``depth`` combine levels.

Rectangular support: the paper (like Strassen 1969) treats square 2^p
matrices "for mathematical brevity". Splitting M, K and N in half each
level makes the identical scheme valid for any (M, K) @ (K, N) with all
three dims divisible by 2**depth; :mod:`repro.core.backend` pads odd dims.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coefficients import Scheme, STRASSEN, get_scheme

__all__ = [
    "strassen_recursive",
    "split_quadrants",
    "merge_quadrants",
    "divide_level",
    "combine_level",
    "strassen_matmul",
    "leaf_count",
]

LeafFn = Callable[[jax.Array, jax.Array], jax.Array]


def leaf_count(scheme: Scheme, depth: int) -> int:
    """Number of leaf multiplications: the paper's 7^(p-q) (= b^2.807)."""
    return scheme.n_mults**depth


def split_quadrants(x: jax.Array) -> jax.Array:
    """(..., r, c) -> (..., 4, r/2, c/2), quadrants row-major [11, 12, 21, 22].

    This is the paper's "Divide" of a sub-matrix into four equal quadrants
    (Fig. 3 "index reordering"), vectorized over any leading batch dims.
    """
    *lead, r, c = x.shape
    if r % 2 or c % 2:
        raise ValueError(f"need even dims, got {x.shape}")
    hr, hc = r // 2, c // 2
    x = x.reshape(*lead, 2, hr, 2, hc)
    x = jnp.moveaxis(x, -2, -3)  # (..., 2, 2, hr, hc)
    return x.reshape(*lead, 4, hr, hc)


def merge_quadrants(q: jax.Array) -> jax.Array:
    """Inverse of :func:`split_quadrants`: (..., 4, hr, hc) -> (..., 2hr, 2hc)."""
    *lead, four, hr, hc = q.shape
    if four != 4:
        raise ValueError(f"need (..., 4, hr, hc), got {q.shape}")
    q = q.reshape(*lead, 2, 2, hr, hc)
    q = jnp.moveaxis(q, -3, -2)  # (..., 2, hr, 2, hc)
    return q.reshape(*lead, 2 * hr, 2 * hc)


def divide_level(x: jax.Array, coef: jax.Array, *, precision=None) -> jax.Array:
    """One divide level: (m, r, c) -> (m*rank, r/2, c/2).

    ``coef`` is the scheme's (rank, 4) a_coef or b_coef. Equivalent to
    Stark's divide stage: replicate quadrants into the rank groups
    (flatMapToPair + groupByKey) and form each group's signed sum (flatMap)
    — here a single einsum. Leaf ordering is level-major: output index is
    m_old * rank + p, so base-rank digits of the final leaf index reproduce
    the paper's M-index tag path.
    """
    m, r, c = x.shape
    q = split_quadrants(x)  # (m, 4, r/2, c/2)
    coef = coef.astype(x.dtype)
    out = jnp.einsum("pq,mqij->mpij", coef, q, precision=precision)
    return out.reshape(m * coef.shape[0], r // 2, c // 2)


def combine_level(products: jax.Array, c_coef: jax.Array, *, precision=None) -> jax.Array:
    """One combine level: (m*rank, hr, hc) -> (m, 2hr, 2hc).

    ``c_coef`` is the scheme's (4, rank) combine matrix. Equivalent to
    Stark's combine stage (map + groupByKey + flatMap over M-index tags).
    """
    rank = c_coef.shape[1]
    mr, hr, hc = products.shape
    if mr % rank:
        raise ValueError(f"batch {mr} not divisible by rank {rank}")
    m = mr // rank
    prod = products.reshape(m, rank, hr, hc)
    c_coef = c_coef.astype(products.dtype)
    quads = jnp.einsum("kp,mpij->mkij", c_coef, prod, precision=precision)
    return merge_quadrants(quads)


def _default_leaf(a: jax.Array, b: jax.Array, *, precision=None) -> jax.Array:
    """Batched leaf multiply: einsum('mij,mjk->mik'). The paper's Algorithm 4."""
    return jnp.einsum("mij,mjk->mik", a, b, precision=precision)


def strassen_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    depth: int,
    scheme: Scheme | str = STRASSEN,
    leaf_fn: Optional[LeafFn] = None,
    precision=None,
    constrain_a=None,
    constrain_b=None,
    constrain_out=None,
) -> jax.Array:
    """Batched-BFS Strassen: ``depth`` unrolled recursion levels.

    This is Stark's flattened recursion (Fig. 2): each of the ``depth``
    divide levels runs fully in parallel, the 7^depth leaf products form a
    single parallel stage, and combine levels rebuild C bottom-up. Under
    jit the entire pipeline is one XLA program.

    Args:
      a: (M, K); b: (K, N). M, K, N divisible by 2**depth.
      depth: number of Strassen levels (the paper's p - q).
      scheme: coefficient scheme (strassen | winograd | naive8).
      leaf_fn: batched leaf multiply (m, i, j) x (m, j, k) -> (m, i, k).
        Defaults to a batched einsum; the Pallas MXU kernel plugs in here.
      precision: jax matmul precision for the default leaf.
      constrain_a/b/out: optional per-level sharding hooks (m, r, c) ->
        array. Under GSPMD the quadrant reshapes break sharding
        propagation (operands silently replicate, measured 3x compute /
        6x collectives on internlm2 train) — the backend passes hooks
        that re-pin each level to the caller's layout.
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad matmul shapes {a.shape} @ {b.shape}")
    if depth < 0:
        raise ValueError("depth must be >= 0")
    step = 2**depth
    for d in (*a.shape, b.shape[1]):
        if d % step:
            raise ValueError(f"dim {d} not divisible by 2**depth={step}")

    if leaf_fn is None:
        leaf_fn = functools.partial(_default_leaf, precision=precision)

    a_coef = jnp.asarray(scheme.a_coef)
    b_coef = jnp.asarray(scheme.b_coef)
    c_coef = jnp.asarray(scheme.c_coef)

    # Divide phase: depth levels, each one parallel einsum.
    ta = a[None]  # (1, M, K)
    tb = b[None]
    for _ in range(depth):
        ta = divide_level(ta, a_coef)
        tb = divide_level(tb, b_coef)
        if constrain_a is not None:
            ta = constrain_a(ta)
        if constrain_b is not None:
            tb = constrain_b(tb)

    # Leaf phase: one batched multiply of rank^depth blocks.
    prod = leaf_fn(ta, tb)
    if constrain_out is not None:
        prod = constrain_out(prod)

    # Combine phase: depth levels bottom-up.
    for _ in range(depth):
        prod = combine_level(prod, c_coef)
        if constrain_out is not None:
            prod = constrain_out(prod)

    return prod[0]


def strassen_recursive(
    a: jax.Array,
    b: jax.Array,
    *,
    threshold: int = 64,
    scheme: Scheme | str = STRASSEN,
) -> jax.Array:
    """Paper Algorithm 1: serial recursive Strassen (single node reference).

    Recurses until the smallest dim reaches ``threshold``, then multiplies
    naively (the paper's Breeze/BLAS leaf call -> jnp.dot here).
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    m, k = a.shape
    n = b.shape[1]
    if min(m, k, n) <= threshold or m % 2 or k % 2 or n % 2:
        return a @ b
    aq = split_quadrants(a)  # (4, m/2, k/2)
    bq = split_quadrants(b)
    prods = []
    for p in range(scheme.n_mults):
        left = _combo(aq, scheme.a_coef[p], a.dtype)
        right = _combo(bq, scheme.b_coef[p], b.dtype)
        prods.append(strassen_recursive(left, right, threshold=threshold, scheme=scheme))
    quads = []
    for kk in range(4):
        acc = None
        for p in range(scheme.n_mults):
            c = scheme.c_coef[kk, p]
            if c == 0:
                continue
            term = prods[p] if c == 1 else (-prods[p] if c == -1 else c * prods[p])
            acc = term if acc is None else acc + term
        quads.append(acc)
    return merge_quadrants(jnp.stack(quads))


def _combo(quads: jax.Array, coef_row: np.ndarray, dtype) -> jax.Array:
    """Signed sum of quadrants per one coefficient row (serial-form helper)."""
    acc = None
    for q in range(4):
        c = float(coef_row[q])
        if c == 0.0:
            continue
        term = quads[q] if c == 1.0 else (-quads[q] if c == -1.0 else c * quads[q])
        acc = term if acc is None else acc + term
    assert acc is not None
    return acc.astype(dtype)

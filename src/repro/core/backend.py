"""Pluggable matmul backend: routes model-layer matmuls through Strassen.

This is how the paper's technique becomes a first-class framework feature:
every dense projection in :mod:`repro.models` calls :func:`matmul` with the
config's :class:`MatmulBackend`. The backend decides — per call site and
per shape — whether to run the naive XLA matmul (MLLib/Marlin regime), the
batched-BFS Strassen pipeline (Stark regime), or the Pallas-fused variant.

The crossover logic mirrors the paper's empirical finding (§V-C): Strassen
wins only when matrix dims are large relative to the leaf block size; below
``min_dim`` the divide/combine overhead dominates and we fall back to the
naive path (exactly like Stark's ``threshold`` leaf cutoff).
"""
from __future__ import annotations

import dataclasses
import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.strassen import strassen_matmul
from repro.obs import tracer as obs_tracer

__all__ = [
    "MatmulBackend",
    "matmul",
    "inverse",
    "solve_triangular",
    "NAIVE_BACKEND",
    "AUTO_BACKEND",
    "resolve_auto",
    "VALID_KINDS",
    "EAGER_ONLY_KINDS",
    "JIT_SAFE_KINDS",
    "SOLVER_KINDS",
    "SOLVER_EAGER_ONLY_KINDS",
    "SOLVER_JIT_SAFE_KINDS",
    "XLA_ASYNC_FLAGS",
    "enable_xla_async_flags",
    "set_default_matmul_precision",
    "default_matmul_precision",
    "resolve_precision",
]

# The registered routing kinds: every MatmulBackend.kind (and every CLI
# --backend choice) must come from this tuple, so a typo fails shallowly
# with the list of valid names instead of a deep trace-time error.
VALID_KINDS: Tuple[str, ...] = (
    "naive",
    "strassen",
    "winograd",
    "strassen_fused",
    "strassen_oot",
    "auto",
)

# Kinds that cannot trace under jit (host-resident pipelines). Jitted
# surfaces (train/serve/dryrun CLIs) derive their --backend menus as
# VALID_KINDS minus these.
EAGER_ONLY_KINDS: Tuple[str, ...] = ("strassen_oot",)
JIT_SAFE_KINDS: Tuple[str, ...] = tuple(
    k for k in VALID_KINDS if k not in EAGER_ONLY_KINDS
)

# Routing kinds of the solver ops (:func:`inverse` /
# :func:`solve_triangular`): 'dense' is one device LAPACK-style call,
# 'spin_oot' the SPIN block-recursive pipeline over the tagged block
# runtime, 'auto' picks per shape against ``device_budget``. Error
# messages enumerate these tuples dynamically — new kinds can never
# drift out of the message text.
SOLVER_KINDS: Tuple[str, ...] = ("dense", "spin_oot", "auto")
SOLVER_EAGER_ONLY_KINDS: Tuple[str, ...] = ("spin_oot",)
SOLVER_JIT_SAFE_KINDS: Tuple[str, ...] = tuple(
    k for k in SOLVER_KINDS if k not in SOLVER_EAGER_ONLY_KINDS
)

# XLA flags that let the compiler overlap collectives and transfers with
# compute (the bayespec config.py GPU recipe): the scheduler-level analogue
# of the out-of-core wave pipeline. They only take effect if appended to
# XLA_FLAGS before the jax backend initializes — enable_xla_async_flags()
# reports which regime it ran in.
XLA_ASYNC_FLAGS: Tuple[str, ...] = (
    "--xla_gpu_enable_latency_hiding_scheduler=true",
    "--xla_gpu_enable_async_collectives=true",
    "--xla_gpu_enable_highest_priority_async_stream=true",
)

# Process-default matmul precision, the HomebrewNLP backend.py discipline:
# precision policy is a backend knob set once, not threaded per call site.
# A MatmulBackend with precision=None inherits this default.
_DEFAULT_PRECISION: Optional[str] = None


def set_default_matmul_precision(precision: Optional[str]) -> Optional[str]:
    """Set the process default for backends with ``precision=None``.

    Accepts jax precision names ('default' | 'fastest' | 'high' |
    'highest') or None to clear. Returns the previous default.
    """
    global _DEFAULT_PRECISION
    if precision is not None and precision not in (
        "default", "fastest", "high", "highest", "bfloat16", "float32", "tensorfloat32"
    ):
        raise ValueError(f"unknown matmul precision {precision!r}")
    prev, _DEFAULT_PRECISION = _DEFAULT_PRECISION, precision
    return prev


def default_matmul_precision() -> Optional[str]:
    return _DEFAULT_PRECISION


def resolve_precision(backend: "MatmulBackend") -> Optional[str]:
    """The precision a backend's matmuls run at: its own, else the default."""
    return backend.precision if backend.precision is not None else _DEFAULT_PRECISION


def enable_xla_async_flags(flags: Tuple[str, ...] = XLA_ASYNC_FLAGS) -> bool:
    """Append latency-hiding/async-collective flags to ``XLA_FLAGS``.

    Idempotent: flags already present (under any value) are left alone.
    Returns True when the jax backend has not initialized yet — i.e. the
    flags will actually reach XLA — and False when they can only take
    effect in a future process (set XLA_FLAGS before the first jax call).
    """
    current = os.environ.get("XLA_FLAGS", "")
    missing = [f for f in flags if f.split("=", 1)[0] not in current]
    if missing:
        os.environ["XLA_FLAGS"] = " ".join(([current] if current else []) + missing)
    try:  # private, so probed defensively: absence just means "unknown"
        from jax._src import xla_bridge

        initialized = bool(xla_bridge._backends)
    except Exception:  # pragma: no cover - jax internals moved
        initialized = False
    return not initialized


def is_oom_error(exc: BaseException) -> bool:
    """Classify a device out-of-memory failure, across jax versions.

    XLA surfaces OOM as ``XlaRuntimeError`` with RESOURCE_EXHAUSTED (the
    type's import path has moved repeatedly, so match by name) or as a
    generic RuntimeError carrying an allocator message. The out-of-core
    scheduler treats OOM differently from transient faults: retrying the
    same dispatch cannot succeed, so it skips straight to the
    degradation ladder (smaller waves, deeper recursion).
    """
    names = {t.__name__ for t in type(exc).__mro__}
    msg = str(exc)
    markers = ("RESOURCE_EXHAUSTED", "Out of memory", "out of memory", "OOM")
    if "XlaRuntimeError" in names and any(m in msg for m in markers):
        return True
    if isinstance(exc, MemoryError):
        return True
    return isinstance(exc, RuntimeError) and any(m in msg for m in markers)


@dataclasses.dataclass(frozen=True)
class MatmulBackend:
    """Configuration for routing matmuls.

    Attributes:
      kind: one of :data:`VALID_KINDS`. 'auto' defers the choice to the
        calibrated cost model in :mod:`repro.core.autotune`, resolved per
        (M, K, N, dtype) at trace time and cached — so jitted call sites
        pay the decision once. 'strassen_oot' routes through the
        out-of-core tagged-block runtime (:mod:`repro.blocks`): host
        operands, device bytes capped by ``device_budget`` — eager-only.
      depth: Strassen recursion depth (paper's p - q). Ignored for naive;
        for 'auto' it is the maximum depth the tuner may pick; for
        'strassen_oot' it deepens automatically until the async pipeline's
        wave slot fits the budget (falling back to a bare leaf when no
        depth leaves pipeline headroom).
      min_dim: minimum of (M, K, N) below which the call falls back to the
        naive matmul (the paper's leaf threshold / crossover point).
      precision: jax precision for leaf matmuls ('default' | 'fastest' |
        'highest'...). None inherits the process default set via
        :func:`set_default_matmul_precision` — precision policy is a
        backend knob, not a per-call-site argument.
      latency_hiding: apply :data:`XLA_ASYNC_FLAGS` (latency-hiding
        scheduler + async collectives) once via :meth:`configure` — called
        by the surfaces that own a backend for a whole run (serving
        engine, out-of-core scheduler), never per call site.
      tuning_cache: optional path to a persistent autotune JSON cache
        ('auto' only). Decisions found there are reused verbatim — the
        serving engine points this at its warmed startup cache.
      measure: 'auto' only — time the top predicted candidates on device
        instead of trusting the model (slower first trace, exact winner).
      schemes: coefficient schemes 'auto' may choose between.
      device_budget: peak device bytes the out-of-core pipeline may use
        ('strassen_oot', and the gate that lets 'auto' enumerate the
        strassen_oot candidate family). None: 'strassen_oot' budgets one
        single-leaf pipelined wave slot (two leaf working sets plus one
        wave of operand prefetch); 'auto' never picks out-of-core.
    """

    kind: str = "naive"
    depth: int = 1
    min_dim: int = 1024
    precision: Optional[str] = None
    tuning_cache: Optional[str] = None
    measure: bool = False
    schemes: Tuple[str, ...] = ("strassen", "winograd")
    device_budget: Optional[int] = None
    latency_hiding: bool = False

    def __post_init__(self):
        if self.kind not in VALID_KINDS:
            raise ValueError(
                f"unknown matmul backend kind {self.kind!r}; "
                f"valid kinds: {', '.join(VALID_KINDS)}"
            )

    def configure(self) -> "MatmulBackend":
        """Apply the backend's process-level knobs once (idempotent).

        Today that is the XLA latency-hiding/async-collective flag set;
        call it from the surface that owns the backend for a run (Engine
        startup, scheduler construction) rather than per matmul.
        """
        if self.latency_hiding:
            enable_xla_async_flags()
        return self

    @property
    def scheme_name(self) -> str:
        if self.kind == "strassen_oot":
            # The out-of-core runtime executes any scheme; resolve_auto
            # pins the decision's scheme as the single schemes entry.
            return self.schemes[0] if self.schemes else "strassen"
        if self.kind in ("strassen", "strassen_fused"):
            return "strassen"
        if self.kind == "winograd":
            return "winograd"
        raise ValueError(f"no scheme for backend kind {self.kind!r}")

    def effective_depth(self, m: int, k: int, n: int) -> int:
        """Largest usable depth: dims must stay divisible and above min_dim."""
        if self.kind == "naive" or self.depth <= 0:
            return 0
        depth = 0
        while (
            depth < self.depth
            and m % 2 == 0
            and k % 2 == 0
            and n % 2 == 0
            and min(m, k, n) >= self.min_dim
        ):
            m, k, n = m // 2, k // 2, n // 2
            depth += 1
        return depth


NAIVE_BACKEND = MatmulBackend(kind="naive")
AUTO_BACKEND = MatmulBackend(kind="auto", depth=3)


@functools.lru_cache(maxsize=4096)
def resolve_auto(
    m: int,
    k: int,
    n: int,
    dtype_name: str,
    backend: MatmulBackend,
    site: Optional[str] = None,
) -> MatmulBackend:
    """Resolve kind='auto' to a concrete backend for one (M, K, N, dtype).

    Runs at trace time with static shapes, so under jit each call site pays
    the cost-model lookup exactly once per shape; the lru_cache makes every
    later trace (and every other call site with the same shape and site
    tag) free. A persistent ``backend.tuning_cache`` survives process
    restarts. ``site`` keys the decision per call site (e.g. "attn.wq" vs
    "mlp.up"), so equal-shape projections can diverge under measured mode.
    """
    from repro.core import autotune

    cache = autotune.process_cache(backend.tuning_cache)
    decision = autotune.autotune(
        m,
        k,
        n,
        jnp.dtype(dtype_name),
        min_dim=backend.min_dim,
        max_depth=max(backend.depth, 1),
        schemes=backend.schemes,
        cache=cache,
        measure=backend.measure,
        site=site,
        oot_budget=backend.device_budget,
    )
    if decision.kind == "naive":
        return dataclasses.replace(backend, kind="naive", measure=False)
    if decision.kind in ("strassen_fused", "strassen_oot"):
        # schemes pins scheme_name to the decision's scheme (the oot
        # family enumerates winograd too; fused is strassen-only today).
        return dataclasses.replace(
            backend,
            kind=decision.kind,
            depth=decision.depth,
            schemes=(decision.scheme,),
            measure=False,
        )
    return dataclasses.replace(
        backend, kind=decision.scheme, depth=decision.depth, measure=False
    )


def _matmul_oot(x, w, backend: MatmulBackend, lead, m: int, k: int, n: int):
    """Route one matmul through the out-of-core tagged-block runtime.

    Host-resident by construction: the operands are pulled to host, the
    scheduler stages leaf waves through device memory under
    ``backend.device_budget``, and the result returns as a jax array. A
    tracer here means the caller jitted the surrounding computation —
    impossible to honor (the pipeline IS the staging loop), so fail with
    the fix rather than a deep trace error.
    """
    import numpy as np

    from repro.blocks.scheduler import (
        leaf_bytes,
        min_depth_for_budget,
        pipelined_leaf_bytes,
        strassen_oot_matmul,
    )

    if isinstance(x, jax.core.Tracer) or isinstance(w, jax.core.Tracer):
        raise ValueError(
            "kind='strassen_oot' is a host-resident out-of-core pipeline and "
            "cannot run under jit; call it eagerly (launch/blocks_demo.py) or "
            "use kind='auto' without device_budget inside jitted code"
        )
    x_h = np.asarray(x).reshape(m, k)
    w_h = np.asarray(w)
    dtype = np.result_type(x_h.dtype, w_h.dtype)
    depth = max(backend.depth, 1)
    budget = backend.device_budget or pipelined_leaf_bytes(m, k, n, depth, dtype)
    # Deepen until the async pipeline's wave slot fits the budget — a
    # depth that only fits one bare leaf silently degrades the scheduler
    # to synchronous staging, which the autotuner's overlap-discounted
    # prediction did not price. Fall back to the merely-feasible depth
    # when no depth leaves pipeline headroom.
    if pipelined_leaf_bytes(m, k, n, depth, dtype) > budget:
        try:
            depth = min_depth_for_budget(m, k, n, budget, dtype, pipelined=True)
        except ValueError:
            if leaf_bytes(m, k, n, depth, dtype) > budget:
                depth = min_depth_for_budget(m, k, n, budget, dtype)
    leaf_backend = MatmulBackend(
        kind="auto", depth=2, min_dim=backend.min_dim,
        precision=resolve_precision(backend),
    )
    out, _ = strassen_oot_matmul(
        x_h,
        w_h,
        depth=depth,
        budget_bytes=budget,
        scheme=backend.scheme_name,
        backend=leaf_backend,
    )
    return jnp.asarray(out).reshape(*lead, n)


def matmul(
    x: jax.Array,
    w: jax.Array,
    backend: MatmulBackend = NAIVE_BACKEND,
    w_logical=None,
    site: Optional[str] = None,
) -> jax.Array:
    """``x @ w`` routed through the configured backend.

    Args:
      x: (..., K) activations — leading dims are flattened into M.
      w: (K, N) weights.
      backend: routing config.
      w_logical: optional (in_logical, out_logical) names for w's dims
        (e.g. ("fsdp", "d_ff")). When set, the Strassen pipeline pins every
        divide/leaf/combine level to the caller's tensor-parallel layout —
        without this GSPMD loses the sharding at the quadrant reshapes and
        silently replicates the leaf products (hypothesis log, EXPERIMENTS
        §Perf iteration 3).
      site: optional call-site tag ("attn.wq", "mlp.up", ...) for kind=
        'auto': keys the autotune decision (and its persistent cache entry)
        per call site, so same-shape projections can diverge and telemetry
        can attribute decisions.

    Returns:
      (..., N), same dtype as the naive path would produce.
    """
    if w.ndim != 2 or x.shape[-1] != w.shape[0]:
        raise ValueError(f"bad shapes {x.shape} @ {w.shape}")
    *lead, k = x.shape
    n = w.shape[1]
    m = 1
    for d in lead:
        m *= d

    # Disabled-tracer fast path: one attribute read + the shared no-op
    # context manager — this entry sits on every model projection, jitted
    # trace time included. When tracing, eager calls get true wall time;
    # under jit the span covers trace/lowering work (attr traced=True) and
    # the XLA-side timeline comes from the jax.profiler passthrough.
    with obs_tracer.get_tracer().span(
        "backend.matmul", cat="matmul", m=m, k=k, n=n,
        kind=backend.kind, site=site,
        traced=isinstance(x, jax.core.Tracer),
    ):
        return _matmul_routed(x, w, backend, w_logical, site, lead, m, k, n)


def _matmul_routed(x, w, backend, w_logical, site, lead, m, k, n):
    if backend.kind == "auto":
        if backend.device_budget is not None and (
            isinstance(x, jax.core.Tracer) or isinstance(w, jax.core.Tracer)
        ):
            # Under jit the eager-only out-of-core family is infeasible in
            # context: resolve without the budget so the decision (which
            # caches per shape) can never name a plan this call site
            # cannot execute.
            backend = dataclasses.replace(backend, device_budget=None)
        backend = resolve_auto(m, k, n, jnp.result_type(x, w).name, backend, site)

    if backend.kind == "strassen_oot":
        return _matmul_oot(x, w, backend, lead, m, k, n)

    precision = resolve_precision(backend)
    depth = backend.effective_depth(m, k, n) if backend.kind != "naive" else 0
    if depth == 0:
        return jnp.matmul(x, w, precision=precision)

    x2 = x.reshape(m, k)
    if backend.kind == "strassen_fused":
        # Pallas-fused path: divide/combine folded into the leaf kernel.
        from repro.kernels.strassen import ops as strassen_ops

        if w_logical is not None:
            # Pin the kernel's boundary shardings to the caller's
            # tensor-parallel layout — same rationale as the unfused
            # branch's per-level hooks: GSPMD loses sharding at quadrant
            # reshapes. At depth 1 (no outer einsum levels) the boundary
            # fully determines the pallas call's operand layout.
            from repro.models.sharding import constrain

            w_in, w_out = w_logical
            x2 = constrain(x2, "batch", None)
            w = constrain(w, w_in, w_out)
        out = strassen_ops.strassen_matmul_fused(
            x2, w, depth=depth, precision=precision
        )
        if w_logical is not None:
            out = constrain(out, "batch", w_logical[1])
    else:
        from repro.models.sharding import constrain

        c_a = c_b = c_out = None
        if w_logical is not None:
            w_in, w_out = w_logical
            c_a = lambda t: constrain(t, None, "batch", None)
            c_b = lambda t: constrain(t, None, w_in, w_out)
            c_out = lambda t: constrain(t, None, "batch", w_out)
        out = strassen_matmul(
            x2,
            w,
            depth=depth,
            scheme=backend.scheme_name,
            precision=precision,
            constrain_a=c_a,
            constrain_b=c_b,
            constrain_out=c_out,
        )
    return out.reshape(*lead, n)


# --------------------------------------------------------------- solver ops
def _check_solver_kind(kind: str) -> None:
    if kind not in SOLVER_KINDS:
        raise ValueError(
            f"unknown solver kind {kind!r}; "
            f"valid kinds: {', '.join(SOLVER_KINDS)}"
        )


def _solver_jit_guard(op: str, *arrays) -> None:
    if any(isinstance(x, jax.core.Tracer) for x in arrays):
        raise ValueError(
            f"solver kind 'spin_oot' is a host-resident out-of-core "
            f"pipeline and cannot run {op} under jit; jit-safe solver "
            f"kinds: {', '.join(SOLVER_JIT_SAFE_KINDS)}"
        )


def _solver_backend_scheme(backend: MatmulBackend) -> str:
    """Scheme for the solver's nested multiplies (any backend kind)."""
    return backend.schemes[0] if backend.schemes else "strassen"


def _solver_oot_depth(
    op: str, n: int, nrhs: int, dtype, backend: MatmulBackend, budget: int,
    site: Optional[str],
) -> int:
    """Autotuned solver depth (cost-modeled, cached, telemetry-recorded)."""
    from repro.core import autotune

    decision = autotune.autotune_solver(
        op,
        n,
        jnp.dtype(dtype),
        nrhs=nrhs,
        oot_budget=budget,
        max_depth=max(backend.depth, 1) + 8,
        scheme=_solver_backend_scheme(backend),
        cache=autotune.process_cache(backend.tuning_cache),
        site=site,
    )
    return decision.depth


def inverse(
    a: jax.Array,
    backend: MatmulBackend = NAIVE_BACKEND,
    *,
    kind: str = "auto",
    depth: Optional[int] = None,
    site: Optional[str] = None,
) -> jax.Array:
    """Matrix inverse routed through the configured backend.

    ``kind='dense'`` is one device ``jnp.linalg.inv``; ``kind='spin_oot'``
    runs SPIN block-recursive inversion over the tagged block runtime
    (host-resident, device bytes capped by ``backend.device_budget``);
    ``kind='auto'`` picks dense unless the dense op's working set exceeds
    the budget. The recursion's block multiplies route through this
    backend's ``kind='auto'`` dispatcher.
    """
    _check_solver_kind(kind)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"inverse needs a square matrix, got {a.shape}")
    n = a.shape[0]
    traced = isinstance(a, jax.core.Tracer)
    if kind == "auto":
        item = jnp.dtype(jnp.result_type(a, jnp.float32)).itemsize
        over = (
            backend.device_budget is not None
            and 2 * n * n * item > backend.device_budget
        )
        kind = "spin_oot" if (over and not traced) else "dense"
    with obs_tracer.get_tracer().span(
        "backend.inverse", cat="matmul", n=n, kind=kind, site=site,
        traced=traced,
    ):
        if kind == "dense":
            return jnp.linalg.inv(a)
        _solver_jit_guard("inverse", a)
        import numpy as np

        from repro.blocks.solve import solver_min_depth_for_budget, spin_inverse_oot

        a_h = np.asarray(a)
        budget = backend.device_budget or _leaf_budget_fallback(n, n, a_h.dtype)
        if depth is None:
            depth = max(
                _solver_oot_depth("inverse", n, n, a_h.dtype, backend, budget, site),
                solver_min_depth_for_budget(n, budget, a_h.dtype, leaf_kind="inv"),
            )
        out, _ = spin_inverse_oot(
            a_h,
            depth=depth,
            budget_bytes=budget,
            scheme=_solver_backend_scheme(backend),
            backend=MatmulBackend(
                kind="auto", depth=2, min_dim=backend.min_dim,
                precision=resolve_precision(backend),
            ),
        )
        return jnp.asarray(out)


def solve_triangular(
    l: jax.Array,
    b: jax.Array,
    backend: MatmulBackend = NAIVE_BACKEND,
    *,
    lower: bool = True,
    kind: str = "auto",
    depth: Optional[int] = None,
    site: Optional[str] = None,
) -> jax.Array:
    """Triangular solve ``T @ X = B`` routed through the configured backend.

    Same routing contract as :func:`inverse`: 'dense' is one device
    ``jax.scipy.linalg.solve_triangular``, 'spin_oot' the block-recursive
    forward/backward substitution whose multiplies re-enter the matmul
    scheduler, 'auto' picks against ``backend.device_budget``.
    """
    _check_solver_kind(kind)
    if l.ndim != 2 or l.shape[0] != l.shape[1] or b.ndim != 2:
        raise ValueError(f"bad solve_triangular shapes {l.shape} / {b.shape}")
    if l.shape[1] != b.shape[0]:
        raise ValueError(f"bad solve_triangular shapes {l.shape} @ {b.shape}")
    n, nrhs = l.shape[0], b.shape[1]
    traced = isinstance(l, jax.core.Tracer) or isinstance(b, jax.core.Tracer)
    if kind == "auto":
        item = jnp.dtype(jnp.result_type(l, b, jnp.float32)).itemsize
        over = (
            backend.device_budget is not None
            and (n * n + 2 * n * nrhs) * item > backend.device_budget
        )
        kind = "spin_oot" if (over and not traced) else "dense"
    with obs_tracer.get_tracer().span(
        "backend.solve", cat="matmul", n=n, nrhs=nrhs, kind=kind,
        lower=lower, site=site, traced=traced,
    ):
        if kind == "dense":
            import jax.scipy.linalg as jsl

            return jsl.solve_triangular(l, b, lower=lower)
        _solver_jit_guard("solve_triangular", l, b)
        import numpy as np

        from repro.blocks.solve import (
            solver_min_depth_for_budget,
            triangular_solve_oot,
        )

        l_h, b_h = np.asarray(l), np.asarray(b)
        dtype = np.result_type(l_h.dtype, b_h.dtype)
        budget = backend.device_budget or _leaf_budget_fallback(n, nrhs, dtype)
        if depth is None:
            depth = max(
                _solver_oot_depth("solve", n, nrhs, dtype, backend, budget, site),
                solver_min_depth_for_budget(
                    n, budget, dtype, nrhs=nrhs, leaf_kind="trsm_lower"
                ),
            )
        out, _ = triangular_solve_oot(
            l_h,
            b_h,
            lower=lower,
            depth=depth,
            budget_bytes=budget,
            scheme=_solver_backend_scheme(backend),
            backend=MatmulBackend(
                kind="auto", depth=2, min_dim=backend.min_dim,
                precision=resolve_precision(backend),
            ),
        )
        return jnp.asarray(out)


def _leaf_budget_fallback(n: int, nrhs: int, dtype) -> int:
    """Budget when a solver is forced out-of-core without device_budget:
    one depth-1 dense leaf's working set (mirrors _matmul_oot's single
    pipelined-slot default)."""
    import numpy as np

    item = np.dtype(np.result_type(np.dtype(dtype), np.float32)).itemsize
    half = -(-n // 2)
    return max(2 * half * half, half * half + 2 * half * nrhs) * item

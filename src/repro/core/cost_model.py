"""Paper §IV stage-wise cost model for Stark, Marlin, and MLLib.

Reproduces the paper's analytical wall-clock model: each Spark stage has a
computation cost, a communication cost, and a parallelization factor (PF);
stage wall-clock ~ (comp * t_flop + comm * t_elem) / PF, and total
wall-clock is the sum over serially executed stages.

Notation (paper §IV):
    n = 2**p      matrix dimension
    b = 2**(p-q)  number of splits per side (partition count)
    n/b = 2**q    block size
    cores         physical cores in the cluster

The model is used by benchmarks/fig9..fig11 to reproduce the paper's
theory-vs-experiment comparison, with per-environment constants calibrated
from two micro-measurements (a block matmul and a block add) — the same
procedure the paper uses implicitly by plotting both curves in arbitrary
units.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List

__all__ = [
    "StageCost",
    "CostModel",
    "stark_stages",
    "marlin_stages",
    "mllib_stages",
    "total_cost",
]


@dataclasses.dataclass(frozen=True)
class StageCost:
    """One Spark stage: the paper's (Computation, Communication, PF) triple."""

    name: str
    section: str  # divide | leaf | combine | shuffle | preprocess
    computation: float  # scalar op count
    communication: float  # elements moved
    parallelization: float  # PF (before min with cores)

    def wall_clock(
        self, cores: int, t_flop: float, t_elem: float, *, overlap: bool = False
    ) -> float:
        pf = min(self.parallelization, cores)
        pf = max(pf, 1.0)
        comp_s = self.computation * t_flop
        comm_s = self.communication * t_elem
        if overlap:
            # Latency-hidden regime: the engine issues a stage's transfers
            # while its compute runs (the oot scheduler's async wave
            # pipeline / an overlapped Spark shuffle), so the stage costs
            # the longer of the two streams instead of their sum.
            return max(comp_s, comm_s) / pf
        return (comp_s + comm_s) / pf


@dataclasses.dataclass(frozen=True)
class CostModel:
    """Calibrated environment constants.

    t_flop: seconds per scalar multiply-add in the leaf matmul.
    t_elem: seconds per element moved through a shuffle/collective.
    """

    t_flop: float = 1.0e-9
    t_elem: float = 4.0e-9

    def total(
        self, stages: List[StageCost], cores: int, *, overlap: bool = False
    ) -> float:
        return sum(
            s.wall_clock(cores, self.t_flop, self.t_elem, overlap=overlap)
            for s in stages
        )

    def by_section(
        self, stages: List[StageCost], cores: int, *, overlap: bool = False
    ) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for s in stages:
            out[s.section] = out.get(s.section, 0.0) + s.wall_clock(
                cores, self.t_flop, self.t_elem, overlap=overlap
            )
        return out


def _check(n: int, b: int) -> int:
    if n & (n - 1) or b & (b - 1) or b < 1 or b > n:
        raise ValueError(f"need powers of two with b<=n, got n={n} b={b}")
    return int(math.log2(b))  # = p - q


def stark_stages(n: int, b: int) -> List[StageCost]:
    """Stark (paper Table III). b = 2**(p-q) splits; depth l = p - q levels.

    Stage count = 2(p-q) + 2 (paper eq. 25).
    """
    l = _check(n, b)
    stages: List[StageCost] = []
    blk = n // b  # leaf block side
    # Divide section: levels i = 0 .. l-1. At level i there are 7^i groups,
    # each holding matrices of side n/2^i made of (b/2^i)^2 blocks.
    for i in range(l):
        elems = (7.0 / 4.0) ** i * 2 * n * n  # elements processed this level
        blocks = (7.0 / 4.0) ** i * 2 * b * b
        # flatMap replicate (comp ~ blocks touched) + groupByKey shuffle
        stages.append(
            StageCost(
                name=f"divide[{i}].flatMap",
                section="divide",
                computation=blocks,
                communication=3.0 * elems,  # paper eq. 28: factor-3 replication
                parallelization=min(blocks, 7.0 ** (i + 1) * (b / 2**i) ** 2),
            )
        )
        stages.append(
            StageCost(
                name=f"divide[{i}].add",
                section="divide",
                computation=3.0 * elems,  # 12 adds of quarter-size blocks ~ 3 n_i^2
                communication=0.0,
                parallelization=7.0 ** (i + 1) * (b / 2 ** (i + 1)) ** 2,
            )
        )
    # Leaf section (paper eq. 31-33): 7^l block pairs shuffled then multiplied.
    leaves = 7.0**l
    stages.append(
        StageCost(
            name="leaf.shuffle",
            section="leaf",
            computation=0.0,
            communication=2.0 * leaves * blk * blk,
            parallelization=leaves,
        )
    )
    stages.append(
        StageCost(
            name="leaf.matmul",
            section="leaf",
            computation=leaves * float(blk) ** 3,  # b^2.807 * (n/b)^3
            communication=0.0,
            parallelization=leaves,
        )
    )
    # Combine section: levels i = l-1 .. 0 (paper eq. 34-37).
    for i in reversed(range(l)):
        groups = 7.0**i
        elems = (7.0 / 4.0) ** (i + 1) * n * n
        stages.append(
            StageCost(
                name=f"combine[{i}].shuffle",
                section="combine",
                computation=(7.0 / 4.0) ** (i + 1) * b * b,
                communication=elems,
                parallelization=max(groups, 1.0) * (b / 2 ** (i + 1)) ** 2,
            )
        )
        stages.append(
            StageCost(
                name=f"combine[{i}].add",
                section="combine",
                computation=groups * 12.0 * (n / b) ** 2 * 4.0 ** (l - 1 - i),
                communication=0.0,
                parallelization=max(groups, 1.0) * (b / 2 ** (i + 1)) ** 2,
            )
        )
    return stages


def marlin_stages(n: int, b: int) -> List[StageCost]:
    """Marlin (paper Table II / Lemma IV.1)."""
    _check(n, b)
    blk = n // b
    return [
        StageCost(
            "stage1.flatMapA", "divide", 2.0 * b**3, 2.0 * b * n * n, 2.0 * b * b
        ),
        StageCost(
            "stage1.flatMapB", "divide", 2.0 * b**3, 2.0 * b * n * n, 2.0 * b * b
        ),
        StageCost("stage3.join", "shuffle", 0.0, float(b) * n * n, float(b) ** 3),
        StageCost(
            "stage3.mapPartition",
            "leaf",
            float(b) ** 3 * float(blk) ** 3,
            0.0,
            float(b) ** 3,
        ),
        StageCost(
            "stage4.reduceByKey", "combine", float(b) * n * n, float(b) * n * n, float(b) ** 2
        ),
    ]


def mllib_stages(n: int, b: int) -> List[StageCost]:
    """MLLib BlockMatrix.multiply (paper Table I / eq. 9)."""
    _check(n, b)
    blk = n // b
    return [
        StageCost("simulate", "preprocess", 0.0, 2.0 * (n / b) ** 2, 1.0),
        StageCost("stage1.flatMapA", "divide", float(b) ** 3, 0.0, float(b) ** 2),
        StageCost("stage1.flatMapB", "divide", float(b) ** 3, 0.0, float(b) ** 2),
        StageCost(
            "stage3.coGroup", "shuffle", 0.0, 2.0 * b * n * n, float(b) ** 2
        ),
        StageCost(
            "stage3.flatMap", "leaf", float(b) ** 3 * float(blk) ** 3, 0.0, float(b) ** 2
        ),
        StageCost(
            "stage4.reduceByKey", "combine", float(b) * n * n, 0.0, float(b) ** 2
        ),
    ]


_SYSTEMS = {
    "stark": stark_stages,
    "marlin": marlin_stages,
    "mllib": mllib_stages,
}


def total_cost(
    system: str,
    n: int,
    b: int,
    cores: int,
    model: CostModel | None = None,
    *,
    overlap: bool = False,
) -> float:
    """Predicted wall-clock seconds for one distributed multiply.

    ``overlap=True`` prices each stage at max(compute, communication)
    instead of their sum — the latency-hidden regime an async pipeline
    (or an overlapped shuffle) achieves.
    """
    model = model or CostModel()
    return model.total(_SYSTEMS[system](n, b), cores, overlap=overlap)


def stage_count(system: str, n: int, b: int) -> int:
    """Number of StageCost entries (steps — finer than Spark stages)."""
    return len(_SYSTEMS[system](n, b))


def paper_stage_count(n: int, b: int) -> int:
    """Stark's Spark-stage count, paper eq. 25: 2(p-q) + 2."""
    return 2 * _check(n, b) + 2

"""Strassen-family coefficient schemes as constant +/-1 matrices.

A fast 2x2 block-matmul scheme with r multiplications is a triple of
coefficient matrices (A_COEF, B_COEF, C_COEF):

    M_p   = (sum_q A_COEF[p, q] * A_q) @ (sum_q B_COEF[p, q] * B_q)
    C_k   =  sum_p C_COEF[k, p] * M_p

where quadrants are enumerated row-major: [X11, X12, X21, X22].

The paper (Algorithm 1) uses Strassen's original 7-multiplication scheme.
We additionally ship the Winograd variant (7 mults, 15 additions in staged
form vs Strassen's 18) as a beyond-paper optimization, and the naive
8-multiplication scheme as the MLLib/Marlin-style baseline.

Paper erratum: Algorithm 1 in the paper lists C22 = M1 - M2 - M3 + M6;
the correct identity is C22 = M1 - M2 + M3 + M6 (validated in tests).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

__all__ = [
    "Scheme",
    "STRASSEN",
    "WINOGRAD",
    "NAIVE8",
    "get_scheme",
]


@dataclasses.dataclass(frozen=True)
class Scheme:
    """A 2x2 fast-matmul scheme.

    Attributes:
      name: scheme identifier.
      a_coef: (r, 4) left-operand coefficients over [A11, A12, A21, A22].
      b_coef: (r, 4) right-operand coefficients over [B11, B12, B21, B22].
      c_coef: (4, r) combine coefficients producing [C11, C12, C21, C22].
      n_mults: r, the number of block multiplications (the paper's key metric:
        7 for Stark vs 8 for MLLib/Marlin).
      n_adds: block additions/subtractions in the *staged* (serial) form;
        used by the cost model.
    """

    name: str
    a_coef: np.ndarray
    b_coef: np.ndarray
    c_coef: np.ndarray
    n_mults: int
    n_adds: int

    def __post_init__(self):
        r = self.a_coef.shape[0]
        assert self.a_coef.shape == (r, 4), self.a_coef.shape
        assert self.b_coef.shape == (r, 4), self.b_coef.shape
        assert self.c_coef.shape == (4, r), self.c_coef.shape
        assert self.n_mults == r

    @property
    def rank(self) -> int:
        return self.n_mults

    def exponent(self) -> float:
        """The asymptotic exponent log2(n_mults): 2.807 for Strassen, 3 for naive."""
        return float(np.log2(self.n_mults))

    def validate(self) -> None:
        """Check the bilinear identity <C_k> == sum over the 2x2 algebra.

        The scheme is correct iff for all k=(i,j), and all quadrant pairs
        (q_a=(i,l), q_b=(l,j)):

            sum_p c_coef[k,p] * a_coef[p,q_a] * b_coef[p,q_b]
                == 1 if (row(q_a)==row(k) and col(q_a)==row(q_b)
                         and col(q_b)==col(k)) else 0
        """
        # Tensor T[k, qa, qb] produced by the scheme.
        t = np.einsum("kp,pq,pr->kqr", self.c_coef, self.a_coef, self.b_coef)
        # Target matmul tensor for 2x2: C[i,j] = sum_l A[i,l] B[l,j].
        want = np.zeros((4, 4, 4))
        for i in range(2):
            for j in range(2):
                for l in range(2):
                    want[i * 2 + j, i * 2 + l, l * 2 + j] = 1.0
        if not np.array_equal(t, want):
            raise ValueError(f"scheme {self.name} fails bilinear identity")


def _arr(rows) -> np.ndarray:
    return np.array(rows, dtype=np.float64)


# --- Strassen's original scheme (paper Algorithm 1, with C22 erratum fixed).
# Quadrant order: [11, 12, 21, 22].
STRASSEN = Scheme(
    name="strassen",
    a_coef=_arr(
        [
            [1, 0, 0, 1],   # M1: (A11 + A22)
            [0, 0, 1, 1],   # M2: (A21 + A22)
            [1, 0, 0, 0],   # M3: A11
            [0, 0, 0, 1],   # M4: A22
            [1, 1, 0, 0],   # M5: (A11 + A12)
            [-1, 0, 1, 0],  # M6: (A21 - A11)
            [0, 1, 0, -1],  # M7: (A12 - A22)
        ]
    ),
    b_coef=_arr(
        [
            [1, 0, 0, 1],   # M1: (B11 + B22)
            [1, 0, 0, 0],   # M2: B11
            [0, 1, 0, -1],  # M3: (B12 - B22)
            [-1, 0, 1, 0],  # M4: (B21 - B11)
            [0, 0, 0, 1],   # M5: B22
            [1, 1, 0, 0],   # M6: (B11 + B12)
            [0, 0, 1, 1],   # M7: (B21 + B22)
        ]
    ),
    c_coef=_arr(
        [
            # M1  M2  M3  M4  M5  M6  M7
            [1, 0, 0, 1, -1, 0, 1],   # C11 = M1 + M4 - M5 + M7
            [0, 0, 1, 0, 1, 0, 0],    # C12 = M3 + M5
            [0, 1, 0, 1, 0, 0, 0],    # C21 = M2 + M4
            [1, -1, 1, 0, 0, 1, 0],   # C22 = M1 - M2 + M3 + M6
        ]
    ),
    n_mults=7,
    n_adds=18,
)


# --- Winograd's variant: 7 multiplications, 15 additions in staged form.
# Beyond-paper optimization (the paper uses classic Strassen only).
WINOGRAD = Scheme(
    name="winograd",
    a_coef=_arr(
        [
            [1, 0, 0, 0],     # M1: A11
            [0, 1, 0, 0],     # M2: A12
            [1, 1, -1, -1],   # M3: S4 = A11 + A12 - A21 - A22
            [0, 0, 0, 1],     # M4: A22
            [0, 0, 1, 1],     # M5: S1 = A21 + A22
            [-1, 0, 1, 1],    # M6: S2 = A21 + A22 - A11
            [1, 0, -1, 0],    # M7: S3 = A11 - A21
        ]
    ),
    b_coef=_arr(
        [
            [1, 0, 0, 0],     # M1: B11
            [0, 0, 1, 0],     # M2: B21
            [0, 0, 0, 1],     # M3: B22
            [1, -1, -1, 1],   # M4: T4 = B11 - B12 - B21 + B22
            [-1, 1, 0, 0],    # M5: T1 = B12 - B11
            [1, -1, 0, 1],    # M6: T2 = B11 - B12 + B22  (sign: B22 - T1)
            [0, -1, 0, 1],    # M7: T3 = B22 - B12
        ]
    ),
    c_coef=_arr(
        [
            # M1  M2  M3  M4  M5  M6  M7
            [1, 1, 0, 0, 0, 0, 0],    # C11 = M1 + M2
            [1, 0, 1, 0, 1, 1, 0],    # C12 = M1 + M3 + M5 + M6
            [1, 0, 0, -1, 0, 1, 1],   # C21 = M1 - M4 + M6 + M7
            [1, 0, 0, 0, 1, 1, 1],    # C22 = M1 + M5 + M6 + M7
        ]
    ),
    n_mults=7,
    n_adds=15,
)


# --- Naive 8-multiplication block scheme: the MLLib/Marlin-style baseline.
NAIVE8 = Scheme(
    name="naive8",
    a_coef=_arr(
        [
            [1, 0, 0, 0],  # A11 (for C11 term 1)
            [0, 1, 0, 0],  # A12 (for C11 term 2)
            [1, 0, 0, 0],  # A11 (for C12 term 1)
            [0, 1, 0, 0],  # A12 (for C12 term 2)
            [0, 0, 1, 0],  # A21
            [0, 0, 0, 1],  # A22
            [0, 0, 1, 0],  # A21
            [0, 0, 0, 1],  # A22
        ]
    ),
    b_coef=_arr(
        [
            [1, 0, 0, 0],  # B11
            [0, 0, 1, 0],  # B21
            [0, 1, 0, 0],  # B12
            [0, 0, 0, 1],  # B22
            [1, 0, 0, 0],  # B11
            [0, 0, 1, 0],  # B21
            [0, 1, 0, 0],  # B12
            [0, 0, 0, 1],  # B22
        ]
    ),
    c_coef=_arr(
        [
            [1, 1, 0, 0, 0, 0, 0, 0],  # C11 = A11B11 + A12B21
            [0, 0, 1, 1, 0, 0, 0, 0],  # C12
            [0, 0, 0, 0, 1, 1, 0, 0],  # C21
            [0, 0, 0, 0, 0, 0, 1, 1],  # C22
        ]
    ),
    n_mults=8,
    n_adds=4,
)


_SCHEMES = {s.name: s for s in (STRASSEN, WINOGRAD, NAIVE8)}


def get_scheme(name: str) -> Scheme:
    try:
        return _SCHEMES[name]
    except KeyError:
        raise ValueError(f"unknown scheme {name!r}; have {sorted(_SCHEMES)}")


def leaf_tag_path(index: int, depth: int) -> Tuple[int, ...]:
    """The paper's M-index tag path for a leaf: base-7 digits of ``index``.

    Stark tags every block with a comma-separated M-index string recording
    which M_i branch it took at each recursion level. In the batched layout
    the leaf's position in the 7^depth batch encodes the same path:
    digit i (most-significant first) is the level-i branch (0-based M-index).
    """
    if not 0 <= index < 7**depth:
        raise ValueError(f"index {index} out of range for depth {depth}")
    digits = []
    for _ in range(depth):
        digits.append(index % 7)
        index //= 7
    return tuple(reversed(digits))


def leaf_index_from_path(path: Tuple[int, ...]) -> int:
    """Inverse of :func:`leaf_tag_path`."""
    index = 0
    for digit in path:
        if not 0 <= digit < 7:
            raise ValueError(f"bad M-index digit {digit}")
        index = index * 7 + digit
    return index

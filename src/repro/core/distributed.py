"""Distributed Strassen on a JAX device mesh.

Two distribution strategies, mirroring the taxonomy in the paper's related
work (§II) and adapted to TPU SPMD:

1. :func:`strassen_bfs_sharded` — Stark's own strategy (and CAPS's
   "unlimited memory" BFS scheme): take ``depth`` BFS steps so the leaf
   batch of 7^depth independent block products is sharded across devices;
   divide/combine levels are einsums whose resharding becomes XLA
   collectives. This is the paper's technique, SPMD-native: where Spark
   shuffles blocks between executors keyed by M-index tags, GSPMD moves
   exactly the blocks whose leaf shard differs — the tag IS the batch
   coordinate.

2. :func:`strassen_2d` — the "Strassen-2D" hybrid of Luo & Drake (paper
   §II-A): run Strassen levels at the top, and execute every leaf product
   as a classic 2D-parallel matmul over the (data, model) mesh. Uses O(1)
   extra memory per device relative to the naive distributed matmul and is
   the right choice when 7^depth is small compared to the device count.

3. :func:`strassen_shardmap` — an explicit-collective shard_map rendition
   of one BFS level over a 7-way mesh axis: every device group owns one
   M_p product; combine is a single weighted psum. This exists to make the
   communication pattern inspectable (tests assert its HLO contains exactly
   one psum) and as the template the Pallas-fused path follows.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.coefficients import Scheme, STRASSEN, get_scheme
from repro.core import strassen as _s
from repro.core.compat import shard_map as _shard_map

__all__ = [
    "strassen_bfs_sharded",
    "strassen_2d",
    "strassen_shardmap",
    "strassen_fused_sharded",
    "MESH_STRATEGIES",
    "register_strategy",
    "get_strategy",
    "available_strategies",
]


def _constraint(x: jax.Array, mesh: Mesh, spec: P) -> jax.Array:
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def strassen_bfs_sharded(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    depth: int,
    scheme: Scheme | str = STRASSEN,
    batch_axes: Sequence[str] = ("data", "model"),
    leaf_fn=None,
    precision=None,
) -> jax.Array:
    """Stark/CAPS-BFS: shard the 7^depth leaf batch across ``batch_axes``.

    The input/output matrices are row-sharded across the same axes (the
    natural layout for an RDD of block-rows). GSPMD inserts the all-to-all
    style collectives that correspond to Stark's divide/combine shuffles.
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    axes = tuple(batch_axes)
    row_spec = P(axes, None)
    # Leaf batch m = 7^depth over the FIRST axis only (uneven shards are
    # padded: 343 over 16 wastes 2.6%); block rows over the second axis.
    # Sharding m over the full 256-device mesh replicates whenever
    # m < devices — measured 33x flops blowup — so rows carry the rest.
    if len(axes) > 1:
        batch_spec = P(axes[0], axes[1:], None)
    else:
        batch_spec = P(axes[0], None, None)

    a = _constraint(a, mesh, row_spec)
    b = _constraint(b, mesh, row_spec)

    a_coef = jnp.asarray(scheme.a_coef)
    b_coef = jnp.asarray(scheme.b_coef)
    c_coef = jnp.asarray(scheme.c_coef)

    ta, tb = a[None], b[None]
    for _ in range(depth):
        ta = _constraint(_s.divide_level(ta, a_coef), mesh, batch_spec)
        tb = _constraint(_s.divide_level(tb, b_coef), mesh, batch_spec)

    if leaf_fn is None:
        prod = jnp.einsum("mij,mjk->mik", ta, tb, precision=precision)
    else:
        prod = leaf_fn(ta, tb)
    prod = _constraint(prod, mesh, batch_spec)

    for _ in range(depth):
        prod = _constraint(_s.combine_level(prod, c_coef), mesh, batch_spec)
    return _constraint(prod[0], mesh, row_spec)


def strassen_2d(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    depth: int,
    scheme: Scheme | str = STRASSEN,
    row_axis: str = "data",
    col_axis: str = "model",
    precision=None,
) -> jax.Array:
    """Strassen-2D (Luo & Drake): Strassen on top, 2D-parallel leaves.

    Every one of the 7^depth leaf products is computed as a classic
    2D-sharded matmul: A_leaf row-sharded over ``row_axis``, B_leaf
    col-sharded over ``col_axis``, C_leaf sharded over both. The leaf batch
    stays replicated, so combine levels are communication-free — trading
    leaf-stage bandwidth for a collective-free combine (the reverse of the
    BFS scheme; see EXPERIMENTS.md §Perf for the crossover).
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)

    def leaf(ta: jax.Array, tb: jax.Array) -> jax.Array:
        ta = _constraint(ta, mesh, P(None, row_axis, None))
        tb = _constraint(tb, mesh, P(None, None, col_axis))
        out = jnp.einsum("mij,mjk->mik", ta, tb, precision=precision)
        return _constraint(out, mesh, P(None, row_axis, col_axis))

    out = _s.strassen_matmul(a, b, depth=depth, scheme=scheme, leaf_fn=leaf)
    return _constraint(out, mesh, P(row_axis, col_axis))


def strassen_shardmap_2d(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    rows_axis: str = "rows",
    mult_axis: str = "mult",
    scheme: Scheme | str = STRASSEN,
    precision=None,
) -> jax.Array:
    """Explicit one-level Strassen on a (rows x 7) grid — zero GSPMD guessing.

    The paper's processor layout, TPU-native: the 7-way ``mult`` axis owns
    one M_p each (Stark's seven parallel sub-matrix groups), the ``rows``
    axis splits each M_p's row range (Stark's per-executor block rows).
    Inputs replicated (n^2 bf16 fits HBM at n=16384): divide is LOCAL
    arithmetic; the ONLY collective is one psum over ``mult`` that fuses
    Stark's entire combine phase — measured vs the GSPMD variants this is
    the version whose collective term matches the napkin math.
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    n = a.shape[0]
    n_rows = mesh.shape[rows_axis]
    assert mesh.shape[mult_axis] == scheme.n_mults
    blk = (n // 2) // n_rows
    a_coef = jnp.asarray(scheme.a_coef)
    b_coef = jnp.asarray(scheme.b_coef)
    c_coef = jnp.asarray(scheme.c_coef)

    def body(a_rep, b_rep):
        r = jax.lax.axis_index(rows_axis)
        p = jax.lax.axis_index(mult_axis)
        aq = _s.split_quadrants(a_rep)  # (4, n/2, n/2) local views
        bq = _s.split_quadrants(b_rep)
        # left operand: only OUR row stripe of the combo (slice THEN add)
        aq_rows = jax.lax.dynamic_slice_in_dim(aq, r * blk, blk, axis=1)
        left = jnp.einsum("q,qij->ij", a_coef[p].astype(a_rep.dtype), aq_rows)
        right = jnp.einsum("q,qij->ij", b_coef[p].astype(b_rep.dtype), bq)
        mp_rows = jnp.matmul(left, right, precision=precision)  # (blk, n/2)
        contrib = c_coef[:, p].astype(mp_rows.dtype)[:, None, None] * mp_rows[None]
        return jax.lax.psum(contrib, mult_axis)  # (4, blk, n/2)

    quads = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(None, rows_axis, None),
    )(a, b)  # (4, n/2, n/2)
    return _s.merge_quadrants(quads)


def strassen_shardmap_3d(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    rb_axis: str = "rb",
    cb_axis: str = "cb",
    mult_axis: str = "mult",
    scheme: Scheme | str = STRASSEN,
    precision=None,
    merge: bool = True,
) -> jax.Array:
    """Explicit one-level Strassen on an (rb x cb x 7) grid.

    merge=False returns C in quadrant-block layout (4, n/2, n/2) — the
    paper's own Block data structure — avoiding the cross-shard interleave
    of merge_quadrants (a pure layout change that costs a full reshard).

    Iteration 3 of the matmul hillclimb: shardmap_2d was memory-bound on
    whole-quadrant right operands. Here each device owns one (row-stripe,
    col-stripe) tile of one M_p: it reads only its stripes of the
    replicated inputs, computes a (blk_r, n/2) x (n/2, blk_c) product, and
    the single psum over ``mult`` both combines Stark's seven products and
    leaves C tile-sharded over (rb, cb) — the 2.5D-Strassen layout of
    CAPS, with the contraction dim kept local.
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    n = a.shape[0]
    nrb, ncb = mesh.shape[rb_axis], mesh.shape[cb_axis]
    assert mesh.shape[mult_axis] == scheme.n_mults
    blk_r = (n // 2) // nrb
    blk_c = (n // 2) // ncb
    a_coef = jnp.asarray(scheme.a_coef)
    b_coef = jnp.asarray(scheme.b_coef)
    c_coef = jnp.asarray(scheme.c_coef)

    n2 = n // 2

    def body(a_rep, b_rep):
        r = jax.lax.axis_index(rb_axis)
        c = jax.lax.axis_index(cb_axis)
        p = jax.lax.axis_index(mult_axis)

        # Static +/-1 combos per mult-shard: each branch reads ONLY the
        # quadrant stripes its coefficients touch (avg 12/7 of 4), sliced
        # DIRECTLY from the replicated inputs (split_quadrants' transpose
        # would materialize a full n^2 copy — measured +2.1 GB/device).
        def a_stripe(qi, r_):
            row0 = (qi // 2) * n2 + r_ * blk_r
            col0 = (qi % 2) * n2
            return jax.lax.dynamic_slice(a_rep, (row0, col0), (blk_r, n2))

        def b_stripe(qi, c_):
            row0 = (qi // 2) * n2
            col0 = (qi % 2) * n2 + c_ * blk_c
            return jax.lax.dynamic_slice(b_rep, (row0, col0), (n2, blk_c))

        def make_branch(pi):
            def branch(operands):
                a_, b_, r_, c_ = operands
                left = None
                for qi in range(4):
                    coef = float(scheme.a_coef[pi, qi])
                    if coef == 0.0:
                        continue
                    stripe = a_stripe(qi, r_)
                    term = stripe if coef == 1.0 else coef * stripe
                    left = term if left is None else left + term
                right = None
                for qi in range(4):
                    coef = float(scheme.b_coef[pi, qi])
                    if coef == 0.0:
                        continue
                    stripe = b_stripe(qi, c_)
                    term = stripe if coef == 1.0 else coef * stripe
                    right = term if right is None else right + term
                mp = jnp.matmul(left, right, precision=precision)
                cc = scheme.c_coef[:, pi]
                return jnp.stack(
                    [float(cc[k]) * mp for k in range(4)], axis=0
                )

            return branch

        contrib = jax.lax.switch(
            p, [make_branch(pi) for pi in range(scheme.n_mults)],
            (a_rep, b_rep, r, c),
        )
        return jax.lax.psum(contrib, mult_axis)  # (4, blk_r, blk_c)

    quads = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(None, rb_axis, cb_axis),
    )(a, b)  # (4, n/2, n/2) tile-sharded
    return _s.merge_quadrants(quads) if merge else quads


def strassen_shardmap(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    axis: str = "mult",
    scheme: Scheme | str = STRASSEN,
    precision=None,
) -> jax.Array:
    """One explicit BFS level over a mesh axis of size 7 (rank of the scheme).

    Device p forms its operand combos locally (replicated inputs), computes
    M_p, then the combine is ONE weighted psum:

        C_quadrants = psum_p( c_coef[:, p] outer* M_p )

    i.e. Stark's combine groupByKey collapses to a single all-reduce whose
    payload is 4 * (n/2)^2 — strictly less than shuffling all 7 products.
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    if mesh.shape[axis] != scheme.n_mults:
        raise ValueError(
            f"axis {axis!r} must have size {scheme.n_mults}, got {mesh.shape[axis]}"
        )
    a_coef = jnp.asarray(scheme.a_coef)
    b_coef = jnp.asarray(scheme.b_coef)
    c_coef = jnp.asarray(scheme.c_coef)

    def body(a_loc, b_loc):
        p = jax.lax.axis_index(axis)
        aq = _s.split_quadrants(a_loc)  # (4, m/2, k/2)
        bq = _s.split_quadrants(b_loc)
        left = jnp.einsum("q,qij->ij", a_coef[p].astype(a_loc.dtype), aq)
        right = jnp.einsum("q,qij->ij", b_coef[p].astype(b_loc.dtype), bq)
        m_p = jnp.matmul(left, right, precision=precision)
        # Weighted contribution of M_p to all four C quadrants, then one psum.
        contrib = c_coef[:, p].astype(m_p.dtype)[:, None, None] * m_p[None]
        quads = jax.lax.psum(contrib, axis)
        return _s.merge_quadrants(quads)

    fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P()),
        out_specs=P(),
    )
    return fn(a, b)


def strassen_fused_sharded(
    a: jax.Array,
    b: jax.Array,
    *,
    mesh: Mesh,
    depth: int,
    scheme: Scheme | str = STRASSEN,
    rows_axes: Sequence[str] = ("data", "model"),
    precision=None,
) -> jax.Array:
    """Row-parallel Strassen with the fused Pallas leaf under shard_map.

    Each device owns an M-stripe of A (and of C) with B replicated — the
    communication pattern of the classic row-parallel matmul (one B
    broadcast, no combine collective) — but the per-device product runs
    :func:`repro.kernels.strassen.ops.strassen_matmul_fused`, so the last
    Strassen level (divide + 7 MXU products + combine) never leaves VMEM.
    This is the Huang-et-al. fused-leaf insight lifted to the mesh: the
    7/4x M-term blowup that dominates the BFS strategies' HBM traffic is
    gone, and the only interconnect term is the one-time B replication.

    Rows shard over EVERY ``rows_axes`` axis present in the mesh (data and
    model, for this repo's canonical meshes), so the whole device count
    carries leaf work — which is what :func:`repro.core.autotune
    .predict_seconds` charges it. M is zero-padded up to the stripe grain
    (row shards * 2**depth) and sliced back, so any shape the autotuner
    enumerates (dims divisible by 2**depth) executes.

    On CPU hosts :func:`repro.core.compat.pallas_leaf_mode` reports
    'interpret' and the kernel runs in interpret mode (bit-faithful, slow);
    if pallas is unavailable entirely the body falls back to the jnp
    reference pipeline, so the strategy stays callable everywhere.
    """
    from repro.core.compat import pallas_leaf_mode

    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    mode = pallas_leaf_mode()
    axes = tuple(ax for ax in rows_axes if ax in mesh.shape)
    if not axes:
        raise ValueError(f"none of {rows_axes} in mesh axes {tuple(mesh.shape)}")
    n_rows = 1
    for ax in axes:
        n_rows *= mesh.shape[ax]
    m = a.shape[0]
    grain = n_rows * 2**depth
    mp = -(-m // grain) * grain
    a_p = jnp.pad(a, ((0, mp - m), (0, 0))) if mp != m else a

    def body(a_loc, b_rep):
        if mode == "none":
            return _s.strassen_matmul(
                a_loc, b_rep, depth=depth, scheme=scheme, precision=precision
            )
        # Imported here, not at function entry: pulling in the ops module
        # imports pallas, which is exactly what mode == 'none' says this
        # host cannot do — the jnp fallback above must stay reachable.
        from repro.kernels.strassen.ops import strassen_matmul_fused_padded

        return strassen_matmul_fused_padded(
            a_loc,
            b_rep,
            depth=depth,
            scheme_name=scheme.name,
            interpret=(mode != "compiled"),
            precision=precision,
        )

    fn = _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes, None), P()),
        out_specs=P(axes, None),
    )
    out = fn(a_p, b)
    return out[:m] if mp != m else out


# --------------------------------------------------------------------------
# Strategy registry — the autotuner's enumeration surface.
#
# Each entry maps a stable name to (fn, requires). ``requires(mesh, scheme)``
# answers whether the strategy can run on that mesh at all (e.g. the shardmap
# variants need a mesh axis exactly equal to the scheme rank); the autotuner
# only costs candidates whose requirement holds. Registration is open so
# future PRs (Pallas-fused mesh leaf, 2.5D variants) plug in without touching
# the dispatcher.
# --------------------------------------------------------------------------


def _axes_cover(mesh: Mesh, names: Sequence[str]) -> bool:
    return all(n in mesh.shape for n in names)


def _req_bfs(mesh: Mesh, scheme: Scheme) -> bool:
    return _axes_cover(mesh, ("data", "model"))


def _req_2d(mesh: Mesh, scheme: Scheme) -> bool:
    return _axes_cover(mesh, ("data", "model"))


def _req_shardmap(mesh: Mesh, scheme: Scheme) -> bool:
    return mesh.shape.get("mult") == scheme.n_mults


def _req_shardmap_2d(mesh: Mesh, scheme: Scheme) -> bool:
    return "rows" in mesh.shape and mesh.shape.get("mult") == scheme.n_mults


def _req_shardmap_3d(mesh: Mesh, scheme: Scheme) -> bool:
    return (
        _axes_cover(mesh, ("rb", "cb"))
        and mesh.shape.get("mult") == scheme.n_mults
    )


def _req_fused_sharded(mesh: Mesh, scheme: Scheme) -> bool:
    # Enumerable only where the Pallas leaf actually runs (compiled on TPU,
    # interpret elsewhere); the 'none' fallback inside the strategy is for
    # direct callers, not the autotuner.
    from repro.core.compat import pallas_leaf_mode

    return "data" in mesh.shape and pallas_leaf_mode() != "none"


MESH_STRATEGIES: dict = {}


def register_strategy(name: str, fn, requires) -> None:
    """Register a distributed matmul strategy for autotune enumeration."""
    MESH_STRATEGIES[name] = (fn, requires)


def get_strategy(name: str):
    return MESH_STRATEGIES[name][0]


def available_strategies(mesh: Optional[Mesh], scheme: Scheme | str = STRASSEN):
    """Names of registered strategies whose mesh requirement holds."""
    if mesh is None:
        return []
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    return [n for n, (_, req) in MESH_STRATEGIES.items() if req(mesh, scheme)]


register_strategy("strassen_bfs_sharded", strassen_bfs_sharded, _req_bfs)
register_strategy("strassen_2d", strassen_2d, _req_2d)
register_strategy("strassen_shardmap", strassen_shardmap, _req_shardmap)
register_strategy("strassen_shardmap_2d", strassen_shardmap_2d, _req_shardmap_2d)
register_strategy("strassen_shardmap_3d", strassen_shardmap_3d, _req_shardmap_3d)
register_strategy("strassen_fused_sharded", strassen_fused_sharded, _req_fused_sharded)

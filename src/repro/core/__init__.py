"""Core library: the paper's contribution (distributed Strassen matmul).

Public API:
  coefficients  — Strassen/Winograd/naive8 schemes as constant matrices
  strassen      — serial recursive + batched-BFS Strassen
  distributed   — mesh-sharded variants (BFS-sharded, Strassen-2D, shard_map)
  backend       — pluggable matmul routing used by all model layers
  cost_model    — the paper's §IV stage-wise analytical cost model
"""
from repro.core import compat  # noqa: F401  (applies jax version shims)
from repro.core.coefficients import STRASSEN, WINOGRAD, NAIVE8, Scheme, get_scheme
from repro.core.strassen import (
    strassen_matmul,
    strassen_recursive,
    divide_level,
    combine_level,
    split_quadrants,
    merge_quadrants,
    leaf_count,
)
from repro.core.backend import MatmulBackend, matmul, NAIVE_BACKEND, AUTO_BACKEND
# NOTE: the autotune *functions* stay namespaced (repro.core.autotune.autotune)
# so the submodule attribute isn't shadowed; only the data types re-export.
from repro.core.autotune import Calibration, Candidate, Decision, TuningCache

__all__ = [
    "STRASSEN",
    "WINOGRAD",
    "NAIVE8",
    "Scheme",
    "get_scheme",
    "strassen_matmul",
    "strassen_recursive",
    "divide_level",
    "combine_level",
    "split_quadrants",
    "merge_quadrants",
    "leaf_count",
    "MatmulBackend",
    "matmul",
    "NAIVE_BACKEND",
    "AUTO_BACKEND",
    "Calibration",
    "Candidate",
    "Decision",
    "TuningCache",
]

"""Core library: the paper's contribution (distributed Strassen matmul).

Public API:
  coefficients  — Strassen/Winograd/naive8 schemes as constant matrices
  strassen      — serial recursive + batched-BFS Strassen
  distributed   — mesh-sharded variants (BFS-sharded, Strassen-2D, shard_map)
  backend       — pluggable matmul routing used by all model layers
  cost_model    — the paper's §IV stage-wise analytical cost model
"""
from repro.core.coefficients import STRASSEN, WINOGRAD, NAIVE8, Scheme, get_scheme
from repro.core.strassen import (
    strassen_matmul,
    strassen_recursive,
    divide_level,
    combine_level,
    split_quadrants,
    merge_quadrants,
    leaf_count,
)
from repro.core.backend import MatmulBackend, matmul, NAIVE_BACKEND

__all__ = [
    "STRASSEN",
    "WINOGRAD",
    "NAIVE8",
    "Scheme",
    "get_scheme",
    "strassen_matmul",
    "strassen_recursive",
    "divide_level",
    "combine_level",
    "split_quadrants",
    "merge_quadrants",
    "leaf_count",
    "MatmulBackend",
    "matmul",
    "NAIVE_BACKEND",
]

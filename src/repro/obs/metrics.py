"""Process-local counters, gauges, and fixed-bucket histograms.

The registry is deliberately small: named instruments created on first
use, a ``snapshot()`` that returns plain dicts (JSON-able, embeddable
in ``Engine.stats()["obs"]`` and benchmark reports), and a lock per
instrument so concurrent engines / scheduler threads can record safely.

Histograms use Prometheus ``le`` semantics — a value lands in the
first bucket whose upper bound is **>= v** (boundary values belong to
the bucket they bound). Alongside the fixed buckets each histogram
keeps a bounded reservoir of raw samples; while the reservoir has not
overflowed, ``percentile()`` is exact and matches
``numpy.percentile(..., interpolation="linear")`` bit-for-bit — that
is what lets ``benchmarks/serve_load.py`` gate its obs-derived
TTFT/TPOT percentiles against per-request ``latency_stats()``.
"""
from __future__ import annotations

import bisect
import math
import threading
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "Metrics",
    "get_metrics",
    "reset_metrics",
    "TIME_BUCKETS_S",
    "BYTES_BUCKETS",
]

# Exponential upper bounds covering 10 µs .. 100 s — wide enough for
# TTFT on CPU smoke runs and for full out-of-core wave times.
TIME_BUCKETS_S: Tuple[float, ...] = tuple(
    round(10.0 ** (e / 2.0), 10) for e in range(-10, 5)
)

# Power-of-4 byte buckets: 1 KiB .. 16 GiB.
BYTES_BUCKETS: Tuple[float, ...] = tuple(float(1 << s) for s in range(10, 35, 2))


class Counter:
    """Monotonic accumulator."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> float:
        with self._lock:
            return self._value

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0


class Gauge:
    """Last-write value, plus the high-water mark since reset."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._value = 0.0
        self._max = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)
            if v > self._max:
                self._max = float(v)

    @property
    def value(self) -> float:
        return self._value

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            return {"value": self._value, "max": self._max}

    def reset(self) -> None:
        with self._lock:
            self._value = 0.0
            self._max = 0.0


class Histogram:
    """Fixed-bucket histogram with an exact-percentile reservoir.

    ``bounds`` are the buckets' inclusive upper edges; an implicit
    +inf bucket catches the overflow. The raw-sample reservoir (capped
    at ``max_samples``) keeps percentiles exact for bounded runs; once
    it overflows, ``percentile()`` degrades to linear interpolation
    inside the matched bucket and ``snapshot()["exact"]`` flips False.
    """

    def __init__(
        self,
        name: str,
        bounds: Sequence[float] = TIME_BUCKETS_S,
        max_samples: int = 4096,
    ):
        if not bounds or list(bounds) != sorted(bounds):
            raise ValueError(f"histogram {name!r}: bounds must be sorted, non-empty")
        self.name = name
        self.bounds: Tuple[float, ...] = tuple(float(b) for b in bounds)
        self.max_samples = max_samples
        self._lock = threading.Lock()
        self._counts = [0] * (len(self.bounds) + 1)
        self._sum = 0.0
        self._count = 0
        self._min: Optional[float] = None
        self._max: Optional[float] = None
        self._samples: List[float] = []
        self._overflowed = False

    def record(self, v: float) -> None:
        v = float(v)
        with self._lock:
            # le semantics: first bound >= v gets the observation, so a
            # value sitting exactly on a boundary lands in the bucket it
            # bounds (bisect_left, not bisect_right).
            self._counts[bisect.bisect_left(self.bounds, v)] += 1
            self._sum += v
            self._count += 1
            if self._min is None or v < self._min:
                self._min = v
            if self._max is None or v > self._max:
                self._max = v
            if len(self._samples) < self.max_samples:
                self._samples.append(v)
            else:
                self._overflowed = True

    @property
    def count(self) -> int:
        return self._count

    def percentile(self, q: float) -> Optional[float]:
        """q in [0, 100]. Exact (numpy 'linear' method) while the
        reservoir holds every observation; bucket-interpolated after."""
        with self._lock:
            if self._count == 0:
                return None
            if not self._overflowed:
                xs = sorted(self._samples)
                rank = (q / 100.0) * (len(xs) - 1)
                lo = int(math.floor(rank))
                hi = min(lo + 1, len(xs) - 1)
                frac = rank - lo
                return xs[lo] + (xs[hi] - xs[lo]) * frac
            return self._bucket_percentile(q)

    def _bucket_percentile(self, q: float) -> float:
        target = (q / 100.0) * self._count
        cum = 0
        for i, c in enumerate(self._counts):
            if cum + c >= target and c > 0:
                lo = self.bounds[i - 1] if i > 0 else (self._min or 0.0)
                hi = self.bounds[i] if i < len(self.bounds) else (self._max or lo)
                frac = (target - cum) / c
                return lo + (hi - lo) * frac
            cum += c
        return self._max or 0.0

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counts = list(self._counts)
            count, total = self._count, self._sum
            lo, hi = self._min, self._max
            exact = not self._overflowed
        out: Dict[str, Any] = {
            "count": count,
            "sum": total,
            "mean": (total / count) if count else None,
            "min": lo,
            "max": hi,
            "buckets": [
                {"le": b, "count": c} for b, c in zip(self.bounds, counts)
            ]
            + [{"le": "inf", "count": counts[-1]}],
            "exact": exact,
        }
        for q in (50, 90, 99):
            out[f"p{q}"] = self.percentile(q)
        return out

    def reset(self) -> None:
        with self._lock:
            self._counts = [0] * (len(self.bounds) + 1)
            self._sum = 0.0
            self._count = 0
            self._min = self._max = None
            self._samples = []
            self._overflowed = False


class Metrics:
    """Named-instrument registry. Engines own a private instance for
    per-engine series; module-level code shares :func:`get_metrics`."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter(name)
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge(name)
            return g

    def histogram(
        self,
        name: str,
        bounds: Sequence[float] = TIME_BUCKETS_S,
        max_samples: int = 4096,
    ) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram(name, bounds, max_samples)
            return h

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {k: c.snapshot() for k, c in counters.items()},
            "gauges": {k: g.snapshot() for k, g in gauges.items()},
            "histograms": {k: h.snapshot() for k, h in histograms.items()},
        }

    def reset(self) -> None:
        with self._lock:
            instruments = (
                list(self._counters.values())
                + list(self._gauges.values())
                + list(self._histograms.values())
            )
        for inst in instruments:
            inst.reset()


_GLOBAL = Metrics()


def get_metrics() -> Metrics:
    """The process-global registry (scheduler / autotune series)."""
    return _GLOBAL


def reset_metrics() -> None:
    _GLOBAL.reset()

"""Unified observability layer: tag-addressed spans + process metrics.

Stark's evaluation is a wall-clock argument — the paper decomposes
execution into the recursion tree's divide / multiply / combine phases
to show where the 7-multiplication scheme wins. This package is the
repro's single substrate for that decomposition:

* :mod:`repro.obs.tracer` — nestable spans with a thread-local context
  stack. Block-scheduler spans are addressed by the paper's base-7 /
  base-4 **tag** (``tags.to_string``), so an exported trace literally
  renders the recursion tree: level-order divide spans, 7^q leaf-wave
  stage / dispatch / fetch spans, and the async-pipeline overlap as
  concurrent tracks.
* :mod:`repro.obs.metrics` — process-local counters, gauges, and
  fixed-bucket histograms (TTFT / TPOT per request, wave stage / fetch
  seconds, autotune hit / miss, pool pages in use) with a
  ``snapshot()`` dict API.
* :mod:`repro.obs.export` — Chrome / Perfetto ``trace_event`` JSON and
  JSONL event-log writers, plus optional ``jax.profiler`` passthrough
  so spans line up with XLA traces on real hardware.

Tracing is **disabled by default**: ``get_tracer().span(...)`` returns
a shared no-op context manager (zero allocation) until
``obs.configure(enabled=True)`` — launchers flip it on behind their
``--trace-out`` flags.
"""
from __future__ import annotations

from repro.obs.metrics import (  # noqa: F401
    Counter,
    Gauge,
    Histogram,
    Metrics,
    get_metrics,
    reset_metrics,
)
from repro.obs.tracer import (  # noqa: F401
    Span,
    Tracer,
    configure,
    get_tracer,
    reset_tracing,
)

__all__ = [
    "Span",
    "Tracer",
    "configure",
    "get_tracer",
    "reset_tracing",
    "Counter",
    "Gauge",
    "Histogram",
    "Metrics",
    "get_metrics",
    "reset_metrics",
]

"""Nestable, tag-addressed spans with a thread-local context stack.

Design constraints, in order:

1. **Disabled mode is free.** ``Tracer.span()`` on a disabled tracer
   returns one shared no-op context manager — no ``Span`` object, no
   dict, no perf_counter call. Hot paths (the jitted matmul entry, the
   decode loop) can be instrumented unconditionally.
2. **The tag is the span identity.** Block-scheduler spans carry the
   paper's base-7 / base-4 tag (``tags.to_string``) in ``Span.tag``;
   the exporter renders it into the event name so a trace of an
   out-of-core run reads as the recursion tree itself.
3. **Explicit-time spans.** Subsystems that already own precise
   timestamps (the async wave pipeline, the request lifecycle) record
   completed spans via :meth:`Tracer.add_span` instead of wrapping
   code in context managers — overlap between waves then shows up as
   genuinely concurrent tracks, not nested blocks.

Timestamps are raw ``time.perf_counter()`` seconds; the exporter
rebases them against :attr:`Tracer.epoch`. ``begin()``/``end()``
always produce a timed :class:`Span` (callers may need the duration
even when tracing is off — e.g. the straggler watchdog); the span is
only *retained* when the tracer is enabled.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Dict, List, Optional

__all__ = [
    "Span",
    "Tracer",
    "get_tracer",
    "configure",
    "reset_tracing",
]


@dataclasses.dataclass
class Span:
    """One timed region. ``t0``/``t1`` are perf_counter seconds."""

    name: str
    t0: float
    t1: Optional[float] = None
    cat: str = "span"
    tag: Optional[str] = None
    track: Optional[str] = None  # exporter lane (tid); None = per-thread lane
    attrs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    span_id: int = 0
    parent_id: Optional[int] = None
    thread: int = 0

    @property
    def duration(self) -> float:
        """Seconds; 0.0 while the span is still open."""
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def set(self, **attrs: Any) -> "Span":
        self.attrs.update(attrs)
        return self


class _NullSpan:
    """Shared no-op stand-in when tracing is disabled.

    One module-level instance serves every ``span()`` call on a
    disabled tracer: ``with tracer.span(...)`` allocates nothing.
    """

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc: Any) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self

    @property
    def duration(self) -> float:
        return 0.0


NULL_SPAN = _NullSpan()


class _SpanContext:
    """Context manager wrapping begin/end on an enabled tracer."""

    __slots__ = ("_tracer", "_span", "_jax_ctx")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span
        self._jax_ctx = None

    def __enter__(self) -> Span:
        if self._tracer.jax_annotations:
            self._jax_ctx = _jax_annotation(self._span.name)
            if self._jax_ctx is not None:
                self._jax_ctx.__enter__()
        return self._span

    def __exit__(self, *exc: Any) -> bool:
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(*exc)
        self._tracer.end(self._span)
        return False


def _jax_annotation(name: str):
    """Best-effort ``jax.profiler.TraceAnnotation`` (None off-profiler)."""
    try:
        from jax.profiler import TraceAnnotation

        return TraceAnnotation(name)
    except Exception:
        return None


class Tracer:
    """Span recorder with per-thread nesting and a bounded span list."""

    def __init__(
        self,
        enabled: bool = False,
        max_spans: int = 200_000,
        jax_annotations: bool = False,
    ):
        self.enabled = enabled
        self.max_spans = max_spans
        self.jax_annotations = jax_annotations
        self.epoch = time.perf_counter()
        self.spans: List[Span] = []
        self.dropped = 0
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._tls = threading.local()

    # -- nesting ----------------------------------------------------------
    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def current(self) -> Optional[Span]:
        """Innermost open span on this thread (None at top level)."""
        st = self._stack()
        return st[-1] if st else None

    # -- recording --------------------------------------------------------
    def begin(
        self,
        name: str,
        *,
        cat: str = "span",
        tag: Optional[str] = None,
        track: Optional[str] = None,
        **attrs: Any,
    ) -> Span:
        """Open a span. Always returns a timed Span (duration is valid
        even when disabled); it is only retained when enabled."""
        sp = Span(
            name=name,
            t0=time.perf_counter(),
            cat=cat,
            tag=tag,
            track=track,
            attrs=dict(attrs),
            thread=threading.get_ident(),
        )
        if self.enabled:
            sp.span_id = next(self._ids)
            st = self._stack()
            if st:
                sp.parent_id = st[-1].span_id
            st.append(sp)
        return sp

    def end(self, span: Optional[Span], **attrs: Any) -> Optional[Span]:
        """Close ``span``. Tolerates exception unwinding: pops the
        thread stack down through ``span`` if children were left open."""
        if span is None or isinstance(span, _NullSpan):
            return None
        span.t1 = time.perf_counter()
        if attrs:
            span.attrs.update(attrs)
        if self.enabled and span.span_id:
            st = self._stack()
            while st:
                top = st.pop()
                if top is span:
                    break
            self._retain(span)
        return span

    def span(
        self,
        name: str,
        *,
        cat: str = "span",
        tag: Optional[str] = None,
        track: Optional[str] = None,
        **attrs: Any,
    ):
        """``with tracer.span("name"): ...`` — no-op singleton when
        disabled (the zero-allocation fast path)."""
        if not self.enabled:
            return NULL_SPAN
        return _SpanContext(
            self, self.begin(name, cat=cat, tag=tag, track=track, **attrs)
        )

    def add_span(
        self,
        name: str,
        t0: float,
        t1: float,
        *,
        cat: str = "span",
        tag: Optional[str] = None,
        track: Optional[str] = None,
        parent: Optional[Span] = None,
        **attrs: Any,
    ) -> Optional[Span]:
        """Record a completed span from caller-owned perf_counter
        timestamps (async pipeline phases, request lifecycles)."""
        if not self.enabled:
            return None
        sp = Span(
            name=name,
            t0=t0,
            t1=t1,
            cat=cat,
            tag=tag,
            track=track,
            attrs=dict(attrs),
            span_id=next(self._ids),
            parent_id=parent.span_id if parent is not None else None,
            thread=threading.get_ident(),
        )
        self._retain(sp)
        return sp

    def event(self, name: str, *, cat: str = "instant",
              tag: Optional[str] = None, track: Optional[str] = None,
              **attrs: Any) -> Optional[Span]:
        """Instant event (zero-duration span, cat='instant' by default)."""
        if not self.enabled:
            return None
        now = time.perf_counter()
        return self.add_span(
            name, now, now, cat=cat, tag=tag, track=track,
            parent=self.current(), **attrs,
        )

    def _retain(self, span: Span) -> None:
        with self._lock:
            if len(self.spans) >= self.max_spans:
                self.dropped += 1
            else:
                self.spans.append(span)

    # -- inspection -------------------------------------------------------
    def snapshot(self) -> List[Span]:
        with self._lock:
            return list(self.spans)

    def find(self, name: Optional[str] = None, *, cat: Optional[str] = None,
             tag: Optional[str] = None) -> List[Span]:
        """Completed spans filtered by name/cat/tag (tests, derivations)."""
        out = []
        for sp in self.snapshot():
            if name is not None and sp.name != name:
                continue
            if cat is not None and sp.cat != cat:
                continue
            if tag is not None and sp.tag != tag:
                continue
            out.append(sp)
        return out

    def clear(self) -> None:
        with self._lock:
            self.spans.clear()
            self.dropped = 0
        self.epoch = time.perf_counter()


_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-global tracer (disabled until :func:`configure`)."""
    return _GLOBAL


def configure(
    enabled: Optional[bool] = None,
    *,
    jax_annotations: Optional[bool] = None,
    max_spans: Optional[int] = None,
) -> Tracer:
    """Reconfigure the global tracer in place (identity is stable so
    modules may cache ``get_tracer()`` safely)."""
    if enabled is not None:
        _GLOBAL.enabled = enabled
    if jax_annotations is not None:
        _GLOBAL.jax_annotations = jax_annotations
    if max_spans is not None:
        _GLOBAL.max_spans = max_spans
    return _GLOBAL


def reset_tracing() -> None:
    """Drop recorded spans and rebase the epoch (test isolation)."""
    _GLOBAL.clear()

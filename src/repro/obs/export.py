"""Trace exporters: Chrome/Perfetto ``trace_event`` JSON and JSONL.

The Chrome writer emits complete (``ph: "X"``) events with
microsecond ``ts``/``dur`` rebased to the tracer epoch. Lanes: spans
with an explicit ``track`` share a synthetic tid per track name (this
is how the async wave pipeline's stage / compute / fetch phases render
as concurrent tracks); untracked spans get a lane per OS thread.
``thread_name`` metadata events label every lane, and span tags (the
paper's base-7 / base-4 addresses) are folded into the event name so
Perfetto's flame view reads as the recursion tree.

``validate_trace`` is the schema checker the tests and the CI
bench-smoke job share; ``python -m repro.obs.export trace.json ...``
runs it from the command line (non-zero exit on the first bad file).
"""
from __future__ import annotations

import json
from typing import Any, Dict, List, Optional, Union

from repro.obs.metrics import Metrics
from repro.obs.tracer import Tracer, get_tracer

__all__ = [
    "trace_events",
    "to_chrome_trace",
    "write_trace",
    "write_jsonl",
    "validate_trace",
    "start_jax_trace",
    "stop_jax_trace",
]

PID = 1  # single-process repro: one constant Chrome pid


def _lanes(tracer: Tracer) -> Dict[Any, int]:
    """Stable lane (tid) assignment: named tracks first, then threads."""
    lanes: Dict[Any, int] = {}
    for sp in tracer.snapshot():
        key = sp.track if sp.track is not None else ("thread", sp.thread)
        if key not in lanes:
            lanes[key] = len(lanes) + 1
    return lanes


def trace_events(tracer: Optional[Tracer] = None) -> List[Dict[str, Any]]:
    """Tracer spans as a Chrome ``traceEvents`` list."""
    tracer = tracer or get_tracer()
    lanes = _lanes(tracer)
    events: List[Dict[str, Any]] = []
    for key, tid in lanes.items():
        label = key if isinstance(key, str) else f"thread-{key[1]}"
        events.append(
            {
                "ph": "M",
                "name": "thread_name",
                "pid": PID,
                "tid": tid,
                "ts": 0,
                "args": {"name": label},
            }
        )
    for sp in tracer.snapshot():
        if sp.t1 is None:
            continue
        key = sp.track if sp.track is not None else ("thread", sp.thread)
        args: Dict[str, Any] = dict(sp.attrs)
        if sp.tag is not None:
            args["tag"] = sp.tag
        ev = {
            "name": f"{sp.name} [{sp.tag}]" if sp.tag is not None else sp.name,
            "cat": sp.cat,
            "ph": "X",
            "ts": max(0.0, (sp.t0 - tracer.epoch) * 1e6),
            "dur": max(0.0, (sp.t1 - sp.t0) * 1e6),
            "pid": PID,
            "tid": lanes[key],
            "args": args,
        }
        events.append(ev)
    return events


def to_chrome_trace(
    tracer: Optional[Tracer] = None, metrics: Optional[Metrics] = None
) -> Dict[str, Any]:
    """Full Chrome/Perfetto JSON object; metrics ride in ``otherData``."""
    tracer = tracer or get_tracer()
    doc: Dict[str, Any] = {
        "traceEvents": trace_events(tracer),
        "displayTimeUnit": "ms",
    }
    other: Dict[str, Any] = {"dropped_spans": tracer.dropped}
    if metrics is not None:
        other["metrics"] = metrics.snapshot()
    doc["otherData"] = other
    return doc


def write_trace(
    path: str,
    tracer: Optional[Tracer] = None,
    metrics: Optional[Metrics] = None,
) -> str:
    """Write the Chrome/Perfetto JSON trace to ``path``."""
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer, metrics), f)
    return path


def write_jsonl(path: str, tracer: Optional[Tracer] = None) -> str:
    """One JSON object per span (append-friendly event log)."""
    tracer = tracer or get_tracer()
    with open(path, "w") as f:
        for sp in tracer.snapshot():
            if sp.t1 is None:
                continue
            f.write(
                json.dumps(
                    {
                        "name": sp.name,
                        "cat": sp.cat,
                        "tag": sp.tag,
                        "track": sp.track,
                        "t0": sp.t0 - tracer.epoch,
                        "dur": sp.t1 - sp.t0,
                        "span_id": sp.span_id,
                        "parent_id": sp.parent_id,
                        "attrs": sp.attrs,
                    }
                )
                + "\n"
            )
    return path


def validate_trace(source: Union[str, Dict[str, Any]]) -> List[str]:
    """Perfetto-loadability check; returns a list of problems (empty =
    valid). ``source`` is a path or an already-loaded trace object."""
    errors: List[str] = []
    if isinstance(source, str):
        try:
            with open(source) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            return [f"unreadable trace: {e}"]
    else:
        doc = source
    events = doc.get("traceEvents") if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        return ["no traceEvents array"]
    if not events:
        errors.append("empty traceEvents")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errors.append(f"event {i}: not an object")
            continue
        for field in ("ph", "name", "pid", "tid"):
            if field not in ev:
                errors.append(f"event {i} ({ev.get('name', '?')}): missing {field!r}")
        ph = ev.get("ph")
        if ph not in ("X", "B", "E", "M", "i", "I", "C", "b", "e"):
            errors.append(f"event {i}: unknown ph {ph!r}")
        if ph != "M" and "ts" not in ev:
            errors.append(f"event {i} ({ev.get('name', '?')}): missing 'ts'")
        if ph == "X":
            if "dur" not in ev:
                errors.append(f"event {i} ({ev.get('name', '?')}): X without 'dur'")
            elif not (
                isinstance(ev["dur"], (int, float)) and ev["dur"] >= 0
            ):
                errors.append(f"event {i}: bad dur {ev['dur']!r}")
        ts = ev.get("ts")
        if ts is not None and not isinstance(ts, (int, float)):
            errors.append(f"event {i}: non-numeric ts {ts!r}")
    return errors


# -- jax.profiler passthrough ---------------------------------------------


def start_jax_trace(logdir: str) -> bool:
    """Start an XLA-level ``jax.profiler`` trace alongside obs spans
    (so device kernels line up with host spans on real hardware).
    Best-effort: returns False when the profiler is unavailable."""
    try:
        import jax

        jax.profiler.start_trace(logdir)
        return True
    except Exception:
        return False


def stop_jax_trace() -> bool:
    try:
        import jax

        jax.profiler.stop_trace()
        return True
    except Exception:
        return False


def main(argv: Optional[List[str]] = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Validate Chrome/Perfetto trace JSON files"
    )
    ap.add_argument("paths", nargs="+", help="trace JSON files to check")
    args = ap.parse_args(argv)
    rc = 0
    for path in args.paths:
        errs = validate_trace(path)
        if errs:
            rc = 1
            print(f"{path}: INVALID")
            for e in errs[:20]:
                print(f"  - {e}")
        else:
            with open(path) as f:
                n = len(json.load(f).get("traceEvents", []))
            print(f"{path}: ok ({n} events)")
    return rc


if __name__ == "__main__":
    raise SystemExit(main())

"""AdamW + cosine schedule + global-norm clipping, pure JAX.

Optimizer state (m, v) inherits each parameter's sharding (ZeRO-style:
the launcher assigns FSDP-sharded specs to both params and moments, so
optimizer memory scales down with the data axis).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "OptState", "init_opt_state", "apply_updates", "cosine_schedule"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array  # scalar int32
    m: Any  # pytree like params
    v: Any


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def cosine_schedule(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    progress = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * progress))
    scale = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree))
    )


def apply_updates(
    params, grads, state: OptState, cfg: AdamWConfig
) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    """One AdamW step with clipping; returns (params, state, metrics)."""
    step = state.step + 1
    lr = cosine_schedule(step, cfg)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) if cfg.clip_norm else 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m_new = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v_new = cfg.b2 * v + (1.0 - cfg.b2) * g32 * g32
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:  # decay matrices, not norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    # unzip the 3-tuples
    params_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return params_new, OptState(step=step, m=m_new, v=v_new), metrics

"""Pure-jnp oracle for the matmul kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32), precision="highest"
    ).astype(out_dtype)


def batched_matmul_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    out_dtype = out_dtype or a.dtype
    return jnp.einsum(
        "mij,mjk->mik",
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        precision="highest",
    ).astype(out_dtype)

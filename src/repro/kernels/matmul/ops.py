"""Jitted public wrappers for the matmul kernels."""
from __future__ import annotations

import functools

import jax

from repro.kernels.matmul.matmul import batched_matmul_pallas, matmul_pallas

__all__ = ["matmul", "batched_matmul"]


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def matmul(a, b, *, block_m=256, block_n=256, block_k=256, interpret=None):
    return matmul_pallas(
        a, b, block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "interpret")
)
def batched_matmul(a, b, *, block_m=256, block_n=256, block_k=256, interpret=None):
    return batched_matmul_pallas(
        a, b, block_m=block_m, block_n=block_n, block_k=block_k, interpret=interpret
    )

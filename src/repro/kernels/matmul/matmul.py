"""Tiled MXU matmul Pallas kernel — Stark's leaf block multiply, TPU-native.

In the paper, leaf blocks are multiplied on a single node via Breeze -> JNI
-> BLAS. On TPU the analogue is an MXU-tiled kernel: blocks of A and B are
staged HBM -> VMEM per BlockSpec, multiplied on the 128x128 systolic array
with fp32 accumulation in a VMEM scratch, and written back once per (i, j)
tile after the K reduction completes.

Grid layout: (M/bm, N/bn, K/bk) with K innermost so the accumulator lives
across the contraction; the batched variant prepends the leaf index m —
the paper's M-index tag — as the outermost, embarrassingly parallel axis.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret, pick_block

__all__ = ["matmul_pallas", "batched_matmul_pallas"]


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    """One (i, j, k) grid step: acc += A[i,k] @ B[k,j]; flush at last k."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """C = A @ B with (bm, bn, bk) VMEM tiles and fp32 accumulation.

    Default 256^3 tiles: working set = (bm*bk + bk*bn)*2B (bf16 operands)
    + bm*bn*4B (fp32 acc) = 512 KiB — comfortably inside the ~16 MiB VMEM
    budget, with arithmetic intensity bk/2 = 128 FLOP/byte, well past the
    197e12/819e9 = 241 FLOP/byte... per-tile reuse is what the K-innermost
    ordering buys (each A tile read once per j).
    """
    if interpret is None:
        interpret = default_interpret()
    (m, k), (k2, n) = a.shape, b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = pick_block(m, block_m), pick_block(n, block_n), pick_block(k, block_k)
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)


def _batched_matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def batched_matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    out_dtype: Optional[jnp.dtype] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stark's leaf stage: (m, i, j) x (m, j, k) -> (m, i, k).

    The leading axis m = 7^depth is the flattened recursion-tag batch; it is
    the outermost grid axis, so on-device it is a serial loop with zero
    cross-iteration traffic while under pjit/shard_map it is the axis the
    mesh shards (each chip sees only its m-slice).
    """
    if interpret is None:
        interpret = default_interpret()
    (mb, m, k), (_, k2, n) = a.shape, b.shape
    assert k == k2 and b.shape[0] == mb, (a.shape, b.shape)
    bm, bn, bk = pick_block(m, block_m), pick_block(n, block_n), pick_block(k, block_k)
    out_dtype = out_dtype or a.dtype
    return pl.pallas_call(
        _batched_matmul_kernel,
        grid=(mb, m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((1, bm, bk), lambda s, i, j, kk: (s, i, kk)),
            pl.BlockSpec((1, bk, bn), lambda s, i, j, kk: (s, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, bm, bn), lambda s, i, j, kk: (s, i, j)),
        out_shape=jax.ShapeDtypeStruct((mb, m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)

"""Jitted public wrapper for the RMSNorm kernel (any leading dims)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_pallas

__all__ = ["rmsnorm"]


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, w, *, eps=1e-6, block_rows=256, interpret=None):
    *lead, d = x.shape
    rows = 1
    for s in lead:
        rows *= s
    out = rmsnorm_pallas(
        x.reshape(rows, d), w, eps=eps, block_rows=block_rows, interpret=interpret
    )
    return out.reshape(*lead, d)

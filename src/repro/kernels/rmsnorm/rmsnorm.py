"""Fused RMSNorm Pallas kernel.

Memory-bound op: one HBM read of x, one write — the unfused XLA form can
rematerialize x twice (square+mean, then scale). Rows are tiled (br, D)
into VMEM; the reduction and rescale stay in VREGs, fp32 math.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import default_interpret, pick_block

__all__ = ["rmsnorm_pallas"]


def _rmsnorm_kernel(eps: float, x_ref, w_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (br, D)
    w = w_ref[...].astype(jnp.float32)  # (1, D)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """y = x * rsqrt(mean(x^2) + eps) * w over the last dim.

    Args:
      x: (R, D) rows to normalize (callers flatten leading dims).
      w: (D,) scale.
    """
    if interpret is None:
        interpret = default_interpret()
    r, d = x.shape
    assert w.shape == (d,), (x.shape, w.shape)
    br = pick_block(r, block_rows, align=8)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps),
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w[None, :])

"""Shared Pallas kernel utilities.

This container is CPU-only: TPU is the compilation TARGET, not the runtime.
Every kernel accepts ``interpret=`` and defaults to interpret mode when no
TPU is present, so the same call sites run (slowly, but bit-faithfully at
the algorithm level) on CPU and compile to Mosaic on a real TPU.
"""
from __future__ import annotations

import functools

import jax

__all__ = ["default_interpret", "pick_block", "cdiv"]


@functools.lru_cache(None)
def default_interpret() -> bool:
    """True when the default backend has no TPU (interpret the kernel)."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def pick_block(dim: int, preferred: int, align: int = 128) -> int:
    """Largest block <= preferred that divides dim, preferring MXU alignment.

    TPU MXU wants the trailing two tile dims in multiples of (8, 128) for
    fp32 and (16, 128) for bf16; ``preferred`` should already be a multiple
    of 128. For small test shapes we fall back to the dim itself.
    """
    if dim <= preferred:
        return dim
    b = preferred
    while b >= align:
        if dim % b == 0:
            return b
        b -= align
    # No aligned divisor — fall back to any divisor (interpret-mode tests).
    b = preferred
    while b > 1:
        if dim % b == 0:
            return b
        b -= 1
    return 1

"""Pure-jnp oracle for flash attention (materializes the score matrix)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def attention_ref(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Naive masked attention with GQA kv-head broadcast; fp32 math."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = hq // hkv
    scale = scale if scale is not None else d**-0.5
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        precision="highest",
    ) * scale
    rows = jnp.arange(sq)[:, None]
    cols = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= rows >= cols
    if window is not None:
        mask &= rows - cols < window
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32), precision="highest")
    return out.astype(q.dtype)

"""Flash (online-softmax) attention Pallas kernel — TPU target.

Not part of the Stark paper, but required substrate: the prefill_32k and
long-context shape cells are only lowerable if attention never
materializes the (Sq, Sk) score matrix. This kernel tiles Q into (bq, D)
VMEM blocks and streams K/V in (bk, D) blocks with the standard
running-max/running-denominator update; the accumulator never leaves VMEM.

Supports MHA/GQA/MQA (kv-head broadcast via the BlockSpec index map — no
materialized repeat), causal masking, and a sliding local window (for
recurrentgemma-style local attention).

Grid: (B, Hq, Sq/bq, Sk/bk), Sk innermost so the softmax state lives in
scratch across the KV sweep.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret, pick_block

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(
    causal: bool,
    window: Optional[int],
    scale: float,
    block_q: int,
    block_k: int,
    q_ref,
    k_ref,
    v_ref,
    o_ref,
    acc_ref,
    m_ref,
    l_ref,
):
    iq, ik = pl.program_id(2), pl.program_id(3)
    q_off = iq * block_q
    k_off = ik * block_k

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _step():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        rows = q_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = k_off + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, rows >= cols)
        if window is not None:
            mask = jnp.logical_and(mask, rows - cols < window)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)  # (bq, 1)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    # Block-level skip: fully-masked KV blocks do no work (the Pallas
    # analogue of flash attention's causal block pruning). A block is live
    # iff [k_off, k_off+bk) intersects union_rows (row-window, row] —
    # i.e. k_off <= q_off+bq-1 (causal) and k_off+bk-1 > q_off-window.
    if causal or window is not None:
        live = jnp.bool_(True)
        if causal:
            live = jnp.logical_and(live, k_off <= q_off + block_q - 1)
        if window is not None:
            live = jnp.logical_and(live, k_off + block_k - 1 > q_off - window)
        pl.when(live)(_step)
    else:
        _step()

    @pl.when(ik == pl.num_programs(3) - 1)
    def _flush():
        l = l_ref[...]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """O = softmax(QK^T * scale + mask) V, never materializing (Sq, Sk).

    Args:
      q: (B, Hq, Sq, D). k, v: (B, Hkv, Sk, D) with Hq % Hkv == 0.
      causal: apply causal mask (rows >= cols), offset so the LAST query
        aligns with the last key (standard decode/prefill convention when
        Sq == Sk; for Sq != Sk pass explicit full seqs).
      window: optional sliding window size (keys within [row-window+1, row]).
    """
    if window is not None and not causal:
        raise ValueError("sliding window requires causal=True (backward window)")
    if interpret is None:
        interpret = default_interpret()
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    scale = scale if scale is not None else d**-0.5
    bq = pick_block(sq, block_q)
    bk = pick_block(sk, block_k)

    kernel = functools.partial(_flash_kernel, causal, window, scale, bq, bk)
    return pl.pallas_call(
        kernel,
        grid=(b, hq, sq // bq, sk // bk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda bb, h, iq, ik: (bb, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda bb, h, iq, ik: (bb, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)

"""Jitted public wrapper for flash attention."""
from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas

__all__ = ["flash_attention"]


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q,
    k,
    v,
    *,
    causal=True,
    window=None,
    scale=None,
    block_q=512,
    block_k=512,
    interpret=None,
):
    return flash_attention_pallas(
        q,
        k,
        v,
        causal=causal,
        window=window,
        scale=scale,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )

"""Fused Strassen Pallas kernels — the beyond-paper TPU adaptation.

Stark materializes every divide/combine level through a Spark shuffle:
quadrants are replicated (4 copies of A11, 2 of A12, ...) and written to
disk between stages. On TPU the same linear maps are memory-bound
elementwise ops, so we fuse them:

* :func:`divide_pallas` / :func:`combine_pallas` — one level's 18 block
  additions in a single HBM pass (read 4 quadrant tiles, write 7 operand
  tiles, or read 7 product tiles, write 4 C tiles). No replication is ever
  materialized — the coefficient matrix is folded into the kernel as
  compile-time +/-1 constants.

* :func:`strassen1_matmul_pallas` — a full "DFS step in-kernel" (CAPS
  vocabulary): the LAST recursion level's divide, 7 leaf products, and
  combine all happen per-tile in VMEM. A and B quadrant tiles are read
  once from HBM; the 7 operand combinations, 7 MXU matmuls into 7 fp32
  accumulators, and the 4-quadrant combine never touch HBM. This removes
  the (7/4)^1 intermediate blowup of the last level — the dominant HBM
  term — and is the kernel :func:`repro.core.backend.matmul` uses for
  kind='strassen_fused'.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.coefficients import Scheme, STRASSEN, get_scheme
from repro.kernels.common import default_interpret, pick_block

__all__ = [
    "divide_pallas",
    "combine_pallas",
    "strassen1_matmul_pallas",
]


def _signed_sum(refs_slice, coefs) -> jax.Array:
    """Sum_q coefs[q] * refs_slice[q] with compile-time-skipped zeros."""
    acc = None
    for q, c in enumerate(coefs):
        c = float(c)
        if c == 0.0:
            continue
        term = refs_slice[q]
        if c == -1.0:
            term = -term
        elif c != 1.0:
            term = c * term
        acc = term if acc is None else acc + term
    assert acc is not None
    return acc


def _divide_kernel(coef: np.ndarray, x_ref, o_ref):
    """(1, 4, bh, bw) quadrant tile -> (1, r, bh, bw) operand tile."""
    quads = [x_ref[0, q] for q in range(4)]
    for p in range(coef.shape[0]):
        o_ref[0, p] = _signed_sum(quads, coef[p]).astype(o_ref.dtype)


def divide_pallas(
    x: jax.Array,
    coef: np.ndarray,
    *,
    block: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One divide level on quadrant layout: (m, 4, h, w) -> (m, r, h, w).

    Equivalent to ``einsum('pq,mqij->mpij', coef, x)`` but with the adds
    fused into one read of x — Stark's flatMapToPair+groupByKey+flatMap
    divide stage as a single HBM pass.
    """
    if interpret is None:
        interpret = default_interpret()
    m, four, h, w = x.shape
    assert four == 4, x.shape
    r = coef.shape[0]
    bh, bw = pick_block(h, block), pick_block(w, block)
    return pl.pallas_call(
        functools.partial(_divide_kernel, np.asarray(coef)),
        grid=(m, h // bh, w // bw),
        in_specs=[pl.BlockSpec((1, 4, bh, bw), lambda s, i, j: (s, 0, i, j))],
        out_specs=pl.BlockSpec((1, r, bh, bw), lambda s, i, j: (s, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((m, r, h, w), x.dtype),
        interpret=interpret,
    )(x)


def _combine_kernel(c_coef: np.ndarray, p_ref, o_ref):
    """(1, r, bh, bw) product tile -> (1, 4, bh, bw) C-quadrant tile."""
    r = c_coef.shape[1]
    prods = [p_ref[0, p] for p in range(r)]
    for k in range(4):
        o_ref[0, k] = _signed_sum(prods, c_coef[k]).astype(o_ref.dtype)


def combine_pallas(
    products: jax.Array,
    c_coef: np.ndarray,
    *,
    block: int = 256,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """One combine level on quadrant layout: (m, r, h, w) -> (m, 4, h, w)."""
    if interpret is None:
        interpret = default_interpret()
    m, r, h, w = products.shape
    assert r == c_coef.shape[1], (products.shape, c_coef.shape)
    bh, bw = pick_block(h, block), pick_block(w, block)
    return pl.pallas_call(
        functools.partial(_combine_kernel, np.asarray(c_coef)),
        grid=(m, h // bh, w // bw),
        in_specs=[pl.BlockSpec((1, r, bh, bw), lambda s, i, j: (s, 0, i, j))],
        out_specs=pl.BlockSpec((1, 4, bh, bw), lambda s, i, j: (s, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((m, 4, h, w), products.dtype),
        interpret=interpret,
    )(products)


def _strassen1_kernel(scheme: Scheme, aq_ref, bq_ref, o_ref, acc_ref):
    """One (s, i, j, k) grid step of the fused one-level Strassen matmul.

    VMEM residency per step: 4 A-quadrant tiles, 4 B-quadrant tiles, the
    r=7 fp32 accumulators, and (at the last k) the 4 output tiles. Operand
    combos exist only in VREGs.
    """
    r = scheme.n_mults

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a_quads = [aq_ref[0, q] for q in range(4)]
    b_quads = [bq_ref[0, q] for q in range(4)]
    for p in range(r):
        left = _signed_sum(a_quads, scheme.a_coef[p])
        right = _signed_sum(b_quads, scheme.b_coef[p])
        acc_ref[p] += jnp.dot(left, right, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _flush():
        prods = [acc_ref[p] for p in range(r)]
        for k in range(4):
            o_ref[0, k] = _signed_sum(prods, scheme.c_coef[k]).astype(o_ref.dtype)


def strassen1_matmul_pallas(
    aq: jax.Array,
    bq: jax.Array,
    *,
    scheme: Scheme | str = STRASSEN,
    block_m: int = 256,
    block_n: int = 256,
    block_k: int = 256,
    out_dtype=None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Fused one-level Strassen on quadrant layout.

    Args:
      aq: (mb, 4, M2, K2) A-quadrants (batched over mb leaves).
      bq: (mb, 4, K2, N2) B-quadrants.

    Returns:
      (mb, 4, M2, N2) C-quadrants.
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    if interpret is None:
        interpret = default_interpret()
    mb, four, m2, k2 = aq.shape
    _, _, _, n2 = bq.shape
    assert four == 4 and bq.shape[:2] == (mb, 4) and bq.shape[2] == k2
    bm, bn, bk = pick_block(m2, block_m), pick_block(n2, block_n), pick_block(k2, block_k)
    out_dtype = out_dtype or aq.dtype
    return pl.pallas_call(
        functools.partial(_strassen1_kernel, scheme),
        grid=(mb, m2 // bm, n2 // bn, k2 // bk),
        in_specs=[
            pl.BlockSpec((1, 4, bm, bk), lambda s, i, j, kk: (s, 0, i, kk)),
            pl.BlockSpec((1, 4, bk, bn), lambda s, i, j, kk: (s, 0, kk, j)),
        ],
        out_specs=pl.BlockSpec((1, 4, bm, bn), lambda s, i, j, kk: (s, 0, i, j)),
        out_shape=jax.ShapeDtypeStruct((mb, 4, m2, n2), out_dtype),
        scratch_shapes=[pltpu.VMEM((scheme.n_mults, bm, bn), jnp.float32)],
        interpret=interpret,
    )(aq, bq)

"""Jitted public wrappers composing the fused Strassen kernels.

Three pipelines, in increasing distance from the paper:

* :func:`strassen_matmul_stages` — paper-faithful staging (every divide /
  combine level materialized, like Stark's shuffles) but with each stage's
  adds fused by the divide/combine kernels and leaves on the MXU kernel.
* :func:`strassen_matmul_fused`  — the beyond-paper pipeline: unrolled
  einsum levels down to the last, which runs entirely in-kernel
  (divide + 7 products + combine per tile). Used by backend 'strassen_fused'.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.coefficients import get_scheme
from repro.core.strassen import (
    combine_level,
    divide_level,
    merge_quadrants,
    split_quadrants,
)
from repro.kernels.matmul.matmul import batched_matmul_pallas
from repro.kernels.strassen.strassen import (
    combine_pallas,
    divide_pallas,
    strassen1_matmul_pallas,
)

__all__ = [
    "strassen_matmul_stages",
    "strassen_matmul_fused",
    "strassen_matmul_fused_padded",
]


@functools.partial(jax.jit, static_argnames=("depth", "scheme_name", "interpret"))
def strassen_matmul_stages(
    a: jax.Array,
    b: jax.Array,
    *,
    depth: int = 1,
    scheme_name: str = "strassen",
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Stage-by-stage Stark pipeline with per-stage Pallas kernels."""
    scheme = get_scheme(scheme_name)
    ta, tb = a[None], b[None]
    for _ in range(depth):
        ta = divide_pallas(split_quadrants(ta), scheme.a_coef, interpret=interpret)
        ta = ta.reshape(-1, *ta.shape[2:])
        tb = divide_pallas(split_quadrants(tb), scheme.b_coef, interpret=interpret)
        tb = tb.reshape(-1, *tb.shape[2:])
    prod = batched_matmul_pallas(ta, tb, interpret=interpret)
    for _ in range(depth):
        grouped = prod.reshape(-1, scheme.n_mults, *prod.shape[1:])
        quads = combine_pallas(grouped, scheme.c_coef, interpret=interpret)
        prod = merge_quadrants(quads)
    return prod[0]


@functools.partial(jax.jit, static_argnames=("depth", "scheme_name", "interpret", "precision"))
def strassen_matmul_fused(
    a: jax.Array,
    b: jax.Array,
    *,
    depth: int = 1,
    scheme_name: str = "strassen",
    interpret: Optional[bool] = None,
    precision: Optional[str] = None,
) -> jax.Array:
    """Fused pipeline: last level runs fully in-kernel (DFS step in VMEM).

    depth-1 outer levels are unrolled einsums (BFS levels, shardable) run
    at the caller's ``precision``; the final level never materializes its
    7/4x intermediates and always accumulates in fp32 on the MXU (the
    kernel's preferred_element_type), which is the strongest precision the
    leaf offers.
    """
    if depth < 1:
        raise ValueError("fused pipeline needs depth >= 1")
    scheme = get_scheme(scheme_name)
    a_coef = jnp.asarray(scheme.a_coef)
    b_coef = jnp.asarray(scheme.b_coef)
    c_coef = jnp.asarray(scheme.c_coef)

    ta, tb = a[None], b[None]
    for _ in range(depth - 1):
        ta = divide_level(ta, a_coef, precision=precision)
        tb = divide_level(tb, b_coef, precision=precision)
    cq = strassen1_matmul_pallas(
        split_quadrants(ta), split_quadrants(tb), scheme=scheme, interpret=interpret
    )
    prod = merge_quadrants(cq)
    for _ in range(depth - 1):
        prod = combine_level(prod, c_coef, precision=precision)
    return prod[0]


@functools.partial(
    jax.jit, static_argnames=("depth", "scheme_name", "interpret", "precision")
)
def strassen_matmul_fused_padded(
    a: jax.Array,
    b: jax.Array,
    *,
    depth: int = 1,
    scheme_name: str = "strassen",
    interpret: Optional[bool] = None,
    precision: Optional[str] = None,
) -> jax.Array:
    """Fused pipeline for arbitrary (M, K) @ (K, N), odd dims included.

    Zero-pads each dim up to the next multiple of 2**depth, runs
    :func:`strassen_matmul_fused`, and slices back. Padding rows/columns
    contribute exactly zero to every M-term (the scheme is bilinear), so
    the unpadded block of C is exact — the same argument Stark uses for
    its non-power-of-two Block layout.
    """
    m, k = a.shape
    n = b.shape[1]
    step = 2**depth
    mp, kp, np_ = (-(-d // step) * step for d in (m, k, n))
    if (mp, kp, np_) == (m, k, n):
        return strassen_matmul_fused(
            a, b, depth=depth, scheme_name=scheme_name,
            interpret=interpret, precision=precision,
        )
    a_p = jnp.pad(a, ((0, mp - m), (0, kp - k)))
    b_p = jnp.pad(b, ((0, kp - k), (0, np_ - n)))
    out = strassen_matmul_fused(
        a_p, b_p, depth=depth, scheme_name=scheme_name,
        interpret=interpret, precision=precision,
    )
    return out[:m, :n]

"""Pure-jnp oracles for the fused Strassen kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.coefficients import Scheme, STRASSEN, get_scheme


def divide_ref(x: jax.Array, coef: np.ndarray) -> jax.Array:
    """(m, 4, h, w) -> (m, r, h, w) via plain einsum."""
    return jnp.einsum("pq,mqij->mpij", jnp.asarray(coef, x.dtype), x)


def combine_ref(products: jax.Array, c_coef: np.ndarray) -> jax.Array:
    """(m, r, h, w) -> (m, 4, h, w) via plain einsum."""
    return jnp.einsum("kp,mpij->mkij", jnp.asarray(c_coef, products.dtype), products)


def strassen1_matmul_ref(
    aq: jax.Array, bq: jax.Array, scheme: Scheme | str = STRASSEN, out_dtype=None
) -> jax.Array:
    """(mb,4,M2,K2) x (mb,4,K2,N2) -> (mb,4,M2,N2), unfused fp32 pipeline."""
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    out_dtype = out_dtype or aq.dtype
    a32, b32 = aq.astype(jnp.float32), bq.astype(jnp.float32)
    left = divide_ref(a32, scheme.a_coef)
    right = divide_ref(b32, scheme.b_coef)
    prods = jnp.einsum("mpij,mpjk->mpik", left, right, precision="highest")
    return combine_ref(prods, scheme.c_coef).astype(out_dtype)


def strassen1_full_ref(a: jax.Array, b: jax.Array, out_dtype=None) -> jax.Array:
    """Direct (M,K)@(K,N) oracle for the whole fused op (single leaf)."""
    out_dtype = out_dtype or a.dtype
    return jnp.matmul(
        a.astype(jnp.float32), b.astype(jnp.float32), precision="highest"
    ).astype(out_dtype)

"""Pure-jnp oracle for the fused sLSTM kernel: the models/xlstm scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def slstm_seq_ref(wx: jax.Array, r: jax.Array, state: dict):
    """wx (B,S,4,H,dh); r (4,H,dh,dh); state {c,n,m,h} (B,H,dh) fp32."""
    from repro.models.xlstm import _slstm_step

    wx32 = wx.astype(jnp.float32)
    new_state, hs = jax.lax.scan(
        lambda c, w_t: _slstm_step(r.astype(jnp.float32), c, w_t),
        dict(state),
        jnp.moveaxis(wx32, 1, 0),
    )
    return new_state, jnp.moveaxis(hs, 0, 1)  # (B, S, H, dh)

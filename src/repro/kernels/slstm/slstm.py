"""Fused sLSTM sequence kernel — the hillclimb-identified "next lever".

EXPERIMENTS.md §Perf cell 1: after the chunkwise mLSTM fix, xlstm
train_4k's residual memory term is the sLSTM layers' sequential scan —
~200k tiny XLA steps, each round-tripping the (B, H, dh) state quadruple
through HBM. TPU Pallas grid iterations execute SEQUENTIALLY on a core,
and scratch persists across them: this kernel walks the time axis as the
grid, keeps (c, n, m, h) in VMEM scratch for the whole sequence, and
touches HBM only for the per-step input preactivations and the h output
— state HBM traffic drops from O(S) round trips to zero.

Layout: wx (B, S, 4, H, dh) input preactivations (z/i/f/o order),
r (4, H, dh, dh) per-head recurrent mixing, state (B, H, dh) x4.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import default_interpret

__all__ = ["slstm_seq_pallas"]


def _slstm_kernel(
    wx_ref, r_ref, c0_ref, n0_ref, m0_ref, h0_ref,
    hs_ref, cf_ref, nf_ref, mf_ref, hf_ref,
    c_s, n_s, m_s, h_s,
):
    t = pl.program_id(0)

    @pl.when(t == 0)
    def _init():
        c_s[...] = c0_ref[...].astype(jnp.float32)
        n_s[...] = n0_ref[...].astype(jnp.float32)
        m_s[...] = m0_ref[...].astype(jnp.float32)
        h_s[...] = h0_ref[...].astype(jnp.float32)

    wx = wx_ref[:, 0].astype(jnp.float32)  # (B, 4, H, dh)
    r = r_ref[...].astype(jnp.float32)  # (4, H, dh, dh)
    h_prev = h_s[...]  # (B, H, dh)

    # recurrent mixing: (B,H,dh) x (4,H,dh,dh) -> (B,4,H,dh)
    rec = jax.lax.dot_general(
        h_prev, r,
        (((2,), (2,)), ((1,), (1,))),  # contract dh; batch over H
        preferred_element_type=jnp.float32,
    )  # (H, B, 4, dh)
    rec = jnp.transpose(rec, (1, 2, 0, 3))  # (B, 4, H, dh)
    pre = wx + rec

    z = jnp.tanh(pre[:, 0])
    i_pre = pre[:, 1]
    log_f = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(log_f + m_s[...], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m_s[...] - m_new)
    c_new = f_g * c_s[...] + i_g * z
    n_new = f_g * n_s[...] + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1.0)

    c_s[...] = c_new
    n_s[...] = n_new
    m_s[...] = m_new
    h_s[...] = h_new
    hs_ref[:, 0] = h_new.astype(hs_ref.dtype)

    @pl.when(t == pl.num_programs(0) - 1)
    def _flush():
        cf_ref[...] = c_new.astype(cf_ref.dtype)
        nf_ref[...] = n_new.astype(nf_ref.dtype)
        mf_ref[...] = m_new.astype(mf_ref.dtype)
        hf_ref[...] = h_new.astype(hf_ref.dtype)


def slstm_seq_pallas(
    wx: jax.Array,  # (B, S, 4, H, dh)
    r: jax.Array,  # (4, H, dh, dh)
    state: dict,  # {c, n, m, h}: (B, H, dh) fp32
    *,
    interpret: Optional[bool] = None,
) -> Tuple[dict, jax.Array]:
    """Run the full sLSTM sequence in one kernel; returns (state, hs)."""
    if interpret is None:
        interpret = default_interpret()
    b, s, four, h, dh = wx.shape
    assert four == 4, wx.shape
    state_shape = jax.ShapeDtypeStruct((b, h, dh), jnp.float32)
    out_shapes = (
        jax.ShapeDtypeStruct((b, s, h, dh), jnp.float32),  # hs
        state_shape, state_shape, state_shape, state_shape,
    )
    grid = (s,)
    full_state_spec = pl.BlockSpec((b, h, dh), lambda t: (0, 0, 0))
    hs, cf, nf, mf, hf = pl.pallas_call(
        _slstm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, 1, 4, h, dh), lambda t: (0, t, 0, 0, 0)),
            pl.BlockSpec((4, h, dh, dh), lambda t: (0, 0, 0, 0)),
            full_state_spec, full_state_spec, full_state_spec, full_state_spec,
        ],
        out_specs=(
            pl.BlockSpec((b, 1, h, dh), lambda t: (0, t, 0, 0)),
            full_state_spec, full_state_spec, full_state_spec, full_state_spec,
        ),
        out_shape=out_shapes,
        scratch_shapes=[pltpu.VMEM((b, h, dh), jnp.float32)] * 4,
        interpret=interpret,
    )(wx, r, state["c"], state["n"], state["m"], state["h"])
    return {"c": cf, "n": nf, "m": mf, "h": hf}, hs

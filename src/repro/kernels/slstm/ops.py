"""Jitted wrapper for the fused sLSTM sequence kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.slstm.slstm import slstm_seq_pallas

__all__ = ["slstm_seq"]


@functools.partial(jax.jit, static_argnames=("interpret",))
def slstm_seq(wx, r, state, *, interpret=None):
    return slstm_seq_pallas(wx, r, state, interpret=interpret)

"""Request lifecycle for the continuous-batching serving engine.

A request moves through::

    submit() -> QUEUED -> PREFILL -> DECODING -> FINISHED
                      \\-> REJECTED          \\-> EVICTED

EVICTED covers user eviction (``finish_reason='evicted'``), fault
isolation (``'error'`` — a decode/prefill fault attributed to this
request), and the per-request watchdog (``'timeout'``).

Tokens stream to the caller through an optional ``on_token`` callback
(fired at every engine sync with the newly arrived token ids, in
emission order) and through :meth:`RequestHandle.tokens` snapshots.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Callable, List, Optional, Tuple

import numpy as np

__all__ = ["RequestState", "Request", "RequestHandle", "TokenEvent"]


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILL = "prefill"
    DECODING = "decoding"
    FINISHED = "finished"
    EVICTED = "evicted"
    REJECTED = "rejected"

    @property
    def terminal(self) -> bool:
        return self in (
            RequestState.FINISHED,
            RequestState.EVICTED,
            RequestState.REJECTED,
        )


@dataclasses.dataclass(frozen=True)
class TokenEvent:
    """One streamed token: (request id, position in the output, token)."""

    request_id: int
    index: int
    token: int


@dataclasses.dataclass
class Request:
    """Engine-internal request record. Users hold a RequestHandle."""

    id: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int
    temperature: float
    eos_id: int
    seed: int
    on_token: Optional[Callable] = None

    state: RequestState = RequestState.QUEUED
    slot: Optional[int] = None
    page_ids: List[int] = dataclasses.field(default_factory=list)
    tokens: List[int] = dataclasses.field(default_factory=list)
    # "eos" | "length" | "evicted" | "error" (fault isolation) |
    # "timeout" (request_timeout_s watchdog) | "rejected"
    finish_reason: Optional[str] = None

    # telemetry (wall-clock, perf_counter domain)
    t_submit: float = 0.0
    t_admit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None
    token_times: List[float] = dataclasses.field(default_factory=list)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        return self.state.terminal

    def record_tokens(self, toks: List[int], now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        if self.t_first_token is None and toks:
            self.t_first_token = now
        self.tokens.extend(int(t) for t in toks)
        self.token_times.extend(now for _ in toks)


class RequestHandle:
    """User-facing view of a submitted request."""

    def __init__(self, engine, request: Request):
        self._engine = engine
        self._request = request

    @property
    def id(self) -> int:
        return self._request.id

    @property
    def state(self) -> RequestState:
        return self._request.state

    @property
    def finish_reason(self) -> Optional[str]:
        return self._request.finish_reason

    @property
    def done(self) -> bool:
        return self._request.done

    def tokens(self) -> List[int]:
        """Snapshot of tokens streamed so far (prompt excluded)."""
        return list(self._request.tokens)

    def result(self) -> List[int]:
        """Drive the engine until this request is terminal; return tokens."""
        self._engine.run(until=self)
        return self.tokens()

    def cancel(self) -> None:
        """Evict this request (mid-decode allowed); pages return to pool."""
        self._engine.evict(self)

    def latency_stats(self) -> Tuple[Optional[float], List[float]]:
        """(time-to-first-token, inter-token gaps) in seconds."""
        r = self._request
        ttft = (
            r.t_first_token - r.t_submit if r.t_first_token is not None else None
        )
        gaps = [
            r.token_times[i] - r.token_times[i - 1]
            for i in range(1, len(r.token_times))
        ]
        return ttft, gaps

    def __repr__(self) -> str:
        r = self._request
        return (
            f"RequestHandle(id={r.id}, state={r.state.value}, "
            f"tokens={len(r.tokens)}/{r.max_new_tokens})"
        )

"""Batched serving engine: continuous prefill + decode over a KV cache.

The engine jits one prefill step and one decode step per (batch, seq)
bucket and runs greedy/temperature sampling. Caches are the model's
family-appropriate state (dense KV, ring-buffer local KV, or recurrent
state — O(1) for the SSM/hybrid archs, which is what makes long_500k
serveable at all).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import autotune
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.frontends import make_stub_positions

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_seq: int = 2048
    temperature: float = 0.0  # 0 -> greedy
    eos_id: int = -1  # -1 -> never stop early
    # Persistent autotune cache for kind='auto' backends: the engine loads
    # it at startup and pre-resolves the common dense-projection shapes so
    # typical prefill/decode traces dispatch from the cache; shapes outside
    # the warmed (batch, tokens) grid still resolve lazily at trace time.
    tuning_cache: Optional[str] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig = ServeConfig()):
        # Telemetry is process-scoped (resolutions fire at jit-trace time),
        # so each engine zeroes it up front: autotune_stats()/generate()
        # then report this engine's resolutions, not a previous instance's
        # — two engines used to interleave counters and decision records.
        # The out-of-core run ring is process-global for the same reason
        # and gets the same treatment, keeping autotune_stats()["oot"]
        # scoped to runs since this engine was built.
        autotune.reset_telemetry()
        from repro.blocks.scheduler import reset_oot_stats

        reset_oot_stats()
        # Apply process-level backend knobs (XLA latency-hiding flags)
        # once per run, here rather than per call site.
        cfg.matmul_backend.configure()
        if cfg.matmul_backend.kind == "auto":
            if serve_cfg.tuning_cache and not cfg.matmul_backend.tuning_cache:
                cfg = dataclasses.replace(
                    cfg,
                    matmul_backend=dataclasses.replace(
                        cfg.matmul_backend, tuning_cache=serve_cfg.tuning_cache
                    ),
                )
            # decode resolves at 1 token/seq; prefill at up to max_seq tokens
            autotune.warm_for_model(
                cfg, tokens=(1, min(128, serve_cfg.max_seq), serve_cfg.max_seq)
            )
        self.cfg = cfg
        self.params = params
        self.serve = serve_cfg

        self._prefill = jax.jit(
            functools.partial(self._prefill_impl, cfg=cfg)
        )
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg=cfg))

    # --- jitted bodies (static cfg via closure/partial)
    @staticmethod
    def _prefill_impl(params, batch, cache, *, cfg):
        return M.apply_prefill(params, batch, cache, cfg)

    @staticmethod
    def _decode_impl(params, tokens, cache, positions, key, temperature, *, cfg):
        kwargs = {"positions": positions} if cfg.mrope else {}
        logits, cache = M.apply_decode(params, tokens, cache, cfg, **kwargs)

        def sample_greedy():
            return jnp.argmax(logits, axis=-1)

        def sample_temp():
            return jax.random.categorical(key, logits / jnp.maximum(temperature, 1e-6))

        nxt = jax.lax.cond(temperature > 0.0, sample_temp, sample_greedy)
        return nxt[:, None], cache

    def generate(
        self,
        prompts: jax.Array,  # (B, S_prompt) int32
        max_new_tokens: int,
        *,
        frames: Optional[jax.Array] = None,
        seed: int = 0,
    ) -> Tuple[jax.Array, Dict[str, float]]:
        """Greedy/temperature generation for a batch of equal-length prompts."""
        cfg, serve = self.cfg, self.serve
        b, s = prompts.shape
        total = s + max_new_tokens
        assert total <= serve.max_seq, (total, serve.max_seq)
        cache = M.init_cache(cfg, b, serve.max_seq)

        batch = {"tokens": prompts}
        if frames is not None:
            batch["frames"] = frames
        if cfg.mrope:
            batch["positions"] = make_stub_positions(b, s)
        logits, cache = self._prefill(self.params, batch, cache)

        key = jax.random.PRNGKey(seed)
        if serve.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / serve.temperature)[:, None]
        else:
            nxt = jnp.argmax(logits, axis=-1)[:, None]

        out: List[jax.Array] = [nxt]
        done = jnp.zeros((b,), bool)
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            positions = (
                make_stub_positions(b, 1, offset=s + i + 1) if cfg.mrope else None
            )
            nxt, cache = self._decode(
                self.params, nxt, cache, positions, sub,
                jnp.float32(serve.temperature),
            )
            if serve.eos_id >= 0:
                done = done | (nxt[:, 0] == serve.eos_id)
                if bool(jnp.all(done)):
                    out.append(nxt)
                    break
            out.append(nxt)
        tokens = jnp.concatenate(out, axis=1)
        stats = {
            "prompt_len": float(s),
            "generated": float(tokens.shape[1]),
            "cache_pos": float(cache["pos"]),
        }
        # Autotune decision telemetry: how many matmul resolutions this
        # process served from the cache vs decided fresh. Full per-decision
        # records (site, kind, predicted-vs-measured) via autotune_stats().
        tel = autotune.get_telemetry()
        stats["autotune_cache_hits"] = float(tel.cache_hits)
        stats["autotune_cache_misses"] = float(tel.cache_misses)
        return tokens, stats

    def autotune_stats(self) -> Dict:
        """Full autotune telemetry snapshot plus the calibration it ran on.

        Each fresh decision carries its per-constant cost split under
        ``terms`` (t_flop/t_elem/t_coll seconds, and t_h2d for the
        out-of-core ``strassen_oot`` family); ``calibration`` reports the
        fitted constants themselves (None when every decision came from a
        warm cache and no calibration ever ran). ``oot`` carries the
        out-of-core scheduler's recent run stats (waves, peak device
        bytes, overlap telemetry) for any ``strassen_oot`` resolutions
        this process executed since the engine was built.
        """
        from repro.blocks.scheduler import recent_oot_stats

        return {
            **autotune.get_telemetry().snapshot(),
            "calibration": autotune.calibration_snapshot(),
            "oot": recent_oot_stats(),
        }

"""Continuous-batching serving engine: request-based API over a paged KV pool.

Redesigned around a request lifecycle instead of one blocking call::

    engine = Engine(cfg, params, ServeConfig(slots=8, page_size=16))
    h = engine.submit([1, 2, 3], max_new_tokens=64, on_token=cb)
    for ev in engine.stream():          # or: engine.step() by hand
        ...                             # TokenEvent(request_id, index, token)
    h.tokens()

* ``submit()`` queues a request (admission control: reject or queue when
  the page budget / slots are exhausted); the scheduler admits and
  evicts requests *mid-decode*, so the jitted decode step always runs a
  full ``slots``-wide bucket with per-slot position/eos state.
* KV memory is a paged pool (``kv_pool.py``): full-attention layers
  share a page-budgeted arena through per-slot page tables, so
  heterogeneous sequence lengths share the device budget instead of
  each padding to ``max_seq``. Ring/recurrent state stays slot-indexed.
* End-of-sequence is checked **on device** inside the step (the old
  loop's per-token ``bool(jnp.all(done))`` host sync is gone); the host
  fetches tokens/finish state every ``sync_interval`` steps.
* ``generate()`` remains as a thin compatibility shim on top of the new
  loop (token-exact for the old greedy call shape); encoder-decoder
  configs (whisper) fall back to the retained legacy static-batch path
  ``_generate_static``, which is also the parity anchor in tests.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.blocks.recovery import FaultError, InjectedFault
from repro.core import autotune
from repro.models import model as M
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs_tracer
from repro.models.config import ModelConfig
from repro.models.frontends import make_stub_positions
from repro.serving.kv_pool import CacheLayout, PagePool
from repro.serving.request import Request, RequestHandle, RequestState, TokenEvent

__all__ = ["ServeConfig", "Engine"]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The single serving-surface config: sampling, memory, scheduling.

    ``apply_to(cfg)`` is the one place serving knobs rewrite the model
    config (tuning-cache warm start for ``kind='auto'`` backends).
    """

    max_seq: int = 2048
    temperature: float = 0.0  # 0 -> greedy
    eos_id: int = -1  # -1 -> never stop early
    # Persistent autotune cache for kind='auto' backends: the engine loads
    # it at startup and pre-resolves the common dense-projection shapes so
    # typical prefill/decode traces dispatch from the cache; shapes outside
    # the warmed (batch, tokens) grid still resolve lazily at trace time.
    tuning_cache: Optional[str] = None

    # --- continuous-batching surface
    slots: int = 4  # decode bucket width (requests resident at once)
    page_size: int = 16  # tokens per KV page
    page_budget: int = 0  # usable KV pages; 0 = slots * ceil(max_seq/page_size)
    admission: str = "queue"  # "queue" (wait for slots/pages) | "reject"
    max_queue: int = 0  # queue-policy cap; 0 = unbounded
    batching: str = "continuous"  # "continuous" | "static" (gang baseline)
    sync_interval: int = 4  # decode steps between host<->device token syncs
    decode_pages: int = 0  # gathered pages per step; 0 = pow2 bucketing
    # Per-request watchdog: a request still decoding this many seconds
    # after admission is evicted with finish_reason="timeout" and its
    # pages returned to the pool. 0 disables the watchdog.
    request_timeout_s: float = 0.0

    def __post_init__(self):
        if self.admission not in ("queue", "reject"):
            raise ValueError(f"admission must be queue|reject, got {self.admission!r}")
        if self.batching not in ("continuous", "static"):
            raise ValueError(
                f"batching must be continuous|static, got {self.batching!r}"
            )
        for name in ("max_seq", "slots", "page_size", "sync_interval"):
            if getattr(self, name) < 1:
                raise ValueError(f"{name} must be >= 1, got {getattr(self, name)}")
        if self.page_budget < 0 or self.decode_pages < 0 or self.max_queue < 0:
            raise ValueError("page_budget/decode_pages/max_queue must be >= 0")
        if self.request_timeout_s < 0:
            raise ValueError(
                f"request_timeout_s must be >= 0, got {self.request_timeout_s}"
            )

    @property
    def table_width(self) -> int:
        """Pages needed to cover max_seq — the per-slot page-table width."""
        return -(-self.max_seq // self.page_size)

    @property
    def pages_total(self) -> int:
        """Usable pages in the pool (scratch page excluded)."""
        return self.page_budget or self.slots * self.table_width

    def apply_to(self, cfg: ModelConfig) -> ModelConfig:
        """Resolve serving-surface knobs into the model config.

        Replaces the old ad-hoc ``dataclasses.replace`` splice in
        ``Engine.__init__``: any serving-layer rewrite of the model
        config happens here and nowhere else.
        """
        backend = cfg.matmul_backend
        if backend.kind == "auto" and self.tuning_cache and not backend.tuning_cache:
            cfg = dataclasses.replace(
                cfg,
                matmul_backend=dataclasses.replace(
                    backend, tuning_cache=self.tuning_cache
                ),
            )
        return cfg


@dataclasses.dataclass
class _ServeStats:
    submitted: int = 0
    admitted: int = 0
    finished: int = 0
    evicted: int = 0
    errors: int = 0
    timeouts: int = 0
    rejected: int = 0
    prefills: int = 0
    decode_steps: int = 0
    syncs: int = 0
    tokens_emitted: int = 0
    peak_pages_in_use: int = 0
    peak_queue_depth: int = 0
    prefill_s: float = 0.0
    decode_dispatch_s: float = 0.0
    drain_s: float = 0.0
    buckets: Dict[int, int] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Buffered:
    """One dispatched step whose tokens the host has not fetched yet."""

    arr: jax.Array  # () prefill token or (slots,) decode tokens
    # (slot, request) pairs live at dispatch; prefill entries carry one.
    snapshot: Tuple[Tuple[int, Request], ...]
    prefill: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, serve_cfg: ServeConfig = ServeConfig()):
        # Telemetry is process-scoped (resolutions fire at jit-trace time),
        # so each engine zeroes it up front: autotune_stats()/generate()
        # then report this engine's resolutions, not a previous instance's
        # — two engines used to interleave counters and decision records.
        autotune.reset_telemetry()
        # Out-of-core run stats, by contrast, are consumed through an
        # engine-OWNED ring: every run since this engine was built lands
        # here regardless of how many other engines run concurrently —
        # resetting the process-global ring (the previous fix) still
        # clobbered a concurrently-running second engine's view.
        from repro.blocks.scheduler import attach_stats_ring

        self._oot_ring = attach_stats_ring()
        # Per-engine obs registry: request-latency histograms (TTFT /
        # TPOT), pool-page gauges, token counters. Engine-scoped for the
        # same isolation reason as the ring; surfaced by stats()["obs"].
        self.metrics = obs_metrics.Metrics()
        # Apply process-level backend knobs (XLA latency-hiding flags)
        # once per run, here rather than per call site.
        cfg.matmul_backend.configure()
        cfg = serve_cfg.apply_to(cfg)
        if cfg.matmul_backend.kind == "auto":
            # decode resolves at 1 token/seq; prefill at up to max_seq tokens
            autotune.warm_for_model(
                cfg, tokens=(1, min(128, serve_cfg.max_seq), serve_cfg.max_seq)
            )
        self.cfg = cfg
        self.params = params
        self.serve = serve_cfg

        self._prefill = jax.jit(functools.partial(self._prefill_impl, cfg=cfg))
        # Legacy lockstep decode, kept for _generate_static (encdec
        # fallback + the pre-redesign parity anchor).
        self._decode = jax.jit(functools.partial(self._decode_impl, cfg=cfg))

        # --- request-scheduler state (device state built lazily: encdec
        # configs never touch it and fall back to the static path).
        self._layout: Optional[CacheLayout] = None
        self._pool: Optional[PagePool] = None
        self._kv = None
        self._table = None
        self._meta = None
        self._decode_step = None
        self._insert = None
        self._next_id = 0
        self._queue: deque = deque()
        self._active: Dict[int, Request] = {}
        self._free_slots: List[int] = []
        self._requests: Dict[int, Request] = {}
        self._buffer: List[_Buffered] = []
        self._steps_since_sync = 0
        self._stats = _ServeStats()

    # ------------------------------------------------------ jitted bodies

    @staticmethod
    def _prefill_impl(params, batch, cache, *, cfg):
        return M.apply_prefill(params, batch, cache, cfg)

    @staticmethod
    def _decode_impl(params, tokens, cache, positions, key, temperature, *, cfg):
        kwargs = {"positions": positions} if cfg.mrope else {}
        logits, cache = M.apply_decode(params, tokens, cache, cfg, **kwargs)

        def sample_greedy():
            return jnp.argmax(logits, axis=-1)

        def sample_temp():
            return jax.random.categorical(key, logits / jnp.maximum(temperature, 1e-6))

        nxt = jax.lax.cond(temperature > 0.0, sample_temp, sample_greedy)
        return nxt[:, None], cache

    @staticmethod
    def _decode_step_impl(params, kv, table, meta, active, *, cfg, layout, bucket_pages):
        """One continuous-batching decode step over the full slot bucket.

        Per-slot positions, per-slot sampling params, on-device eos: a
        slot is live iff the host marked it active AND the device hasn't
        flagged it done. Dead slots are frozen (state, pos, pages all
        unchanged; their KV write lands on the scratch page).
        """
        live = active & ~meta["done"]
        pos = meta["pos"]
        dense = layout.gather(kv, table, pos, bucket_pages)
        tokens = meta["last_tok"][:, None]
        if cfg.mrope:
            # Stub M-RoPE streams at pos+1: matches the pre-redesign
            # static loop's offset (generate parity is token-exact).
            b = pos.shape[0]
            p3 = jnp.broadcast_to((pos + 1)[:, None, None], (b, 1, 3)).astype(jnp.int32)
            logits, new_dense = M.apply_decode(params, tokens, dense, cfg, positions=p3)
        else:
            logits, new_dense = M.apply_decode(params, tokens, dense, cfg)

        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # Per-slot sampling streams: fold the request key with its token
        # index, so draws are independent of batch composition.
        keys = jax.vmap(jax.random.fold_in)(meta["key"], meta["n_gen"])
        temp = meta["temp"]
        sampled = jax.vmap(jax.random.categorical)(
            keys, logits / jnp.maximum(temp, 1e-6)[:, None]
        ).astype(jnp.int32)
        nxt = jnp.where(temp > 0, sampled, greedy)
        nxt = jnp.where(live, nxt, meta["last_tok"])

        kv = layout.scatter_token(kv, new_dense, table, pos, live)
        step = live.astype(jnp.int32)
        n_gen = meta["n_gen"] + step
        hit_eos = live & (meta["eos"] >= 0) & (nxt == meta["eos"])
        done = meta["done"] | hit_eos | (live & (n_gen >= meta["max_new"]))
        meta = {
            **meta,
            "last_tok": nxt,
            "pos": pos + step,
            "n_gen": n_gen,
            "done": done,
        }
        return kv, meta, nxt

    @staticmethod
    def _insert_impl(
        kv, table, meta, pre_cache, pre_logits, slot, page_row, page_ids, req, *, layout
    ):
        """Move a finished batch-1 prefill into slot ``slot``: pages
        scattered, slot state row-written, per-slot meta initialized,
        first token sampled from the prefill logits."""
        kv = layout.insert_request(kv, pre_cache, slot, page_ids)
        table = table.at[slot].set(page_row)
        logits = pre_logits[0]
        greedy = jnp.argmax(logits).astype(jnp.int32)
        k0 = jax.random.fold_in(req["key"], 0)
        sampled = jax.random.categorical(
            k0, logits / jnp.maximum(req["temp"], 1e-6)
        ).astype(jnp.int32)
        tok = jnp.where(req["temp"] > 0, sampled, greedy)
        done = ((req["eos"] >= 0) & (tok == req["eos"])) | (req["max_new"] <= 1)
        meta = {
            "last_tok": meta["last_tok"].at[slot].set(tok),
            "pos": meta["pos"].at[slot].set(pre_cache["pos"].astype(jnp.int32)),
            "n_gen": meta["n_gen"].at[slot].set(1),
            "done": meta["done"].at[slot].set(done),
            "eos": meta["eos"].at[slot].set(req["eos"]),
            "temp": meta["temp"].at[slot].set(req["temp"]),
            "max_new": meta["max_new"].at[slot].set(req["max_new"]),
            "key": meta["key"].at[slot].set(req["key"]),
        }
        return kv, table, meta, tok

    # ------------------------------------------------- serving state init

    def _ensure_serving(self) -> None:
        if self._layout is not None:
            return
        if self.cfg.is_encdec:
            raise NotImplementedError(
                "continuous batching covers decoder-only families; "
                "encoder-decoder configs serve through generate()'s "
                "legacy static path"
            )
        serve = self.serve
        layout = CacheLayout(
            cfg=self.cfg,
            n_slots=serve.slots,
            page_size=serve.page_size,
            max_seq=serve.max_seq,
        )
        self._layout = layout
        self._pool = PagePool(
            serve.pages_total if layout.has_paged else 0, serve.page_size
        )
        self._kv = layout.init_kv_state(self._pool.capacity)
        self._table = jnp.zeros((serve.slots, layout.table_width), jnp.int32)
        s = serve.slots
        self._meta = {
            "last_tok": jnp.zeros((s,), jnp.int32),
            "pos": jnp.zeros((s,), jnp.int32),
            "n_gen": jnp.zeros((s,), jnp.int32),
            "done": jnp.ones((s,), bool),  # empty slots are dead
            "eos": jnp.full((s,), -1, jnp.int32),
            "temp": jnp.zeros((s,), jnp.float32),
            "max_new": jnp.zeros((s,), jnp.int32),
            "key": jnp.zeros((s, 2), jnp.uint32),
        }
        self._free_slots = list(range(serve.slots))
        self._decode_step = jax.jit(
            functools.partial(
                Engine._decode_step_impl, cfg=self.cfg, layout=layout
            ),
            static_argnames=("bucket_pages",),
        )
        self._insert = jax.jit(
            functools.partial(Engine._insert_impl, layout=layout)
        )

    # ------------------------------------------------------- request API

    def submit(
        self,
        prompt,
        max_new_tokens: int,
        *,
        temperature: Optional[float] = None,
        eos_id: Optional[int] = None,
        seed: Optional[int] = None,
        on_token: Optional[Callable] = None,
        _key: Optional[np.ndarray] = None,
        _inject_fault_at: Optional[int] = None,
    ) -> RequestHandle:
        """Queue one request; returns immediately with a RequestHandle.

        Admission control: ``admission='queue'`` waits for slots/pages
        (bounded by ``max_queue``); ``'reject'`` marks the request
        REJECTED when it cannot start right now. Requests that can
        *never* fit (sequence beyond max_seq, pages beyond the pool
        capacity) raise ValueError.

        ``_inject_fault_at`` is the chaos-harness hook: the request's
        k-th decode dispatch raises :class:`InjectedFault` (k counts
        tokens already emitted, so ``1`` fails the first decode step
        after the prefill token; ``0`` fails the prefill itself). The
        engine's fault isolation evicts exactly that request with
        ``finish_reason='error'``; survivors are untouched.
        """
        self._ensure_serving()
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("prompt must hold at least one token")
        if max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        total = int(prompt.size) + max_new_tokens
        if total > self.serve.max_seq:
            raise ValueError(
                f"prompt+max_new_tokens={total} exceeds max_seq={self.serve.max_seq}"
            )
        need = self._pages_for_request(int(prompt.size), max_new_tokens)
        if need > self._pool.capacity:
            raise ValueError(
                f"request needs {need} pages, pool capacity is {self._pool.capacity}"
            )
        if _key is None:
            base = jax.random.PRNGKey(0 if seed is None else seed)
            key = base if seed is not None else jax.random.fold_in(base, self._next_id)
        else:
            key = jnp.asarray(_key, jnp.uint32)
        req = Request(
            id=self._next_id,
            prompt=prompt,
            max_new_tokens=int(max_new_tokens),
            temperature=(
                self.serve.temperature if temperature is None else float(temperature)
            ),
            eos_id=self.serve.eos_id if eos_id is None else int(eos_id),
            seed=0 if seed is None else int(seed),
            on_token=on_token,
            t_submit=time.perf_counter(),
        )
        req._key = np.asarray(key, np.uint32)  # type: ignore[attr-defined]
        req._emitted_est = 0  # type: ignore[attr-defined]
        req._fault_at = _inject_fault_at  # type: ignore[attr-defined]
        self._next_id += 1
        self._requests[req.id] = req
        self._stats.submitted += 1
        obs_tracer.get_tracer().event(
            "request.submit", tag=f"req{req.id}", track=f"serve.req/{req.id}",
            prompt_len=req.prompt_len, max_new=req.max_new_tokens,
        )
        handle = RequestHandle(self, req)

        if self.serve.admission == "reject":
            startable = bool(self._free_slots) and need <= self._pool.available
            if self.serve.batching == "static" and self._active:
                startable = False
            if not startable:
                req.state = RequestState.REJECTED
                req.finish_reason = "rejected"
                self._stats.rejected += 1
                return handle
        elif self.serve.max_queue and len(self._queue) >= self.serve.max_queue:
            req.state = RequestState.REJECTED
            req.finish_reason = "rejected"
            self._stats.rejected += 1
            return handle

        self._queue.append(req)
        self._stats.peak_queue_depth = max(
            self._stats.peak_queue_depth, len(self._queue)
        )
        self._try_admit()
        return handle

    def step(self) -> List[TokenEvent]:
        """One scheduler iteration: sync if due, admit, dispatch decode.

        Returns the TokenEvents drained this iteration (possibly empty —
        tokens surface at sync boundaries, not every step).
        """
        events: List[TokenEvent] = []
        self._check_timeouts()
        if self._drain_due():
            events.extend(self._drain())
        self._try_admit()
        dispatched = self._dispatch_decode()
        if not dispatched and self._buffer:
            # nothing computable until the host learns what finished
            events.extend(self._drain())
            self._try_admit()
            self._dispatch_decode()
        return events

    def stream(
        self, handles: Optional[Sequence[RequestHandle]] = None
    ) -> Iterator[TokenEvent]:
        """Drive the engine, yielding TokenEvents in emission order
        (step-major, slot-minor; per-request order is guaranteed).
        With ``handles``, stops once those requests are terminal."""
        wanted = None if handles is None else {h.id for h in handles}
        while True:
            if wanted is not None and all(
                self._requests[i].done for i in wanted
            ):
                return
            if not (self._queue or self._active or self._buffer):
                return
            for ev in self.step():
                if wanted is None or ev.request_id in wanted:
                    yield ev

    def run(self, until: Optional[RequestHandle] = None) -> None:
        """Step until all work (or ``until``'s request) is complete."""
        while self._queue or self._active or self._buffer:
            if until is not None and until.done:
                return
            self.step()

    def evict(self, handle: RequestHandle) -> None:
        """Evict a request mid-decode (or drop it from the queue): its
        pages return to the pool and its slot frees immediately;
        delivered tokens (including any buffered on device) are kept."""
        req = self._requests[handle.id]
        if req.done:
            return
        if req.state == RequestState.QUEUED:
            try:
                self._queue.remove(req)
            except ValueError:
                pass
            self._finish(req, "evicted")
            return
        # flush dispatched-but-unfetched tokens so delivery stays exact
        self._drain()
        if req.done:
            return
        self._finish(req, "evicted")

    # ------------------------------------------------------- scheduling

    def _pages_for_request(self, prompt_len: int, max_new: int) -> int:
        if not self._layout.has_paged:
            return 0
        # positions written: [0, prompt) by prefill, then one per decode
        # step up to prompt + max_new - 2 (the last sampled token is
        # never written back) — max_new - 1 decode writes.
        return self._pool.pages_for_tokens(prompt_len + max_new - 1)

    def _try_admit(self) -> None:
        if self._layout is None:
            return
        if self.serve.batching == "static" and self._active:
            return  # gang-scheduled baseline: admit only into an idle engine
        while self._queue and self._free_slots:
            req = self._queue[0]
            need = self._pages_for_request(req.prompt_len, req.max_new_tokens)
            if need > self._pool.available:
                break  # FIFO head-of-line wait for pages
            self._queue.popleft()
            self._admit(req, need)

    def _admit(self, req: Request, need: int) -> None:
        span = obs_tracer.get_tracer().begin(
            "engine.prefill", cat="serve", track="serve.engine",
            request=req.id, prompt_len=req.prompt_len, pages=need,
        )
        t0 = span.t0
        serve = self.serve
        req.state = RequestState.PREFILL
        req.t_admit = t0
        req.page_ids = self._pool.alloc(need)
        req.slot = self._free_slots.pop()
        self._stats.admitted += 1
        self._stats.prefills += 1
        self._stats.peak_pages_in_use = max(
            self._stats.peak_pages_in_use, self._pool.in_use
        )

        s = req.prompt_len
        ps = serve.page_size
        capacity = -(-s // ps) * ps
        pre_cache = self._layout.init_prefill_cache(capacity)
        batch = {"tokens": jnp.asarray(req.prompt[None, :])}
        if self.cfg.mrope:
            batch["positions"] = make_stub_positions(1, s)
        try:
            if getattr(req, "_fault_at", None) == 0:
                err = InjectedFault(f"injected prefill failure (request {req.id})")
                err.request_id = req.id  # type: ignore[attr-defined]
                raise err
            logits, filled = self._prefill(self.params, batch, pre_cache)
        except FaultError as e:
            # Prefill is batch-1, so the culprit is exact: release its
            # pages and slot, mark it errored, and keep serving. Device
            # slot state was never touched (the insert never ran).
            if isinstance(e, InjectedFault):
                self.metrics.counter("fault.injected_faults").inc()
            self.metrics.counter("fault.evicted_requests").inc()
            obs_tracer.get_tracer().end(span, error=type(e).__name__)
            obs_tracer.get_tracer().event(
                "fault.evict", cat="fault", tag=f"req{req.id}",
                track=f"serve.req/{req.id}", cause=type(e).__name__,
                phase="prefill",
            )
            self._finish(req, "error")
            return

        n_prompt_pages = capacity // ps
        page_row = np.zeros((self._layout.table_width,), np.int32)
        page_row[: len(req.page_ids)] = req.page_ids
        if self._layout.has_paged:
            prompt_pages = jnp.asarray(req.page_ids[:n_prompt_pages], jnp.int32)
        else:
            prompt_pages = jnp.zeros((0,), jnp.int32)
        req_meta = {
            "eos": jnp.int32(req.eos_id),
            "temp": jnp.float32(req.temperature),
            "max_new": jnp.int32(req.max_new_tokens),
            "key": jnp.asarray(req._key),  # type: ignore[attr-defined]
        }
        self._kv, self._table, self._meta, tok = self._insert(
            self._kv,
            self._table,
            self._meta,
            filled,
            logits,
            jnp.int32(req.slot),
            jnp.asarray(page_row),
            prompt_pages,
            req_meta,
        )
        req.state = RequestState.DECODING
        self._active[req.slot] = req
        # the prefill-sampled token is emission #1 for this request
        self._buffer.append(_Buffered(tok, ((req.slot, req),), prefill=True))
        req._emitted_est = 1  # type: ignore[attr-defined]
        obs_tracer.get_tracer().end(span)
        self._stats.prefill_s += span.duration
        # Decode phase starts here; _finish uses this to split the
        # request's lifecycle spans.
        req._t_decode = span.t1  # type: ignore[attr-defined]
        self.metrics.histogram("serve.prefill_s").record(span.duration)
        self.metrics.gauge("serve.pages_in_use").set(self._pool.in_use)

    def _host_live(self) -> List[Tuple[int, Request]]:
        return [
            (slot, req)
            for slot, req in sorted(self._active.items())
            if req._emitted_est < req.max_new_tokens  # type: ignore[attr-defined]
        ]

    def _bucket_pages(self) -> int:
        layout = self._layout
        if not layout.has_paged:
            return 1  # static placeholder; gather has no paged leaves
        if self.serve.decode_pages:
            return min(self.serve.decode_pages, layout.table_width)
        need = 1
        ps = self.serve.page_size
        for _, req in self._host_live():
            pos_est = req.prompt_len + req._emitted_est  # type: ignore[attr-defined]
            need = max(need, pos_est // ps + 1)
        bucket = 1
        while bucket < need:
            bucket *= 2
        return min(bucket, layout.table_width)

    def _dispatch_decode(self) -> bool:
        """Dispatch one decode step, isolating per-request faults.

        A fault-typed dispatch failure (injected or device-raised before
        the state assignment) evicts only the culprit request — the
        jitted step's results are assigned in one statement, so a raise
        leaves ``_kv``/``_meta`` untouched and every surviving slot
        continues bit-identically. Bounded retry: each attempt can evict
        at most one request, so ``slots + 1`` attempts suffice.
        """
        for _ in range(self.serve.slots + 1):
            live = self._host_live()
            if not live:
                return False
            try:
                return self._dispatch_decode_once(live)
            except FaultError as e:
                self._isolate_decode_fault(e, live)
        return False

    def _isolate_decode_fault(self, exc: FaultError, live) -> None:
        """Evict the request a failed decode dispatch is attributed to.

        Attribution: an :class:`InjectedFault` carries ``request_id``;
        anonymous fault-typed failures blame the newest-admitted live
        request (the one whose admission most recently changed the
        batch composition). Buffered tokens are drained first so every
        already-computed token is delivered before the eviction.
        """
        self._drain()
        rid = getattr(exc, "request_id", None)
        culprit = self._requests.get(rid) if rid is not None else None
        if culprit is None or culprit.done:
            cands = [r for r in self._active.values() if not r.done]
            if not cands:
                return  # the failure's request finished at the drain
            culprit = max(cands, key=lambda r: (r.t_admit or 0.0, r.id))
        if isinstance(exc, InjectedFault):
            self.metrics.counter("fault.injected_faults").inc()
        self.metrics.counter("fault.evicted_requests").inc()
        obs_tracer.get_tracer().event(
            "fault.evict", cat="fault", tag=f"req{culprit.id}",
            track=f"serve.req/{culprit.id}", cause=type(exc).__name__,
            phase="decode",
        )
        self._finish(culprit, "error")

    def _check_timeouts(self) -> None:
        """Per-request watchdog: evict admitted requests that have been
        decoding longer than ``request_timeout_s`` (pages freed, reason
        ``'timeout'``); survivors and delivered tokens are unaffected."""
        limit = self.serve.request_timeout_s
        if not limit or not self._active:
            return
        now = time.perf_counter()
        expired = [
            r
            for r in self._active.values()
            if (now - (r.t_admit if r.t_admit is not None else r.t_submit)) > limit
        ]
        if not expired:
            return
        self._drain()  # deliver everything computed before the cut
        for req in expired:
            if req.done:
                continue
            self.metrics.counter("fault.timeouts").inc()
            self.metrics.counter("fault.evicted_requests").inc()
            obs_tracer.get_tracer().event(
                "fault.evict", cat="fault", tag=f"req{req.id}",
                track=f"serve.req/{req.id}", cause="timeout",
            )
            self._finish(req, "timeout")

    def _dispatch_decode_once(self, live) -> bool:
        span = obs_tracer.get_tracer().begin(
            "engine.decode_step", cat="serve", track="serve.engine",
            live=len(live),
        )
        for _, req in live:
            fa = getattr(req, "_fault_at", None)
            if fa is not None and req._emitted_est >= fa:  # type: ignore[attr-defined]
                obs_tracer.get_tracer().end(span, error="InjectedFault")
                err = InjectedFault(
                    f"injected decode failure (request {req.id}, "
                    f"emitted {req._emitted_est})"  # type: ignore[attr-defined]
                )
                err.request_id = req.id  # type: ignore[attr-defined]
                raise err
        mask = np.zeros((self.serve.slots,), bool)
        for slot, _ in live:
            mask[slot] = True
        bucket = self._bucket_pages()
        self._kv, self._meta, emitted = self._decode_step(
            self.params,
            self._kv,
            self._table,
            self._meta,
            jnp.asarray(mask),
            bucket_pages=bucket,
        )
        self._buffer.append(_Buffered(emitted, tuple(live)))
        for _, req in live:
            req._emitted_est += 1  # type: ignore[attr-defined]
        self._steps_since_sync += 1
        self._stats.decode_steps += 1
        self._stats.buckets[bucket] = self._stats.buckets.get(bucket, 0) + 1
        obs_tracer.get_tracer().end(span, bucket_pages=bucket)
        self._stats.decode_dispatch_s += span.duration
        return True

    def _drain_due(self) -> bool:
        if not self._buffer:
            return False
        if self._steps_since_sync >= self.serve.sync_interval:
            return True
        # a request provably finished (length) -> sync to free its slot
        return any(
            req._emitted_est >= req.max_new_tokens  # type: ignore[attr-defined]
            for req in self._active.values()
        )

    def _drain(self) -> List[TokenEvent]:
        """Fetch buffered step outputs, distribute tokens to requests,
        fire streaming callbacks, and retire finished requests."""
        if not self._buffer:
            return []
        # The sync_interval host<->device boundary: the one place decode
        # tokens materialize on host, so its span IS the sync cadence.
        span = obs_tracer.get_tracer().begin(
            "engine.sync", cat="serve", track="serve.engine",
            buffered=len(self._buffer),
        )
        buffered, self._buffer = self._buffer, []
        arrays = jax.device_get([b.arr for b in buffered])
        now = time.perf_counter()
        events: List[TokenEvent] = []
        callbacks: List[Tuple[Request, TokenEvent]] = []
        for entry, arr in zip(buffered, arrays):
            for slot, req in entry.snapshot:
                if req.done:
                    continue  # frozen on device; later entries repeat last_tok
                tok = int(arr) if entry.prefill else int(arr[slot])
                ev = TokenEvent(req.id, len(req.tokens), tok)
                req.record_tokens([tok], now)
                self._stats.tokens_emitted += 1
                events.append(ev)
                if req.on_token is not None:
                    callbacks.append((req, ev))
                # mirror of the device's done rule (same order: the eos
                # token is delivered, then the request freezes)
                if req.eos_id >= 0 and tok == req.eos_id:
                    self._finish(req, "eos")
                elif len(req.tokens) >= req.max_new_tokens:
                    self._finish(req, "length")
        for req in self._active.values():
            req._emitted_est = len(req.tokens)  # type: ignore[attr-defined]
        self._steps_since_sync = 0
        self._stats.syncs += 1
        for req, ev in callbacks:
            req.on_token(RequestHandle(self, req), ev)
        obs_tracer.get_tracer().end(span, tokens=len(events))
        self._stats.drain_s += span.duration
        self.metrics.counter("serve.tokens_emitted").inc(len(events))
        return events

    def _finish(self, req: Request, reason: str) -> None:
        req.finish_reason = reason
        req.t_finish = time.perf_counter()
        if reason in ("evicted", "error", "timeout"):
            req.state = RequestState.EVICTED
            self._stats.evicted += 1
            if reason == "error":
                self._stats.errors += 1
            elif reason == "timeout":
                self._stats.timeouts += 1
        else:
            req.state = RequestState.FINISHED
            self._stats.finished += 1
        if req.page_ids:
            self._pool.free(req.page_ids)
            req.page_ids = []
        if req.slot is not None:
            self._active.pop(req.slot, None)
            self._free_slots.append(req.slot)
            req.slot = None
        self._record_request_obs(req)

    def _record_request_obs(self, req: Request) -> None:
        """Lifecycle spans (queued -> prefill -> decoding, one lane per
        request) + the TTFT/TPOT histograms. TTFT and the per-request
        mean inter-token gap are computed exactly as
        ``RequestHandle.latency_stats()`` consumers do, so histogram
        percentiles reconcile with the per-request records to float
        precision (the serve_load smoke gate)."""
        tr = obs_tracer.get_tracer()
        if tr.enabled:
            lane = f"serve.req/{req.id}"
            tag = f"req{req.id}"
            end = req.t_finish if req.t_finish is not None else req.t_submit
            if req.t_admit is not None:
                tr.add_span(
                    "request.queued", req.t_submit, req.t_admit,
                    cat="serve", tag=tag, track=lane,
                )
                t_decode = getattr(req, "_t_decode", req.t_admit)
                tr.add_span(
                    "request.prefill", req.t_admit, t_decode,
                    cat="serve", tag=tag, track=lane,
                )
                tr.add_span(
                    "request.decoding", t_decode, end,
                    cat="serve", tag=tag, track=lane,
                    tokens=len(req.tokens), finish=req.finish_reason,
                )
            else:  # never admitted (rejected / evicted from queue)
                tr.add_span(
                    "request.queued", req.t_submit, end,
                    cat="serve", tag=tag, track=lane, finish=req.finish_reason,
                )
        if self._pool is not None:
            self.metrics.gauge("serve.pages_in_use").set(self._pool.in_use)
        self.metrics.counter(f"serve.requests_{req.finish_reason}").inc()
        if req.t_first_token is not None:
            self.metrics.histogram("serve.ttft_s").record(
                req.t_first_token - req.t_submit
            )
        gaps = [
            req.token_times[i] - req.token_times[i - 1]
            for i in range(1, len(req.token_times))
        ]
        if gaps:
            self.metrics.histogram("serve.tpot_s").record(float(np.mean(gaps)))

    # ------------------------------------------------------- generate API

    def generate(
        self,
        prompts: jax.Array,  # (B, S_prompt) int32
        max_new_tokens: int,
        *,
        frames: Optional[jax.Array] = None,
        seed: int = 0,
    ) -> Tuple[jax.Array, Dict[str, float]]:
        """Compatibility shim: batched equal-length generation on top of
        the request loop. Token-exact with the pre-redesign static path
        for greedy decoding (the parity test pins this); encoder-decoder
        configs and frame inputs take the legacy path directly.
        """
        if self.cfg.is_encdec or frames is not None:
            return self._generate_static(
                prompts, max_new_tokens, frames=frames, seed=seed
            )
        serve = self.serve
        b, s = prompts.shape
        prompts_np = np.asarray(prompts)
        base = jax.random.PRNGKey(seed)
        eos = serve.eos_id

        def legacy_len(handle_rows: List[List[int]]) -> Optional[int]:
            # Legacy truncation rule: the prefill token (index 0) is never
            # eos-checked; the loop stopped one step after the LAST row hit
            # eos, so output length = max over rows of (first eos index)+1.
            # None while some row hasn't hit eos yet.
            if eos < 0:
                return None
            firsts = []
            for toks in handle_rows:
                hit = next((i for i in range(1, len(toks)) if toks[i] == eos), None)
                if hit is None:
                    return None
                firsts.append(hit)
            return min(max_new_tokens, max(firsts) + 1)

        # Requests carry eos disabled (the host applies the legacy
        # stop-when-ALL-done rule above); rows must always queue, whatever
        # the engine's admission policy, or the shim would drop rows.
        saved_serve = self.serve
        if saved_serve.admission != "queue" or saved_serve.max_queue:
            self.serve = dataclasses.replace(
                saved_serve, admission="queue", max_queue=0
            )
        try:
            handles = [
                self.submit(
                    prompts_np[i],
                    max_new_tokens,
                    temperature=serve.temperature,
                    eos_id=-1,
                    _key=np.asarray(jax.random.fold_in(base, i)),
                )
                for i in range(b)
            ]
            while not all(h.done for h in handles):
                self.step()
                t = legacy_len([h.tokens() for h in handles])
                if t is not None and all(len(h.tokens()) >= t for h in handles):
                    break
            for h in handles:
                if not h.done:
                    self.evict(h)
        finally:
            self.serve = saved_serve
        rows = [h.tokens() for h in handles]
        target_len = legacy_len(rows) or max_new_tokens
        tokens = jnp.asarray(np.asarray([r[:target_len] for r in rows], np.int32))
        stats = {
            "prompt_len": float(s),
            "generated": float(tokens.shape[1]),
            "cache_pos": float(s + tokens.shape[1] - 1),
        }
        # Autotune decision telemetry: how many matmul resolutions this
        # process served from the cache vs decided fresh. Full per-decision
        # records (site, kind, predicted-vs-measured) via autotune_stats().
        tel = autotune.get_telemetry()
        stats["autotune_cache_hits"] = float(tel.cache_hits)
        stats["autotune_cache_misses"] = float(tel.cache_misses)
        return tokens, stats

    def _generate_static(
        self,
        prompts: jax.Array,  # (B, S_prompt) int32
        max_new_tokens: int,
        *,
        frames: Optional[jax.Array] = None,
        seed: int = 0,
    ) -> Tuple[jax.Array, Dict[str, float]]:
        """The pre-redesign lockstep loop, verbatim: one static
        equal-length batch, per-token host sync on eos. Kept as the
        encdec/frames path and as the parity anchor for the shim."""
        cfg, serve = self.cfg, self.serve
        b, s = prompts.shape
        total = s + max_new_tokens
        assert total <= serve.max_seq, (total, serve.max_seq)
        cache = M.init_cache(cfg, b, serve.max_seq)

        batch = {"tokens": prompts}
        if frames is not None:
            batch["frames"] = frames
        if cfg.mrope:
            batch["positions"] = make_stub_positions(b, s)
        logits, cache = self._prefill(self.params, batch, cache)

        key = jax.random.PRNGKey(seed)
        if serve.temperature > 0:
            key, sub = jax.random.split(key)
            nxt = jax.random.categorical(sub, logits / serve.temperature)[:, None]
        else:
            nxt = jnp.argmax(logits, axis=-1)[:, None]

        out: List[jax.Array] = [nxt]
        done = jnp.zeros((b,), bool)
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            positions = (
                make_stub_positions(b, 1, offset=s + i + 1) if cfg.mrope else None
            )
            nxt, cache = self._decode(
                self.params, nxt, cache, positions, sub,
                jnp.float32(serve.temperature),
            )
            if serve.eos_id >= 0:
                done = done | (nxt[:, 0] == serve.eos_id)
                if bool(jnp.all(done)):
                    out.append(nxt)
                    break
            out.append(nxt)
        tokens = jnp.concatenate(out, axis=1)
        stats = {
            "prompt_len": float(s),
            "generated": float(tokens.shape[1]),
            "cache_pos": float(cache["pos"]),
        }
        tel = autotune.get_telemetry()
        stats["autotune_cache_hits"] = float(tel.cache_hits)
        stats["autotune_cache_misses"] = float(tel.cache_misses)
        return tokens, stats

    # -------------------------------------------------------- telemetry

    def serve_stats(self) -> Dict[str, Any]:
        """Scheduler/pool snapshot, autotune_stats()-style: queue depth,
        slot occupancy, pages in use, prefill/decode split."""
        st = self._stats
        out: Dict[str, Any] = {
            "slots": self.serve.slots,
            "slots_active": len(self._active),
            "queue_depth": len(self._queue),
            "page_size": self.serve.page_size,
            "requests": {
                "submitted": st.submitted,
                "admitted": st.admitted,
                "finished": st.finished,
                "evicted": st.evicted,
                "errors": st.errors,
                "timeouts": st.timeouts,
                "rejected": st.rejected,
            },
            "prefills": st.prefills,
            "decode_steps": st.decode_steps,
            "syncs": st.syncs,
            "tokens_emitted": st.tokens_emitted,
            "peak_queue_depth": st.peak_queue_depth,
            "prefill_s": st.prefill_s,
            "decode_dispatch_s": st.decode_dispatch_s,
            "drain_s": st.drain_s,
            "decode_buckets": dict(st.buckets),
        }
        if self._pool is not None:
            out.update(
                page_budget=self._pool.capacity,
                pages_in_use=self._pool.in_use,
                pages_free=self._pool.available,
                peak_pages_in_use=st.peak_pages_in_use,
            )
        return out

    def autotune_stats(self) -> Dict:
        """Full autotune telemetry snapshot plus the calibration it ran on.

        Each fresh decision carries its per-constant cost split under
        ``terms`` (t_flop/t_elem/t_coll seconds, and t_h2d for the
        out-of-core ``strassen_oot`` family); ``calibration`` reports the
        fitted constants themselves (None when every decision came from a
        warm cache and no calibration ever ran). ``oot`` carries the
        out-of-core scheduler's recent run stats (waves, peak device
        bytes, overlap telemetry) for any ``strassen_oot`` resolutions
        this process executed since the engine was built.
        """
        return {
            **autotune.get_telemetry().snapshot(),
            "calibration": autotune.calibration_snapshot(),
            "oot": self._oot_ring.snapshot(),
        }

    def stats(self) -> Dict[str, Any]:
        """One roll-up of every telemetry surface this engine owns:
        ``serve`` (scheduler/pool counters), ``autotune`` (decision log +
        calibration + out-of-core runs), and ``obs`` — the engine's
        metrics registry snapshot (TTFT/TPOT histograms, pages-in-use
        gauge, token counters) plus the process tracer's state."""
        tracer = obs_tracer.get_tracer()
        return {
            "serve": self.serve_stats(),
            "autotune": self.autotune_stats(),
            "obs": {
                "metrics": self.metrics.snapshot(),
                "tracer": {
                    "enabled": tracer.enabled,
                    "spans": len(tracer.spans),
                    "dropped": tracer.dropped,
                },
            },
        }

"""Paged KV-cache pool for the continuous-batching serving engine.

Two layers, mirroring the blocks arena allocator's split between a host
free-list and device storage:

* :class:`PagePool` — host-side page accounting. A fixed budget of
  interchangeable pages with a free list (the ``ArenaStore`` design from
  ``repro.blocks.blockmatrix``, re-applied to KV pages). Page id 0 is a
  reserved scratch page: dead decode slots and padding writes are routed
  there so the jitted step never needs a branch.
* :class:`CacheLayout` — the bridge between the model's dense serving
  cache pytree (``transformer.init_cache``) and pooled device storage.
  It classifies every cache subtree by its layer kind:

  - full-attention KV (``attn``, or ``local_attn`` with window 0) is
    **paged**: one pool tensor of shape ``(P, Hkv, page_size, hd)``
    (scan-stacked groups carry a leading group axis) shared by all
    slots, addressed through a per-slot page table;
  - ring-buffer local attention and recurrent state (mlstm / slstm /
    rglru) are **slot-indexed**: O(window) / O(1) per slot, so they stay
    dense at ``batch == n_slots``.

  The jitted decode step gathers a slot's pages into a contiguous
  bucketed view, runs the ordinary model decode, then scatters the one
  written column back — so heterogeneous sequence lengths share the
  device budget instead of each padding to ``max_seq``, while the model
  code stays unchanged.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.transformer import _init_layer_cache

__all__ = ["PoolExhausted", "PagePool", "CacheLayout", "SCRATCH_PAGE"]

# Page id 0 never holds request state: dead slots scatter into it and
# unwritten page-table entries gather from it (masked out by position).
SCRATCH_PAGE = 0


class PoolExhausted(RuntimeError):
    """Raised by PagePool.alloc when the request cannot be satisfied."""


class PagePool:
    """Host-side free-list over a fixed budget of interchangeable pages.

    Pages are plain ints in ``[1, capacity]`` (0 is the scratch page).
    Same discipline as the blocks arena allocator: O(1) alloc/free, a
    double-free guard, and exact accounting so eviction leaks surface
    immediately in tests.
    """

    def __init__(self, capacity: int, page_size: int):
        if capacity < 0:
            raise ValueError(f"page capacity must be >= 0, got {capacity}")
        if page_size <= 0:
            raise ValueError(f"page_size must be > 0, got {page_size}")
        self.capacity = int(capacity)
        self.page_size = int(page_size)
        self._free = deque(range(1, capacity + 1))
        self._in_use: set = set()

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._in_use)

    def pages_for_tokens(self, n_tokens: int) -> int:
        return -(-max(int(n_tokens), 0) // self.page_size)

    def alloc(self, n: int) -> List[int]:
        if n > len(self._free):
            raise PoolExhausted(
                f"need {n} pages, {len(self._free)} free of {self.capacity}"
            )
        pages = [self._free.popleft() for _ in range(n)]
        self._in_use.update(pages)
        return pages

    def free(self, pages: List[int]) -> None:
        for p in pages:
            if p == SCRATCH_PAGE:
                raise ValueError("scratch page cannot be freed")
            if p not in self._in_use:
                raise ValueError(f"double free / foreign page {p}")
            self._in_use.remove(p)
            self._free.append(p)


# --------------------------------------------------------------- layout


@dataclasses.dataclass(frozen=True)
class _Node:
    """One cache subtree: where it lives and how it is stored."""

    where: str  # "groups" | "tail"
    key: Any  # "pos{j}" or tail index
    kind: str  # layer kind from cfg.block_pattern
    stacked: bool  # True -> leading scan-group axis
    paged: bool  # True -> attn KV routed through the page pool


def _is_paged(cfg: ModelConfig, kind: str) -> bool:
    # local_attn with window 0 degenerates to full attention (see
    # transformer._apply_layer); a real window is a fixed-size ring.
    return kind == "attn" or (kind == "local_attn" and not cfg.local_window)


@dataclasses.dataclass(frozen=True)
class CacheLayout:
    """Static description of how a config's serving cache maps to pools.

    Built once per engine; all methods are pure shape-level functions,
    safe to close over in jitted step bodies.
    """

    cfg: ModelConfig
    n_slots: int
    page_size: int
    max_seq: int

    @property
    def table_width(self) -> int:
        """Max pages a single slot can reference (covers max_seq)."""
        return -(-self.max_seq // self.page_size)

    @property
    def nodes(self) -> Tuple[_Node, ...]:
        cfg = self.cfg
        period = len(cfg.block_pattern)
        n_groups = cfg.n_layers // period
        n_tail = cfg.n_layers - n_groups * period
        out: List[_Node] = []
        if n_groups:
            for j in range(period):
                kind = cfg.block_pattern[j]
                out.append(
                    _Node("groups", f"pos{j}", kind, True, _is_paged(cfg, kind))
                )
        for i in range(n_tail):
            kind = cfg.block_pattern[i % period]
            out.append(_Node("tail", i, kind, False, _is_paged(cfg, kind)))
        return tuple(out)

    @property
    def has_paged(self) -> bool:
        return any(n.paged for n in self.nodes)

    @property
    def n_groups(self) -> int:
        return self.cfg.n_layers // len(self.cfg.block_pattern)

    def _cache_dtype(self):
        cfg = self.cfg
        return (
            jnp.dtype(cfg.cache_dtype) if cfg.cache_dtype else jnp.dtype(cfg.dtype)
        )

    def _sub(self, tree: Dict[str, Any], node: _Node) -> Any:
        return tree[node.where][node.key]

    def _set_sub(self, tree: Dict[str, Any], node: _Node, value: Any) -> None:
        tree[node.where][node.key] = value

    def _iter_nodes(
        self, *trees: Dict[str, Any]
    ) -> Iterator[Tuple[_Node, Tuple[Any, ...]]]:
        for node in self.nodes:
            yield node, tuple(self._sub(t, node) for t in trees)

    # ------------------------------------------------------------ init

    def init_kv_state(self, n_pages: int) -> Dict[str, Any]:
        """Persistent device state: pools for paged KV, slot arrays else.

        ``n_pages`` is the usable page budget; the pool tensor holds one
        extra scratch page at index 0.
        """
        cfg = self.cfg
        dtype = self._cache_dtype()
        p_total = n_pages + 1  # + scratch
        kv_shape = (p_total, cfg.n_kv_heads, self.page_size, cfg.head_dim)
        state: Dict[str, Any] = {"groups": {}, "tail": {}}
        for node in self.nodes:
            if node.paged:
                shape = ((self.n_groups,) + kv_shape) if node.stacked else kv_shape
                sub = {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
            else:
                sub = self._slot_state(node, self.n_slots)
            self._set_sub(state, node, sub)
        return state

    def _slot_state(self, node: _Node, batch: int) -> Any:
        cfg = self.cfg
        dtype = self._cache_dtype()
        if node.stacked:
            per = [
                _init_layer_cache(cfg, node.kind, batch, self.max_seq, dtype)
                for _ in range(self.n_groups)
            ]
            return jax.tree.map(lambda *xs: jnp.stack(xs), *per)
        return _init_layer_cache(cfg, node.kind, batch, self.max_seq, dtype)

    def init_prefill_cache(self, capacity: int) -> Dict[str, Any]:
        """Batch-1 dense cache for one request's prefill.

        Paged-attn entries are sized to the bucketed prompt ``capacity``
        (a multiple of page_size, so they reshape exactly into pages);
        ring/recurrent entries match the persistent slot layout so the
        insert step is a plain row write.
        """
        assert capacity % self.page_size == 0, (capacity, self.page_size)
        cfg = self.cfg
        dtype = self._cache_dtype()
        cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32), "groups": {}, "tail": {}}
        for node in self.nodes:
            seq = capacity if node.paged else self.max_seq
            if node.stacked:
                per = [
                    _init_layer_cache(cfg, node.kind, 1, seq, dtype)
                    for _ in range(self.n_groups)
                ]
                sub = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
            else:
                sub = _init_layer_cache(cfg, node.kind, 1, seq, dtype)
            self._set_sub(cache, node, sub)
        if not cache["groups"]:
            del cache["groups"]
        if not cache["tail"]:
            del cache["tail"]
        else:
            cache["tail"] = [cache["tail"][i] for i in range(len(cache["tail"]))]
        return cache

    # ------------------------------------------------------- structure

    def _as_model_cache(self, tree: Dict[str, Any], pos: jax.Array) -> Dict[str, Any]:
        """Re-shape an internal {groups,tail} dict into the model's cache
        pytree (tail as a list, empty containers dropped, pos added)."""
        cache: Dict[str, Any] = {"pos": pos}
        if tree["groups"]:
            cache["groups"] = tree["groups"]
        if tree["tail"]:
            cache["tail"] = [tree["tail"][i] for i in range(len(tree["tail"]))]
        return cache

    # ---------------------------------------------------------- gather

    def gather(
        self,
        kv_state: Dict[str, Any],
        page_table: jax.Array,  # (n_slots, table_width) int32
        pos: jax.Array,  # (n_slots,) int32
        bucket_pages: int,
    ) -> Dict[str, Any]:
        """Materialize the dense decode view: each slot's first
        ``bucket_pages`` pages, contiguous along the seq axis."""
        table_b = page_table[:, :bucket_pages]
        dense: Dict[str, Any] = {"groups": {}, "tail": {}}
        for node, (sub,) in self._iter_nodes(kv_state):
            if node.paged:
                out = {
                    name: self._gather_leaf(pool, table_b, node.stacked)
                    for name, pool in sub.items()
                }
            else:
                out = sub  # slot-indexed already
            self._set_sub(dense, node, out)
        return self._as_model_cache(dense, pos)

    def _gather_leaf(self, pool: jax.Array, table_b: jax.Array, stacked: bool):
        ps = self.page_size
        b, bp = table_b.shape
        if stacked:
            g = jnp.take(pool, table_b, axis=1)  # (G, B, bp, H, ps, d)
            g = jnp.moveaxis(g, 3, 2)  # (G, B, H, bp, ps, d)
            gg, _, h, _, _, d = g.shape
            return g.reshape(gg, b, h, bp * ps, d)
        g = jnp.take(pool, table_b, axis=0)  # (B, bp, H, ps, d)
        g = jnp.moveaxis(g, 2, 1)  # (B, H, bp, ps, d)
        _, h, _, _, d = g.shape
        return g.reshape(b, h, bp * ps, d)

    # --------------------------------------------------------- scatter

    def scatter_token(
        self,
        kv_state: Dict[str, Any],
        new_dense: Dict[str, Any],
        page_table: jax.Array,
        pos: jax.Array,  # (n_slots,) position written this step
        live: jax.Array,  # (n_slots,) bool
    ) -> Dict[str, Any]:
        """Commit one decode step: write each live slot's new KV column
        into its page; freeze slot-indexed state of dead slots."""
        ps = self.page_size
        page_idx = jnp.take_along_axis(
            page_table, (pos // ps)[:, None], axis=1
        )[:, 0]
        page_idx = jnp.where(live, page_idx, SCRATCH_PAGE)
        off = pos % ps
        new_tail = new_dense.get("tail", [])
        new_groups = new_dense.get("groups", {})
        new_internal = {"groups": new_groups, "tail": dict(enumerate(new_tail))}
        out: Dict[str, Any] = {"groups": {}, "tail": {}}
        for node, (old, new) in self._iter_nodes(kv_state, new_internal):
            if node.paged:
                sub = {
                    name: self._scatter_leaf(
                        old[name], new[name], page_idx, off, pos, node.stacked
                    )
                    for name in old
                }
            else:
                sub = jax.tree.map(
                    lambda o, n: self._freeze(o, n, live, node.stacked), old, new
                )
            self._set_sub(out, node, sub)
        return out

    def _freeze(self, old, new, live, stacked: bool):
        ax = 1 if stacked else 0
        shape = [1] * old.ndim
        shape[ax] = live.shape[0]
        return jnp.where(live.reshape(shape), new, old)

    def _scatter_leaf(self, pool, dense_new, page_idx, off, pos, stacked: bool):
        # Pages were gathered from the table prefix in order, so view
        # position == true position: the column written by this decode
        # step sits at ``pos`` along the gathered seq axis.
        b = pos.shape[0]
        if stacked:
            # dense_new: (G, B, H, L, d) -> written column (G, B, H, d)
            col = jnp.take_along_axis(
                dense_new, pos.reshape(1, b, 1, 1, 1), axis=3
            )[:, :, :, 0, :]
            vals = jnp.moveaxis(col, 1, 0)  # (B, G, H, d)
            return pool.at[:, page_idx, :, off, :].set(vals)
        # dense_new: (B, H, L, d) -> (B, H, d)
        col = jnp.take_along_axis(
            dense_new, pos.reshape(b, 1, 1, 1), axis=2
        )[:, :, 0, :]
        return pool.at[page_idx, :, off, :].set(col)

    # ---------------------------------------------------------- insert

    def insert_request(
        self,
        kv_state: Dict[str, Any],
        prefill_cache: Dict[str, Any],
        slot: jax.Array,  # scalar int32
        page_ids: jax.Array,  # (capacity // page_size,) int32
    ) -> Dict[str, Any]:
        """Move a finished prefill (batch=1 dense cache) into the pool:
        KV pages scattered to their allocated ids, slot state row-written."""
        pre_tail = prefill_cache.get("tail", [])
        pre = {
            "groups": prefill_cache.get("groups", {}),
            "tail": dict(enumerate(pre_tail)),
        }
        out: Dict[str, Any] = {"groups": {}, "tail": {}}
        for node, (old, new) in self._iter_nodes(kv_state, pre):
            if node.paged:
                sub = {
                    name: self._insert_leaf(old[name], new[name], page_ids, node.stacked)
                    for name in old
                }
            else:
                if node.stacked:
                    sub = jax.tree.map(
                        lambda o, n: o.at[:, slot].set(n[:, 0]), old, new
                    )
                else:
                    sub = jax.tree.map(lambda o, n: o.at[slot].set(n[0]), old, new)
            self._set_sub(out, node, sub)
        return out

    def _insert_leaf(self, pool, pre, page_ids, stacked: bool):
        ps = self.page_size
        nb = page_ids.shape[0]
        if stacked:
            # pre: (G, 1, H, C, d) -> (G, nb, H, ps, d)
            g, _, h, c, d = pre.shape
            vals = pre[:, 0].reshape(g, h, nb, ps, d)
            vals = jnp.moveaxis(vals, 2, 1)
            return pool.at[:, page_ids].set(vals)
        _, h, c, d = pre.shape
        vals = pre[0].reshape(h, nb, ps, d)
        vals = jnp.moveaxis(vals, 1, 0)
        return pool.at[page_ids].set(vals)

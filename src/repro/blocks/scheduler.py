"""Level-order out-of-core Strassen executor over tagged block stores.

This is the paper's level-parallel recursion (Fig. 2) re-targeted at the
host/device memory hierarchy instead of a Spark cluster:

* **divide** — for each level, every tree node's seven children are formed
  by signed sums of the parent's quadrant blocks (Stark's
  flatMapToPair/groupByKey/flatMap stage). These are host-side numpy adds
  streaming block-by-block through the :class:`~repro.blocks.blockmatrix
  .BlockStore`, so host working set is O(block), not O(matrix).
* **leaf** — the 7^q leaf products are batched into *waves* sized so that
  (current wave operands + products + prefetched next-wave operands) fit a
  configurable device-memory budget. Each wave is staged with
  ``jax.device_put`` and dispatched through the standard
  :func:`repro.core.backend.matmul` routing (``kind="auto"`` by default,
  so the calibrated dispatcher picks naive/Strassen/fused per leaf shape);
  the next wave's operands are put on device while the current wave
  computes — double buffering, JAX's async dispatch does the overlap.
* **combine** — level-order bottom-up signed sums of the seven child
  products into each parent's quadrants (Stark's combine stage), again
  host-side and block-streaming; child nodes are freed as soon as their
  parent is built.

Peak device bytes are therefore bounded by the budget rather than the
problem size — the paper's "matrices far larger than memory" regime with
device HBM playing the executor and the host store playing HDFS.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.blocks import tags
from repro.blocks.blockmatrix import BlockMatrix, BlockStore, make_store
from repro.core.coefficients import Scheme, get_scheme

__all__ = [
    "OotStats",
    "StrassenScheduler",
    "strassen_oot_matmul",
    "leaf_bytes",
    "min_depth_for_budget",
]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _leaf_dims(m: int, k: int, n: int, depth: int) -> Tuple[int, int, int]:
    step = 2**depth
    return _ceil_div(m, step), _ceil_div(k, step), _ceil_div(n, step)


def leaf_bytes(m: int, k: int, n: int, depth: int, dtype) -> int:
    """Device bytes one leaf multiply needs: A + B operands + C product.

    Sized at the scheduler's default *staging* dtype — the accumulation
    dtype of ``dtype`` (f32 for bf16 inputs; see
    :class:`StrassenScheduler`) — so budget planning is conservative for
    callers that narrow staging to the compute dtype.
    """
    lm, lk, ln = _leaf_dims(m, k, n, depth)
    item = np.dtype(np.result_type(np.dtype(dtype), np.float32)).itemsize
    return (lm * lk + lk * ln + lm * ln) * item


def min_depth_for_budget(
    m: int, k: int, n: int, budget_bytes: int, dtype, max_depth: int = 12
) -> int:
    """Smallest recursion depth whose single leaf fits the device budget.

    The scheduler needs at least one leaf's (A, B, C) resident; callers
    wanting double-buffered waves should leave ~2x headroom (or pass one
    level deeper).
    """
    for depth in range(1, max_depth + 1):
        if leaf_bytes(m, k, n, depth, dtype) <= budget_bytes:
            return depth
    raise ValueError(
        f"no depth <= {max_depth} fits ({m}x{k}x{n}, {np.dtype(dtype).name}) "
        f"leaves into {budget_bytes} bytes"
    )


@dataclasses.dataclass
class OotStats:
    """Execution telemetry for one out-of-core multiply."""

    m: int
    k: int
    n: int
    depth: int
    scheme: str
    leaves: int
    waves: int
    wave_size: int
    prefetch: bool
    stage_dtype: str
    budget_bytes: int
    per_leaf_bytes: int
    peak_device_bytes: int
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    host_store_peak_bytes: int = 0
    divide_s: float = 0.0
    leaf_s: float = 0.0
    combine_s: float = 0.0
    total_s: float = 0.0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


class StrassenScheduler:
    """Budgeted level-order Strassen over a host-resident block store.

    Args:
      depth: recursion depth q (7^q leaves). Must make a leaf fit the
        budget — see :func:`min_depth_for_budget`.
      budget_bytes: peak device bytes the leaf waves may occupy.
      scheme: coefficient scheme (strassen | winograd | naive8).
      backend: :class:`repro.core.backend.MatmulBackend` routing for the
        leaf multiplies; defaults to ``kind="auto"`` so each leaf shape
        goes through the calibrated dispatcher (and, transitively, any
        registered mesh strategy a future resolve chooses).
      block: target block side for the store partition; ``None`` stores
        one block per leaf operand (the coarsest legal grain).
      prefetch: double-buffer the next wave's host->device staging while
        the current wave computes. Automatically disabled when the budget
        only fits a single un-prefetched wave.
      stage_dtype: dtype of the staged leaf operands (and so of the leaf
        multiply). ``None`` — the default — stages in the accumulation
        dtype (f32 for bf16 inputs): operand combos never round until the
        final output cast, the Huang-et-al. packing-buffer discipline,
        which holds deep-recursion bf16 parity to ~1e-3. Pass the compute
        dtype explicitly to halve staging volume at the cost of one
        rounding per leaf operand (depth-2 bf16 parity degrades to ~2e-2).
    """

    def __init__(
        self,
        *,
        depth: int,
        budget_bytes: int,
        scheme: Scheme | str = "strassen",
        backend=None,
        block: Optional[int] = None,
        prefetch: bool = True,
        stage_dtype=None,
    ) -> None:
        if depth < 1:
            raise ValueError("out-of-core Strassen needs depth >= 1")
        if budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive")
        self.depth = depth
        self.budget_bytes = int(budget_bytes)
        self.scheme = get_scheme(scheme) if isinstance(scheme, str) else scheme
        self.block = block
        self.prefetch = prefetch
        self.stage_dtype = stage_dtype
        if backend is None:
            from repro.core.backend import MatmulBackend

            backend = MatmulBackend(kind="auto", depth=2, min_dim=1024)
        self.backend = backend

    # ------------------------------------------------------------ internals
    @staticmethod
    def _node_tag(op: str, path: Tuple[int, ...]) -> str:
        return f"{op}:{tags.to_string(path)}"

    def _node(
        self,
        store: BlockStore,
        op: str,
        path: Tuple[int, ...],
        root_shape: Tuple[int, int],
        block_shape: Tuple[int, int],
        dtype,
    ) -> BlockMatrix:
        level = len(path)
        shape = (root_shape[0] >> level, root_shape[1] >> level)
        return BlockMatrix(store, shape, block_shape, dtype, self._node_tag(op, path))

    @staticmethod
    def _signed_sum(get_block, coefs: np.ndarray, acc_dtype) -> np.ndarray:
        """sum_i coefs[i] * get_block(i) with zero-skip and +/-1 fast paths.

        The one accumulation discipline both divide and combine share:
        terms are read through ``.astype`` (ml_dtypes/bf16 memmaps fail
        numpy's direct-cast buffer path) and summed in ``acc_dtype``.
        """
        acc = None
        for idx in range(len(coefs)):
            c = float(coefs[idx])
            if c == 0.0:
                continue
            blk = np.asarray(get_block(idx)).astype(acc_dtype, copy=False)
            term = blk if c == 1.0 else (-blk if c == -1.0 else c * blk)
            acc = term if acc is None else acc + term
        assert acc is not None, "coefficient row is all zero"
        return acc

    def _divide_child(
        self,
        parent: BlockMatrix,
        child: BlockMatrix,
        coef_row: np.ndarray,
        acc_dtype,
    ) -> None:
        """child = sum_q coef_row[q] * quadrant_q(parent), block-streamed."""
        gr, gc = child.grid
        for i in range(gr):
            for j in range(gc):
                acc = self._signed_sum(
                    lambda q: parent.block((q // 2) * gr + i, (q % 2) * gc + j),
                    coef_row, acc_dtype,
                )
                child.put_block(i, j, acc.astype(child.dtype))

    def _combine_parent(
        self,
        children: Sequence[BlockMatrix],
        parent: BlockMatrix,
        acc_dtype,
    ) -> None:
        """parent quadrants = sum_p c_coef[k, p] * child_p, block-streamed."""
        gr, gc = children[0].grid
        c_coef = self.scheme.c_coef
        for kq in range(tags.Q_BASE):
            for i in range(gr):
                for j in range(gc):
                    acc = self._signed_sum(
                        lambda p: children[p].block(i, j), c_coef[kq], acc_dtype
                    )
                    parent.put_block(
                        (kq // 2) * gr + i, (kq % 2) * gc + j, acc.astype(parent.dtype)
                    )

    def _leaf_matmul(self, a_dev, b_dev):
        from repro.core import backend as _backend

        return _backend.matmul(a_dev, b_dev, self.backend, site="blocks.leaf")

    # -------------------------------------------------------------- the run
    def matmul(
        self,
        a: np.ndarray,
        b: np.ndarray,
        *,
        store: str | BlockStore = "dict",
        store_root: Optional[str] = None,
    ) -> Tuple[np.ndarray, OotStats]:
        """``a @ b`` with device memory bounded by the budget.

        ``a``/``b`` are host arrays (numpy or anything ``np.asarray``
        accepts, bfloat16 included). ``store`` picks the block residency:
        'dict' | 'arena' | 'memmap' or a ready :class:`BlockStore`.
        """
        import jax

        t_start = time.perf_counter()
        a = np.asarray(a)
        b = np.asarray(b)
        if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
            raise ValueError(f"bad matmul shapes {a.shape} @ {b.shape}")
        dtype = np.result_type(a.dtype, b.dtype)
        acc_dtype = np.result_type(dtype, np.float32)
        m, k = a.shape
        n = b.shape[1]
        depth, rank = self.depth, self.scheme.n_mults

        # Recursion-aligned padded dims and the block partition. With an
        # explicit block grain each leaf dim rounds up to a whole number of
        # blocks so every level's grid halves exactly.
        lm, lk, ln = _leaf_dims(m, k, n, depth)
        if self.block is not None:
            bam = min(self.block, lm)
            bak = min(self.block, lk)
            bbn = min(self.block, ln)
            lm, lk, ln = (
                _ceil_div(lm, bam) * bam,
                _ceil_div(lk, bak) * bak,
                _ceil_div(ln, bbn) * bbn,
            )
        else:
            bam, bak, bbn = lm, lk, ln
        pm, pk, pn = lm << depth, lk << depth, ln << depth

        stage_dtype = (
            np.dtype(self.stage_dtype) if self.stage_dtype is not None else acc_dtype
        )
        itemsize = stage_dtype.itemsize
        in_bytes = (lm * lk + lk * ln) * itemsize
        per_leaf = in_bytes + lm * ln * itemsize
        prefetch = self.prefetch
        wave_size = self.budget_bytes // (per_leaf + in_bytes) if prefetch else 0
        if wave_size < 1:
            prefetch = False
            wave_size = self.budget_bytes // per_leaf
        if wave_size < 1:
            raise ValueError(
                f"device budget {self.budget_bytes} B cannot hold one "
                f"{lm}x{lk}x{ln} {np.dtype(dtype).name} leaf ({per_leaf} B); "
                f"use depth >= "
                f"{min_depth_for_budget(m, k, n, self.budget_bytes, dtype)}"
            )

        # Divide/combine chains accumulate (and store) in acc_dtype; blocks
        # round at most once — operands at the staging cast, C at the final
        # cast. One rounding per value instead of one per level is the same
        # discipline as the fused kernel's fp32 MXU accumulation, and what
        # keeps depth>=2 bf16 parity inside 1e-2. Leaf compute and H2D/D2H
        # volume run at ``stage_dtype`` — the accumulation dtype by default
        # (2x the compute-dtype bytes for bf16 inputs), narrowed to the
        # compute dtype via the ``stage_dtype`` knob.
        acc_item = np.dtype(acc_dtype).itemsize
        slot_bytes = max(bam * bak, bak * bbn, bam * bbn) * acc_item
        # Stores built here from a spec are owned (and closed) here;
        # caller-provided BlockStore instances stay open for inspection.
        owned_store = not isinstance(store, BlockStore)
        store = make_store(store, slot_bytes=slot_bytes, root=store_root)
        try:

            leaves = rank**depth
            stats = OotStats(
                m=m, k=k, n=n, depth=depth, scheme=self.scheme.name,
                leaves=leaves, waves=0, wave_size=wave_size, prefetch=prefetch,
                stage_dtype=stage_dtype.name,
                budget_bytes=self.budget_bytes, per_leaf_bytes=per_leaf,
                peak_device_bytes=0,
            )

            # --- ingest roots (edge/odd dims zero-extend to the padded grain).
            a_root = BlockMatrix.from_dense(
                a, (bam, bak), store, self._node_tag("A", ()), shape=(pm, pk)
            )
            b_root = BlockMatrix.from_dense(
                b, (bak, bbn), store, self._node_tag("B", ()), shape=(pk, pn)
            )

            # --- divide: level-order, all rank^level nodes per level.
            t0 = time.perf_counter()
            for level in range(depth):
                p_dtype = dtype if level == 0 else acc_dtype
                for path in tags.leaf_paths(level, rank):
                    pa = self._node(store, "A", path, (pm, pk), (bam, bak), p_dtype)
                    pb = self._node(store, "B", path, (pk, pn), (bak, bbn), p_dtype)
                    for p in range(rank):
                        ca = self._node(
                            store, "A", tags.child(path, p, rank), (pm, pk),
                            (bam, bak), acc_dtype,
                        )
                        cb = self._node(
                            store, "B", tags.child(path, p, rank), (pk, pn),
                            (bak, bbn), acc_dtype,
                        )
                        self._divide_child(pa, ca, self.scheme.a_coef[p], acc_dtype)
                        self._divide_child(pb, cb, self.scheme.b_coef[p], acc_dtype)
                stats.host_store_peak_bytes = max(
                    stats.host_store_peak_bytes, store.nbytes()
                )
                # Parents are consumed: only the leaf level feeds the multiply.
                # Freed via the node's own key iteration (O(blocks-of-node)),
                # not delete_tag's full-store key scan.
                for path in tags.leaf_paths(level, rank):
                    self._node(store, "A", path, (pm, pk), (bam, bak), p_dtype).free()
                    self._node(store, "B", path, (pk, pn), (bak, bbn), p_dtype).free()
            stats.divide_s = time.perf_counter() - t0
            stats.host_store_peak_bytes = max(stats.host_store_peak_bytes, store.nbytes())

            # --- leaf waves: stage -> dispatch -> (prefetch next) -> fetch.
            t0 = time.perf_counter()
            leaf_list = list(tags.leaf_paths(depth, rank))
            waves: List[List[Tuple[int, ...]]] = [
                leaf_list[i : i + wave_size] for i in range(0, leaves, wave_size)
            ]

            def stage(wave: List[Tuple[int, ...]]):
                staged = []
                for path in wave:
                    na = self._node(store, "A", path, (pm, pk), (bam, bak), acc_dtype)
                    nb = self._node(store, "B", path, (pk, pn), (bak, bbn), acc_dtype)
                    # Any rounding to a narrower staging dtype happens here, at
                    # the host->device boundary — never mid-chain.
                    staged.append(
                        (
                            path,
                            jax.device_put(na.to_dense().astype(stage_dtype, copy=False)),
                            jax.device_put(nb.to_dense().astype(stage_dtype, copy=False)),
                        )
                    )
                    stats.h2d_bytes += in_bytes
                return staged

            staged = stage(waves[0]) if waves else []
            for w_idx, wave in enumerate(waves):
                current, staged = staged, None
                if current is None:  # prefetch off: stage synchronously
                    current = stage(wave)
                outs = [
                    (path, self._leaf_matmul(a_dev, b_dev))
                    for path, a_dev, b_dev in current
                ]
                nxt = waves[w_idx + 1] if w_idx + 1 < len(waves) else None
                device_now = len(wave) * per_leaf
                if prefetch and nxt is not None:
                    # Async H2D of the next wave overlaps the current compute.
                    staged = stage(nxt)
                    device_now += len(nxt) * in_bytes
                stats.peak_device_bytes = max(stats.peak_device_bytes, device_now)
                for path, out in outs:
                    host = np.asarray(out)
                    stats.d2h_bytes += host.nbytes
                    host = host.astype(acc_dtype, copy=False)
                    cn = self._node(store, "C", path, (pm, pn), (bam, bbn), acc_dtype)
                    for i in range(cn.grid[0]):
                        for j in range(cn.grid[1]):
                            cn.put_block(
                                i, j,
                                host[i * bam : (i + 1) * bam, j * bbn : (j + 1) * bbn],
                            )
                    self._node(store, "A", path, (pm, pk), (bam, bak), acc_dtype).free()
                    self._node(store, "B", path, (pk, pn), (bak, bbn), acc_dtype).free()
                # Drop this wave's device references before the next wave
                # dispatches: the fetched product buffers would otherwise stay
                # resident through the next compute and break the budget bound.
                current = outs = None
                stats.waves += 1
                stats.host_store_peak_bytes = max(
                    stats.host_store_peak_bytes, store.nbytes()
                )
            stats.leaf_s = time.perf_counter() - t0

            # --- combine: level-order bottom-up, freeing children as we go.
            t0 = time.perf_counter()
            for level in reversed(range(depth)):
                for path in tags.leaf_paths(level, rank):
                    children = [
                        self._node(
                            store, "C", tags.child(path, p, rank), (pm, pn),
                            (bam, bbn), acc_dtype,
                        )
                        for p in range(rank)
                    ]
                    parent = self._node(
                        store, "C", path, (pm, pn), (bam, bbn), acc_dtype
                    )
                    self._combine_parent(children, parent, acc_dtype)
                    for child in children:
                        child.free()
                stats.host_store_peak_bytes = max(
                    stats.host_store_peak_bytes, store.nbytes()
                )
            stats.combine_s = time.perf_counter() - t0

            c_root = self._node(store, "C", (), (pm, pn), (bam, bbn), acc_dtype)
            result = c_root.to_dense()[:m, :n].astype(dtype, copy=False)
            a_root.free()
            b_root.free()
            c_root.free()
        finally:
            if owned_store:
                store.close()
        stats.total_s = time.perf_counter() - t_start
        return result, stats


def strassen_oot_matmul(
    a: np.ndarray,
    b: np.ndarray,
    *,
    depth: int,
    budget_bytes: int,
    scheme: Scheme | str = "strassen",
    backend=None,
    block: Optional[int] = None,
    prefetch: bool = True,
    stage_dtype=None,
    store: str | BlockStore = "dict",
    store_root: Optional[str] = None,
) -> Tuple[np.ndarray, OotStats]:
    """Functional wrapper: one out-of-core Strassen multiply.

    See :class:`StrassenScheduler` for the parameters; this is the entry
    point :mod:`repro.core.backend` (kind='strassen_oot'), the autotuner's
    ``strassen_oot`` candidate family, ``launch/blocks_demo.py``, and
    ``benchmarks/fig8_scaling.py`` share.
    """
    sched = StrassenScheduler(
        depth=depth, budget_bytes=budget_bytes, scheme=scheme,
        backend=backend, block=block, prefetch=prefetch, stage_dtype=stage_dtype,
    )
    return sched.matmul(a, b, store=store, store_root=store_root)

"""Level-order out-of-core Strassen executor over tagged block stores.

This is the paper's level-parallel recursion (Fig. 2) re-targeted at the
host/device memory hierarchy instead of a Spark cluster:

* **divide** — for each level, every tree node's seven children are formed
  by signed sums of the parent's quadrant blocks (Stark's
  flatMapToPair/groupByKey/flatMap stage). These are host-side numpy adds
  streaming block-by-block through the :class:`~repro.blocks.blockmatrix
  .BlockStore`, so host working set is O(block), not O(matrix).
* **leaf** — the 7^q leaf products are batched into *waves* sized so that
  (current wave operands + products, the previous wave's still-in-flight
  working set — its operands stay pinned by the unfenced executions, not
  just its un-fetched products — and the prefetched next-wave operands)
  fit a configurable device-memory budget; see
  :func:`pipelined_leaf_bytes`. The wave loop is a 2-deep asynchronous
  pipeline
  keyed off JAX's async dispatch: wave k's products are left in flight
  while wave k+1's operands are ``jax.device_put`` and its multiplies
  dispatched, and the only blocking fence is the explicit
  ``jax.block_until_ready`` at each wave's D2H fetch — so H2D staging,
  leaf compute, and D2H drain of adjacent waves all overlap (the paper's
  Spark pipeline keeping all 7^q multiplies busy, JAMPI's
  shuffle-to-overlapped-transfer move re-targeted at the host<->device
  boundary). Fetched product buffers are released ("donated" into the
  host-side combine accumulation) the moment their bytes land on host,
  so peak device bytes stay inside the budget including in-flight
  prefetch. Per-wave issue/dispatch/fetch timestamps land in
  :class:`OotStats.wave_events` and derive ``overlap_efficiency``.
* **combine** — level-order bottom-up signed sums of the seven child
  products into each parent's quadrants (Stark's combine stage), again
  host-side and block-streaming; child nodes are freed as soon as their
  parent is built.

Peak device bytes are therefore bounded by the budget rather than the
problem size — the paper's "matrices far larger than memory" regime with
device HBM playing the executor and the host store playing HDFS.
"""
from __future__ import annotations

import dataclasses
import threading
import time
import weakref
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.blocks import tags
from repro.blocks.blockmatrix import (
    BlockMatrix,
    BlockStore,
    make_store,
    signed_block_sum,
)
from repro.blocks.plan import BilinearPlan, as_bilinear_plan
from repro.blocks.recovery import (
    ChaosConfig,
    ChaosStore,
    FaultError,
    FlakyLeaf,
    Lineage,
    RecoveringStore,
)
from repro.core.coefficients import Scheme
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs_tracer

__all__ = [
    "OotStats",
    "OotStatsRing",
    "PlanScheduler",
    "StrassenScheduler",
    "strassen_oot_matmul",
    "leaf_bytes",
    "pipelined_leaf_bytes",
    "min_depth_for_budget",
    "attach_stats_ring",
    "recent_oot_stats",
    "reset_oot_stats",
]


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _leaf_dims(m: int, k: int, n: int, depth: int) -> Tuple[int, int, int]:
    step = 2**depth
    return _ceil_div(m, step), _ceil_div(k, step), _ceil_div(n, step)


def _leaf_inout_bytes(m: int, k: int, n: int, depth: int, dtype) -> Tuple[int, int]:
    """(operand bytes A + B, product bytes C) of one leaf multiply.

    Sized at the scheduler's default *staging* dtype — the accumulation
    dtype of ``dtype`` (f32 for bf16 inputs; see
    :class:`StrassenScheduler`) — so budget planning is conservative for
    callers that narrow staging to the compute dtype.
    """
    lm, lk, ln = _leaf_dims(m, k, n, depth)
    item = np.dtype(np.result_type(np.dtype(dtype), np.float32)).itemsize
    return (lm * lk + lk * ln) * item, lm * ln * item


def leaf_bytes(m: int, k: int, n: int, depth: int, dtype) -> int:
    """Device bytes one leaf multiply needs: A + B operands + C product.

    See :func:`_leaf_inout_bytes` for the staging-dtype sizing convention;
    :func:`pipelined_leaf_bytes` for the async pipeline's per-slot peak.
    """
    i, o = _leaf_inout_bytes(m, k, n, depth, dtype)
    return i + o


def pipelined_leaf_bytes(m: int, k: int, n: int, depth: int, dtype) -> int:
    """Device bytes one leaf *slot* occupies at the async pipeline's peak.

    While wave k computes, the 2-deep pipeline concurrently holds, per
    slot: wave k's full working set (A + B + C), wave k-1's full working
    set — its products are not yet fetched and its operands stay pinned
    by the still-in-flight executions until the D2H fence — and wave
    k+1's prefetched operands (A + B). That is ``2 * leaf_bytes`` plus
    one more set of operand bytes; sizing waves (and picking depths) at
    this slot makes the device budget a bound on actual residency, not
    just the quiescent single-wave state.
    """
    i, o = _leaf_inout_bytes(m, k, n, depth, dtype)
    return 2 * (i + o) + i


def min_depth_for_budget(
    m: int,
    k: int,
    n: int,
    budget_bytes: int,
    dtype,
    max_depth: int = 12,
    *,
    pipelined: bool = False,
) -> int:
    """Smallest recursion depth whose leaf working set fits the budget.

    ``pipelined=False`` (feasibility): one leaf's (A, B, C) resident — the
    scheduler can always run, degrading to un-prefetched single-leaf waves.
    ``pipelined=True`` (the async wave pipeline's peak): a leaf slot plus
    its in-flight neighbours — the previous wave's whole working set
    (operands pinned by the unfenced executions, products awaiting D2H)
    and the next wave's (A, B) prefetch — i.e.
    :func:`pipelined_leaf_bytes`; depths chosen this way keep the 2-deep
    pipeline enabled instead of silently falling back to synchronous
    staging.
    """
    size = pipelined_leaf_bytes if pipelined else leaf_bytes
    for depth in range(1, max_depth + 1):
        if size(m, k, n, depth, dtype) <= budget_bytes:
            return depth
    raise ValueError(
        f"no depth <= {max_depth} fits ({m}x{k}x{n}, {np.dtype(dtype).name}) "
        f"leaves into {budget_bytes} bytes"
        + (" with pipeline headroom" if pipelined else "")
    )


@dataclasses.dataclass
class OotStats:
    """Execution telemetry for one out-of-core multiply.

    ``wave_events`` holds one record per staging wave with timestamps
    (seconds since the run started) for the pipeline's three async phases:
    ``issue_start``/``issue_end`` (host->device operand staging),
    ``dispatch_end`` (leaf multiplies issued, not fenced), and
    ``fetch_start``/``fetch_end`` (the D2H ``block_until_ready`` fence +
    host combine write). ``overlap_efficiency`` derives from them: the
    fraction of total transfer time (staging + fetch) issued while another
    wave's compute was in flight — with the 2-deep pipeline only the first
    wave's staging and the last wave's fetch are exposed, so any forced
    multi-wave run reports a strictly positive value; a synchronous run
    (``prefetch=False``) reports 0.0.
    """

    m: int
    k: int
    n: int
    depth: int
    scheme: str
    leaves: int
    waves: int
    wave_size: int
    prefetch: bool
    stage_dtype: str
    budget_bytes: int
    per_leaf_bytes: int
    peak_device_bytes: int
    # The plan's operator ("matmul" | "inverse" | "solve"): rings mix runs
    # from every recursive plan, so consumers filter/attribute by op.
    op: str = "matmul"
    # Nested out-of-core multiplies a solver run spawned (0 for matmul
    # runs — the scheduler itself never nests).
    oot_runs: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    host_store_peak_bytes: int = 0
    divide_s: float = 0.0
    leaf_s: float = 0.0
    combine_s: float = 0.0
    total_s: float = 0.0
    stage_s: float = 0.0
    fetch_s: float = 0.0
    overlap_efficiency: float = 0.0
    wave_events: List[dict] = dataclasses.field(default_factory=list)
    # Fault-tolerance telemetry (PR 9). ``rung`` is the degradation-ladder
    # rung the run finally completed on; ``degrade_events`` records each
    # transition. ``unrecovered_faults`` counts lineage recomputes that
    # failed the put-time checksum replay — zero on a healthy run, chaos
    # or not. ``injected_faults`` is cumulative across ladder rungs (the
    # flaky-leaf shim's call counter spans attempts).
    rung: str = "pipeline"
    degrades: int = 0
    degrade_events: List[dict] = dataclasses.field(default_factory=list)
    leaf_retries: int = 0
    recovered_blocks: int = 0
    lost_blocks: int = 0
    corrupt_blocks: int = 0
    injected_faults: int = 0
    unrecovered_faults: int = 0

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def assert_within_budget(self) -> None:
        """Raise if the modeled pipelined peak exceeded the device budget."""
        if self.peak_device_bytes > self.budget_bytes:
            raise AssertionError(
                f"peak device bytes {self.peak_device_bytes} exceeded the "
                f"budget {self.budget_bytes} (waves={self.waves}, "
                f"wave_size={self.wave_size}, prefetch={self.prefetch})"
            )

    def finalize_overlap(self) -> None:
        """Derive ``overlap_efficiency`` from the per-wave timestamps."""
        total = sum(
            (e["issue_end"] - e["issue_start"]) + (e["fetch_end"] - e["fetch_start"])
            for e in self.wave_events
        )
        if not self.prefetch or len(self.wave_events) < 2 or total <= 0.0:
            self.overlap_efficiency = 0.0
            return
        first, last = self.wave_events[0], self.wave_events[-1]
        exposed = (first["issue_end"] - first["issue_start"]) + (
            last["fetch_end"] - last["fetch_start"]
        )
        self.overlap_efficiency = max(0.0, min(1.0, 1.0 - exposed / total))


class OotStatsRing:
    """Bounded, thread-safe ring of recent OotStats dicts (oldest first).

    Every completed out-of-core run is appended to **all** registered
    rings. The module keeps one default ring behind the legacy
    ``recent_oot_stats()`` / ``reset_oot_stats()`` API; consumers that
    must not observe (or clobber) each other — e.g. two concurrently
    running serving Engines — attach their own via
    :func:`attach_stats_ring` and read/clear only that.
    """

    def __init__(self, maxlen: int = 64) -> None:
        self.maxlen = maxlen
        self._lock = threading.Lock()
        self._items: List[dict] = []

    def append(self, item: dict) -> None:
        with self._lock:
            self._items.append(item)
            if len(self._items) > self.maxlen:
                del self._items[: len(self._items) - self.maxlen]

    def snapshot(self) -> List[dict]:
        with self._lock:
            return list(self._items)

    def clear(self) -> None:
        with self._lock:
            self._items.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._items)


# The default ring (legacy module-level API) plus any attached consumer
# rings. WeakSet: an Engine's ring unregisters when the engine is
# collected — there is no explicit close() on that surface.
_DEFAULT_RING = OotStatsRing()
_RINGS: "weakref.WeakSet[OotStatsRing]" = weakref.WeakSet([_DEFAULT_RING])
_RINGS_LOCK = threading.Lock()


def attach_stats_ring(maxlen: int = 64) -> OotStatsRing:
    """New consumer-owned ring, subscribed to every future run's stats.

    The caller must hold the returned ring (registration is weak);
    clearing it does not disturb the default ring or other consumers.
    """
    ring = OotStatsRing(maxlen)
    with _RINGS_LOCK:
        _RINGS.add(ring)
    return ring


def recent_oot_stats() -> List[dict]:
    """Stats dicts of this process's recent out-of-core runs (oldest first)."""
    return _DEFAULT_RING.snapshot()


def reset_oot_stats() -> None:
    """Clear the **default** ring only; attached rings are unaffected."""
    _DEFAULT_RING.clear()


def _record_run(stats: OotStats) -> None:
    d = stats.to_dict()
    with _RINGS_LOCK:
        rings = list(_RINGS)
    for ring in rings:
        ring.append(d)


class _RunTrackingStore(BlockStore):
    """Forwards to a caller-provided store, recording the keys this run put.

    Tags are not run-scoped, so a failing run must delete exactly the
    blocks *it* created — a tag-prefix sweep would also destroy the blocks
    of other (interleaved or earlier) scheduler runs sharing the store.
    """

    def __init__(self, inner: BlockStore) -> None:
        self.inner = inner
        self.created: set = set()

    def put(self, key, block) -> None:
        self.inner.put(key, block)
        self.created.add(key)

    def get(self, key):
        return self.inner.get(key)

    def delete(self, key) -> None:
        self.inner.delete(key)
        self.created.discard(key)

    def __contains__(self, key) -> bool:
        return key in self.inner

    def keys(self):
        return self.inner.keys()

    def nbytes(self) -> int:
        return self.inner.nbytes()

    def drop_created(self) -> None:
        """Delete every block this run created and has not already freed."""
        for key in list(self.created):
            self.inner.delete(key)
        self.created.clear()

    def close(self) -> None:  # the caller owns the inner store
        pass


class PlanScheduler:
    """Budgeted level-order executor for one bilinear recursive plan.

    The waves/budget/pipeline/degradation machinery below is operator
    agnostic: divide rows, combine rows, rank, tag prefixes, and the op
    label all come from a :class:`repro.blocks.plan.BilinearPlan`. The
    Strassen base-7 and naive base-4 multiplies are simply the first two
    registered plans (wrapping the coefficient tables unchanged, so this
    executor is bit-identical to the pre-plan Strassen scheduler).

    Args:
      depth: recursion depth q (rank^q leaves). Must make a leaf fit the
        budget — see :func:`min_depth_for_budget`.
      budget_bytes: peak device bytes the leaf waves may occupy.
      scheme: coefficient scheme (strassen | winograd | naive8) — the
        historical spelling of ``plan`` for matmul plans.
      plan: the :class:`~repro.blocks.plan.BilinearPlan` to walk (or its
        registry name). Overrides ``scheme`` when given.
      backend: :class:`repro.core.backend.MatmulBackend` routing for the
        leaf multiplies; defaults to ``kind="auto"`` so each leaf shape
        goes through the calibrated dispatcher (and, transitively, any
        registered mesh strategy a future resolve chooses).
      block: target block side for the store partition; ``None`` stores
        one block per leaf operand (the coarsest legal grain).
      prefetch: run the leaf waves as a 2-deep asynchronous pipeline —
        wave k+1's host->device staging and dispatch are issued while
        wave k's products are still in flight, and the only blocking
        fence is each wave's D2H fetch. Automatically disabled (fully
        synchronous stage -> compute -> fetch per wave) when the budget
        cannot hold a pipelined slot (:func:`pipelined_leaf_bytes`: two
        leaves' working sets plus one more wave of operand prefetch).
      stage_dtype: dtype of the staged leaf operands (and so of the leaf
        multiply). ``None`` — the default — stages in the accumulation
        dtype (f32 for bf16 inputs): operand combos never round until the
        final output cast, the Huang-et-al. packing-buffer discipline,
        which holds deep-recursion bf16 parity to ~1e-3. Pass the compute
        dtype explicitly to halve staging volume at the cost of one
        rounding per leaf operand (depth-2 bf16 parity degrades to ~2e-2).
      chaos: deterministic fault injection
        (:class:`repro.blocks.recovery.ChaosConfig`): seeded block
        drop/corrupt probabilities on the store and flaky-leaf dispatch
        failures. Tests/benchmarks/CI only — injection implies
        ``recovery`` unless explicitly disabled.
      recovery: wrap the run's store in a
        :class:`~repro.blocks.recovery.RecoveringStore` (checksum on put,
        verify on get, transparent lineage recompute on loss/corruption).
        ``None`` (default) enables it exactly when ``chaos`` is set; pass
        True to harden a production run against a caller-shared store.
      retries: bounded retry count per leaf multiply (exponential backoff
        from ``retry_backoff_s``). Device-OOM is never retried — it goes
        straight to the degradation ladder.
      retry_backoff_s: first retry's sleep; doubles per attempt.
      degrade: on an unrecovered fault or device-OOM, walk the
        degradation ladder instead of failing the multiply: async
        pipeline -> synchronous staging -> halved wave -> one level
        deeper recursion. Each transition is a ``fault.degrade``
        span/counter and lands in ``OotStats.degrade_events``.
    """

    def __init__(
        self,
        *,
        depth: int,
        budget_bytes: int,
        scheme: Scheme | str = "strassen",
        plan: "BilinearPlan | str | None" = None,
        backend=None,
        block: Optional[int] = None,
        prefetch: bool = True,
        stage_dtype=None,
        chaos: Optional[ChaosConfig] = None,
        recovery: Optional[bool] = None,
        retries: int = 2,
        retry_backoff_s: float = 0.05,
        degrade: bool = True,
    ) -> None:
        if depth < 1:
            raise ValueError("out-of-core recursion needs depth >= 1")
        if budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive")
        if retries < 0 or retry_backoff_s < 0:
            raise ValueError("retries and retry_backoff_s must be >= 0")
        self.depth = depth
        self.budget_bytes = int(budget_bytes)
        self.plan = as_bilinear_plan(plan if plan is not None else scheme)
        if self.plan.leaf_kind != "matmul":
            raise ValueError(
                f"plan {self.plan.name!r} has leaf kind "
                f"{self.plan.leaf_kind!r}; the wave scheduler executes "
                f"matmul-leaf bilinear plans (dataflow plans run on "
                f"repro.blocks.solve)"
            )
        self.scheme = self.plan.scheme
        self.block = block
        self.prefetch = prefetch
        self.stage_dtype = stage_dtype
        self.chaos = chaos
        self.recovery = (chaos is not None) if recovery is None else bool(recovery)
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.degrade = degrade
        if backend is None:
            from repro.core.backend import MatmulBackend

            backend = MatmulBackend(kind="auto", depth=2, min_dim=1024)
        # Apply the backend's process-level knobs (XLA latency-hiding /
        # async-collective flags) once, here — not per leaf call site.
        if hasattr(backend, "configure"):
            backend.configure()
        self.backend = backend

    # ------------------------------------------------------------ internals
    @staticmethod
    def _node_tag(op: str, path: Tuple[int, ...]) -> str:
        return f"{op}:{tags.to_string(path)}"

    def _node(
        self,
        store: BlockStore,
        op: str,
        path: Tuple[int, ...],
        root_shape: Tuple[int, int],
        block_shape: Tuple[int, int],
        dtype,
    ) -> BlockMatrix:
        level = len(path)
        shape = (root_shape[0] >> level, root_shape[1] >> level)
        return BlockMatrix(store, shape, block_shape, dtype, self._node_tag(op, path))

    @staticmethod
    def _signed_sum(get_block, coefs: np.ndarray, acc_dtype) -> np.ndarray:
        """Delegates to :func:`repro.blocks.blockmatrix.signed_block_sum`.

        Shared with lineage recompute (:mod:`repro.blocks.recovery`):
        recovery is bit-exact precisely because both run the same loop.
        """
        return signed_block_sum(get_block, coefs, acc_dtype)

    def _retry_leaf(self, fn, stats: "OotStats", mx):
        """Run one leaf multiply with bounded retry + exponential backoff.

        Only fault-typed failures (:class:`FaultError` — the chaos shim,
        a flaky backend) retry, up to ``self.retries`` times. Device-OOM
        raises immediately — re-issuing the identical allocation cannot
        succeed, only the degradation ladder (smaller waves / deeper
        recursion) can. Unknown exceptions also propagate untouched:
        retrying a genuine bug would mask it and burn the backoff budget.
        """
        from repro.core.backend import is_oom_error

        delay = self.retry_backoff_s
        for attempt in range(self.retries + 1):
            try:
                return fn()
            except Exception as e:
                if (
                    is_oom_error(e)
                    or not isinstance(e, FaultError)
                    or attempt >= self.retries
                ):
                    raise
                stats.leaf_retries += 1
                mx.counter("fault.retries").inc()
                mx.counter(f"fault.retries.{self.plan.op}").inc()
                if delay > 0:
                    time.sleep(delay)
                delay = min(delay * 2, 2.0)

    def _divide_child(
        self,
        parent: BlockMatrix,
        child: BlockMatrix,
        coef_row: np.ndarray,
        acc_dtype,
    ) -> None:
        """child = sum_q coef_row[q] * quadrant_q(parent), block-streamed."""
        gr, gc = child.grid
        for i in range(gr):
            for j in range(gc):
                acc = self._signed_sum(
                    lambda q: parent.block((q // 2) * gr + i, (q % 2) * gc + j),
                    coef_row, acc_dtype,
                )
                child.put_block(i, j, acc.astype(child.dtype))

    def _combine_parent(
        self,
        children: Sequence[BlockMatrix],
        parent: BlockMatrix,
        acc_dtype,
    ) -> None:
        """parent quadrants = sum_p c_coef[k, p] * child_p, block-streamed."""
        gr, gc = children[0].grid
        c_coef = self.plan.combine_coef
        for kq in range(tags.Q_BASE):
            for i in range(gr):
                for j in range(gc):
                    acc = self._signed_sum(
                        lambda p: children[p].block(i, j), c_coef[kq], acc_dtype
                    )
                    parent.put_block(
                        (kq // 2) * gr + i, (kq % 2) * gc + j, acc.astype(parent.dtype)
                    )

    def _leaf_matmul(self, a_dev, b_dev):
        from repro.core import backend as _backend

        return _backend.matmul(a_dev, b_dev, self.backend, site="blocks.leaf")

    # -------------------------------------------------------------- the run
    def matmul(
        self,
        a: np.ndarray,
        b: np.ndarray,
        *,
        store: str | BlockStore = "dict",
        store_root: Optional[str] = None,
    ) -> Tuple[np.ndarray, OotStats]:
        """``a @ b`` with device memory bounded by the budget.

        ``a``/``b`` are host arrays (numpy or anything ``np.asarray``
        accepts, bfloat16 included). ``store`` picks the block residency:
        'dict' | 'arena' | 'memmap' or a ready :class:`BlockStore`.

        Runs the graceful-degradation ladder: the configured mode first,
        then — on an unrecovered fault (retries exhausted, lineage
        recompute impossible) or device-OOM — synchronous staging, a
        halved wave, and finally one level deeper recursion. Every rung
        transition is a ``fault.degrade`` counter + instant span; the
        returned stats carry the completed rung and the transition log.
        Anything that is not a fault/OOM propagates unchanged from the
        first attempt.
        """
        from repro.core.backend import is_oom_error

        # One flaky-leaf shim across the whole ladder: its dispatch-call
        # counter spans attempts, so "fail the Nth leaf multiply" windows
        # pass and the ladder can make progress.
        flaky = None
        if self.chaos is not None and self.chaos.injects_leaf_faults:
            flaky = FlakyLeaf(
                fail_calls=self.chaos.fail_leaf_calls,
                fail_rate=self.chaos.leaf_fail_rate,
                seed=self.chaos.seed + 1,
            )
        rungs: List[Tuple[str, dict]] = []
        if self.prefetch:
            rungs.append(
                ("pipeline", dict(prefetch=True, wave_scale=1.0, depth=self.depth))
            )
        rungs.append(("sync", dict(prefetch=False, wave_scale=1.0, depth=self.depth)))
        rungs.append(
            ("halved-wave", dict(prefetch=False, wave_scale=0.5, depth=self.depth))
        )
        rungs.append(
            ("deeper", dict(prefetch=False, wave_scale=0.5, depth=self.depth + 1))
        )
        if not self.degrade:
            rungs = rungs[:1]
        tr = obs_tracer.get_tracer()
        mx = obs_metrics.get_metrics()
        degrade_log: List[dict] = []
        for idx, (name, overrides) in enumerate(rungs):
            try:
                result, stats = self._attempt(
                    a, b, store=store, store_root=store_root, flaky=flaky,
                    **overrides,
                )
            except Exception as e:
                recoverable = isinstance(e, FaultError) or is_oom_error(e)
                if idx == len(rungs) - 1 or not recoverable:
                    raise
                nxt = rungs[idx + 1][0]
                mx.counter("fault.degrade").inc()
                mx.counter(f"fault.degrade.{self.plan.op}").inc()
                tr.event(
                    "fault.degrade", cat="fault", op=self.plan.op,
                    rung_from=name, rung_to=nxt, cause=type(e).__name__,
                )
                degrade_log.append(
                    {"from": name, "to": nxt, "cause": f"{type(e).__name__}: {e}"[:200]}
                )
                continue
            stats.rung = name
            stats.degrades = len(degrade_log)
            stats.degrade_events = degrade_log
            _record_run(stats)
            return result, stats
        raise AssertionError("degradation ladder must return or raise")

    def _attempt(
        self,
        a: np.ndarray,
        b: np.ndarray,
        *,
        store: str | BlockStore,
        store_root: Optional[str],
        depth: int,
        prefetch: bool,
        wave_scale: float,
        flaky: Optional[FlakyLeaf],
    ) -> Tuple[np.ndarray, OotStats]:
        """One run of the level-order executor at a fixed ladder rung."""
        import jax

        # Spans are the run's single timing source: OotStats (wave_events,
        # phase splits, overlap_efficiency) is DERIVED from them after the
        # fact. When the process tracer is exporting, the spans land there
        # (the trace renders the recursion tree, tag-addressed); otherwise
        # a throwaway run-local tracer carries them just far enough to
        # populate the stats.
        tr = obs_tracer.get_tracer()
        if not tr.enabled:
            tr = obs_tracer.Tracer(enabled=True)
        mx = obs_metrics.get_metrics()
        a = np.asarray(a)
        b = np.asarray(b)
        if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
            raise ValueError(f"bad matmul shapes {a.shape} @ {b.shape}")
        dtype = np.result_type(a.dtype, b.dtype)
        acc_dtype = np.result_type(dtype, np.float32)
        m, k = a.shape
        n = b.shape[1]
        rank = self.plan.rank
        # Tag prefixes come from the plan ("A"/"B"/"C" for matmul plans,
        # so lineage keys and traces are unchanged from the pre-plan era).
        a_name, b_name = self.plan.operands
        c_name = self.plan.result
        a_rows = self.plan.divide_coef[a_name]
        b_rows = self.plan.divide_coef[b_name]

        # Recursion-aligned padded dims and the block partition. With an
        # explicit block grain each leaf dim rounds up to a whole number of
        # blocks so every level's grid halves exactly.
        lm, lk, ln = _leaf_dims(m, k, n, depth)
        if self.block is not None:
            bam = min(self.block, lm)
            bak = min(self.block, lk)
            bbn = min(self.block, ln)
            lm, lk, ln = (
                _ceil_div(lm, bam) * bam,
                _ceil_div(lk, bak) * bak,
                _ceil_div(ln, bbn) * bbn,
            )
        else:
            bam, bak, bbn = lm, lk, ln
        pm, pk, pn = lm << depth, lk << depth, ln << depth

        stage_dtype = (
            np.dtype(self.stage_dtype) if self.stage_dtype is not None else acc_dtype
        )
        itemsize = stage_dtype.itemsize
        in_bytes = (lm * lk + lk * ln) * itemsize
        out_bytes = lm * ln * itemsize
        per_leaf = in_bytes + out_bytes
        # Pipelined wave slot: the 2-deep pipeline keeps, per leaf slot, the
        # current wave's full working set (A + B + C) plus its in-flight
        # neighbours — the previous wave's WHOLE working set (its products
        # are not yet fetched and its operands stay pinned by the unfenced
        # executions until drain's D2H fence) and the next wave's
        # prefetched operands (A + B) — concurrently resident, i.e.
        # 2 * per_leaf + in_bytes (pipelined_leaf_bytes). Sizing waves at
        # that slot makes the budget bound hold at the *pipelined* peak,
        # not just the quiescent single-wave state.
        wave_size = self.budget_bytes // (2 * per_leaf + in_bytes) if prefetch else 0
        if wave_size < 1:
            prefetch = False
            wave_size = self.budget_bytes // per_leaf
        if wave_size < 1:
            raise ValueError(
                f"device budget {self.budget_bytes} B cannot hold one "
                f"{lm}x{lk}x{ln} {np.dtype(dtype).name} leaf ({per_leaf} B); "
                f"use depth >= "
                f"{min_depth_for_budget(m, k, n, self.budget_bytes, dtype)}"
            )
        if wave_scale != 1.0:
            # Degradation rung: shrink waves below what the budget allows
            # (never below one leaf — single-leaf feasibility was checked
            # above, so this only trades throughput for headroom).
            wave_size = max(1, int(wave_size * wave_scale))

        # Divide/combine chains accumulate (and store) in acc_dtype; blocks
        # round at most once — operands at the staging cast, C at the final
        # cast. One rounding per value instead of one per level is the same
        # discipline as the fused kernel's fp32 MXU accumulation, and what
        # keeps depth>=2 bf16 parity inside 1e-2. Leaf compute and H2D/D2H
        # volume run at ``stage_dtype`` — the accumulation dtype by default
        # (2x the compute-dtype bytes for bf16 inputs), narrowed to the
        # compute dtype via the ``stage_dtype`` knob.
        acc_item = np.dtype(acc_dtype).itemsize
        slot_bytes = max(bam * bak, bak * bbn, bam * bbn) * acc_item
        # Stores built here from a spec are owned (and closed) here;
        # caller-provided BlockStore instances stay open for inspection —
        # and may be shared across runs, so this run's puts are tracked
        # and the failure path deletes only those. Layering, bottom up:
        # base store -> run tracking -> chaos injection (faults must hit
        # the raw bytes) -> recovering wrapper (checksums + lineage
        # recompute sit ABOVE the injector, so injected faults are
        # detected and healed like real ones).
        owned_store = not isinstance(store, BlockStore)
        base = make_store(store, slot_bytes=slot_bytes, root=store_root)
        inner: BlockStore = base
        tracking: Optional[_RunTrackingStore] = None
        if not owned_store:
            tracking = _RunTrackingStore(inner)
            inner = tracking
        chaos_store: Optional[ChaosStore] = None
        if self.chaos is not None and self.chaos.injects_store_faults:
            chaos_store = ChaosStore(
                inner,
                drop=self.chaos.drop,
                corrupt=self.chaos.corrupt,
                seed=self.chaos.seed,
            )
            inner = chaos_store
        recovering: Optional[RecoveringStore] = None
        if self.recovery:

            def lineage_leaf(a_host: np.ndarray, b_host: np.ndarray) -> np.ndarray:
                # Replays one leaf through the same device path the waves
                # use (device_put -> routed leaf matmul -> fenced fetch),
                # so a recomputed leaf product is bit-identical. Runs only
                # while the device is otherwise idle (divide/combine), so
                # one leaf's working set — already <= the budget — is the
                # whole recovery footprint.
                a_dev = jax.device_put(a_host)
                b_dev = jax.device_put(b_host)
                return np.asarray(jax.block_until_ready(self._leaf_matmul(a_dev, b_dev)))

            lineage = Lineage(
                scheme=self.scheme, plan=self.plan, depth=depth, a=a, b=b,
                pm=pm, pk=pk, pn=pn, bam=bam, bak=bak, bbn=bbn,
                acc_dtype=np.dtype(acc_dtype), stage_dtype=stage_dtype,
                leaf_matmul=lineage_leaf,
            )
            recovering = RecoveringStore(inner, lineage)
            inner = recovering
        store = inner
        root_span = tr.begin(
            f"oot.{self.plan.op}", cat="oot", op=self.plan.op,
            m=m, k=k, n=n, depth=depth, scheme=self.scheme.name,
            budget_bytes=self.budget_bytes,
        )
        t_start = root_span.t0
        # Device arrays in flight per wave index — defined out here so the
        # failure path below can release them even when the exception's
        # traceback keeps the frame (and so these references) alive.
        in_flight: dict = {}
        try:

            leaves = rank**depth
            stats = OotStats(
                m=m, k=k, n=n, depth=depth, scheme=self.scheme.name,
                op=self.plan.op,
                leaves=leaves, waves=0, wave_size=wave_size, prefetch=prefetch,
                stage_dtype=stage_dtype.name,
                budget_bytes=self.budget_bytes, per_leaf_bytes=per_leaf,
                peak_device_bytes=0,
            )

            # --- ingest roots (edge/odd dims zero-extend to the padded grain).
            a_root = BlockMatrix.from_dense(
                a, (bam, bak), store, self._node_tag(a_name, ()), shape=(pm, pk)
            )
            b_root = BlockMatrix.from_dense(
                b, (bak, bbn), store, self._node_tag(b_name, ()), shape=(pk, pn)
            )

            # --- divide: level-order, all rank^level nodes per level. One
            # span per level, one tag-addressed span per tree node — the
            # exported trace's top lane reads as the recursion tree itself.
            div_span = tr.begin("oot.divide", cat="oot")
            for level in range(depth):
                p_dtype = dtype if level == 0 else acc_dtype
                with tr.span(
                    f"divide.L{level + 1}", cat="oot",
                    level=level + 1, nodes=rank ** (level + 1),
                ):
                    for path in tags.leaf_paths(level, rank):
                        with tr.span(
                            "divide.node", cat="oot",
                            tag=tags.to_string(path), level=level,
                        ):
                            pa = self._node(
                                store, a_name, path, (pm, pk), (bam, bak), p_dtype
                            )
                            pb = self._node(
                                store, b_name, path, (pk, pn), (bak, bbn), p_dtype
                            )
                            for p in range(rank):
                                ca = self._node(
                                    store, a_name, tags.child(path, p, rank),
                                    (pm, pk), (bam, bak), acc_dtype,
                                )
                                cb = self._node(
                                    store, b_name, tags.child(path, p, rank),
                                    (pk, pn), (bak, bbn), acc_dtype,
                                )
                                self._divide_child(pa, ca, a_rows[p], acc_dtype)
                                self._divide_child(pb, cb, b_rows[p], acc_dtype)
                    stats.host_store_peak_bytes = max(
                        stats.host_store_peak_bytes, store.nbytes()
                    )
                    # Parents are consumed: only the leaf level feeds the
                    # multiply. Freed via the node's own key iteration
                    # (O(blocks-of-node)), not delete_tag's full-store scan.
                    for path in tags.leaf_paths(level, rank):
                        self._node(
                            store, a_name, path, (pm, pk), (bam, bak), p_dtype
                        ).free()
                        self._node(
                            store, b_name, path, (pk, pn), (bak, bbn), p_dtype
                        ).free()
            tr.end(div_span)
            stats.divide_s = div_span.duration
            stats.host_store_peak_bytes = max(stats.host_store_peak_bytes, store.nbytes())

            # --- leaf waves: a 2-deep async pipeline over stage -> dispatch
            # -> fetch. Iteration k issues wave k's leaf multiplies (async
            # JAX dispatch), stages wave k+1's operands (async device_put)
            # while wave k computes, and only THEN drains wave k-1 — so the
            # pipeline's one blocking fence (block_until_ready at D2H)
            # overlaps the in-flight compute instead of serializing behind
            # it. Fetched product buffers are released the moment their
            # bytes land on host (donated into the host-side combine
            # accumulation), keeping the device peak at the budgeted
            # pipelined slot.
            leaf_span = tr.begin(
                "oot.leaf_waves", cat="oot",
                waves=_ceil_div(leaves, wave_size), wave_size=wave_size,
                prefetch=prefetch,
            )
            leaf_list = list(tags.leaf_paths(depth, rank))
            waves: List[List[Tuple[int, ...]]] = [
                leaf_list[i : i + wave_size] for i in range(0, leaves, wave_size)
            ]
            # Per-wave phase spans, recorded on dedicated tracks so the
            # exported trace shows the pipeline's overlap as concurrent
            # lanes: wave k+1's "wave.stage" sits strictly inside wave k's
            # "wave.compute" (dispatch issue -> D2H fence) when prefetch is
            # on. OotStats.wave_events is derived from these spans after
            # the loop — the spans ARE the record, nothing is hand-stamped.
            wave_spans: Dict[int, Dict[str, obs_tracer.Span]] = {}

            def stage(w_idx: int):
                wsp = tr.begin(
                    "wave.stage", cat="oot", track="oot.stage",
                    wave=w_idx, size=len(waves[w_idx]),
                )
                staged = []
                refs = in_flight.setdefault(w_idx, [])
                for path in waves[w_idx]:
                    with tr.span(
                        "leaf.stage", cat="oot", tag=tags.to_string(path),
                        track="oot.stage", wave=w_idx, h2d_bytes=in_bytes,
                    ):
                        na = self._node(
                            store, a_name, path, (pm, pk), (bam, bak), acc_dtype
                        )
                        nb = self._node(
                            store, b_name, path, (pk, pn), (bak, bbn), acc_dtype
                        )
                        # Any rounding to a narrower staging dtype happens
                        # here, at the host->device boundary — never mid-chain.
                        a_dev = jax.device_put(
                            na.to_dense().astype(stage_dtype, copy=False)
                        )
                        b_dev = jax.device_put(
                            nb.to_dense().astype(stage_dtype, copy=False)
                        )
                    refs.extend((a_dev, b_dev))
                    staged.append((path, a_dev, b_dev))
                    stats.h2d_bytes += in_bytes
                tr.end(wsp)
                wave_spans.setdefault(w_idx, {})["stage"] = wsp
                mx.counter("oot.h2d_bytes").inc(len(waves[w_idx]) * in_bytes)
                mx.histogram("oot.wave_stage_s").record(wsp.duration)
                return staged

            def dispatch(w_idx: int, staged):
                wsp = tr.begin(
                    "wave.dispatch", cat="oot", track="oot.dispatch", wave=w_idx
                )
                refs = in_flight[w_idx]
                outs = []
                for path, a_dev, b_dev in staged:
                    with tr.span(
                        "leaf.mul", cat="oot", tag=tags.to_string(path),
                        track="oot.dispatch", wave=w_idx,
                    ):

                        def call(a_dev=a_dev, b_dev=b_dev):
                            # The chaos shim fails the dispatch the way a
                            # flaky backend would — before issue, so a
                            # retry is a genuinely fresh dispatch.
                            if flaky is not None:
                                flaky.check()
                            return self._leaf_matmul(a_dev, b_dev)

                        out = self._retry_leaf(call, stats, mx)
                    refs.append(out)
                    outs.append((path, out))
                # Multiplies issued: drop this wave's operand refs (XLA
                # keeps the input buffers alive for the in-flight
                # executions) so they free the moment the leaves complete
                # instead of surviving until drain. Only on success —
                # a failing leaf leaves the full ref list for the
                # failure-path release below.
                in_flight[w_idx] = [out for _, out in outs]
                tr.end(wsp)
                wave_spans.setdefault(w_idx, {})["dispatch"] = wsp
                return outs

            def drain(w_idx: int, outs):
                wsp = tr.begin(
                    "wave.fetch", cat="oot", track="oot.fetch", wave=w_idx
                )
                wave_d2h = 0
                for path, out in outs:
                    with tr.span(
                        "leaf.fetch", cat="oot", tag=tags.to_string(path),
                        track="oot.fetch", wave=w_idx,
                    ) as lsp:
                        try:
                            out = jax.block_until_ready(out)  # the only fence
                            host = np.asarray(out)
                        except Exception as fence_exc:
                            from repro.core.backend import is_oom_error

                            if is_oom_error(fence_exc) or not isinstance(
                                fence_exc, FaultError
                            ):
                                # OOM goes to the ladder; unknown errors
                                # propagate (same policy as _retry_leaf).
                                raise
                            # A fault-typed async failure surfaced at the
                            # fence. Drop the dead buffer, then replay this
                            # one leaf synchronously from the host blocks
                            # (still in the store until free() below) —
                            # reaching here already cost one attempt, so it
                            # counts as a retry before the bounded loop.
                            try:
                                out.delete()
                            except Exception:
                                pass
                            stats.leaf_retries += 1
                            mx.counter("fault.retries").inc()
                            mx.counter(f"fault.retries.{self.plan.op}").inc()

                            def redo(path=path):
                                if flaky is not None:
                                    flaky.check()
                                na = self._node(
                                    store, a_name, path,
                                    (pm, pk), (bam, bak), acc_dtype,
                                )
                                nb = self._node(
                                    store, b_name, path,
                                    (pk, pn), (bak, bbn), acc_dtype,
                                )
                                a_dev = jax.device_put(
                                    na.to_dense().astype(stage_dtype, copy=False)
                                )
                                b_dev = jax.device_put(
                                    nb.to_dense().astype(stage_dtype, copy=False)
                                )
                                return np.asarray(
                                    jax.block_until_ready(
                                        self._leaf_matmul(a_dev, b_dev)
                                    )
                                )

                            host = self._retry_leaf(redo, stats, mx)
                        stats.d2h_bytes += host.nbytes
                        wave_d2h += host.nbytes
                        lsp.set(d2h_bytes=host.nbytes)
                        host = host.astype(acc_dtype, copy=False)
                        cn = self._node(
                            store, c_name, path, (pm, pn), (bam, bbn), acc_dtype
                        )
                        for i in range(cn.grid[0]):
                            for j in range(cn.grid[1]):
                                cn.put_block(
                                    i, j,
                                    host[
                                        i * bam : (i + 1) * bam,
                                        j * bbn : (j + 1) * bbn,
                                    ],
                                )
                        self._node(
                            store, a_name, path, (pm, pk), (bam, bak), acc_dtype
                        ).free()
                        self._node(
                            store, b_name, path, (pk, pn), (bak, bbn), acc_dtype
                        ).free()
                # Drop the wave's device references (operands were consumed
                # by the leaf multiplies; products are now on host) so the
                # buffers free without waiting for this host loop or GC.
                in_flight.pop(w_idx, None)
                tr.end(wsp)
                ws = wave_spans.setdefault(w_idx, {})
                ws["fetch"] = wsp
                # In-flight window: multiply issue -> D2H fence completion.
                # Parity lanes keep consecutive (genuinely overlapping)
                # windows from sharing a track, which Chrome renders badly.
                if "dispatch" in ws:
                    tr.add_span(
                        "wave.compute", ws["dispatch"].t1, wsp.t1, cat="oot",
                        track=f"oot.compute/{w_idx % 2}", parent=leaf_span,
                        wave=w_idx, size=len(waves[w_idx]),
                    )
                mx.counter("oot.d2h_bytes").inc(wave_d2h)
                mx.histogram("oot.wave_fetch_s").record(wsp.duration)
                stats.waves += 1
                stats.host_store_peak_bytes = max(
                    stats.host_store_peak_bytes, store.nbytes()
                )

            pending: Optional[Tuple[int, list]] = None
            staged = stage(0) if (prefetch and waves) else None
            for w_idx, wave in enumerate(waves):
                current, staged = staged, None
                if current is None:  # prefetch off: stage synchronously
                    current = stage(w_idx)
                outs = dispatch(w_idx, current)
                current = None
                # Modeled concurrent peak this iteration: wave k's working
                # set + the previous wave's whole working set (un-fetched
                # products, plus operands the in-flight executions may
                # still pin) + the next wave's prefetched operands —
                # matching the wave sizing above, so the budget bounds
                # actual residency.
                device_now = len(wave) * per_leaf
                if pending is not None:
                    device_now += len(pending[1]) * per_leaf
                if prefetch and w_idx + 1 < len(waves):
                    device_now += len(waves[w_idx + 1]) * in_bytes
                stats.peak_device_bytes = max(stats.peak_device_bytes, device_now)
                if prefetch and w_idx + 1 < len(waves):
                    # Stage the next wave's H2D while this wave's multiplies
                    # run behind JAX's async dispatch — the staging calls'
                    # host-side overhead executes on this thread while XLA's
                    # worker pool computes wave k.
                    staged = stage(w_idx + 1)
                if pending is not None:
                    # D2H fence for wave k-1 while wave k is still in flight.
                    drain(*pending)
                    pending = None
                if prefetch:
                    pending = (w_idx, outs)
                else:
                    drain(w_idx, outs)
                outs = None
            if pending is not None:
                drain(*pending)
            tr.end(leaf_span)
            stats.leaf_s = leaf_span.duration
            # Wave telemetry is DERIVED from the recorded spans (public
            # shape unchanged: seconds since run start). finalize_overlap()
            # below then reads these exactly as before the span rewire.
            stats.wave_events = [
                {
                    "wave": i,
                    "size": len(waves[i]),
                    "issue_start": ws["stage"].t0 - t_start,
                    "issue_end": ws["stage"].t1 - t_start,
                    "dispatch_end": ws["dispatch"].t1 - t_start,
                    "fetch_start": ws["fetch"].t0 - t_start,
                    "fetch_end": ws["fetch"].t1 - t_start,
                }
                for i, ws in sorted(wave_spans.items())
            ]
            stats.stage_s = sum(ws["stage"].duration for ws in wave_spans.values())
            stats.fetch_s = sum(ws["fetch"].duration for ws in wave_spans.values())

            # --- combine: level-order bottom-up, freeing children as we go.
            comb_span = tr.begin("oot.combine", cat="oot")
            for level in reversed(range(depth)):
                with tr.span(
                    f"combine.L{level + 1}", cat="oot",
                    level=level + 1, nodes=rank**level,
                ):
                    for path in tags.leaf_paths(level, rank):
                        with tr.span(
                            "combine.node", cat="oot",
                            tag=tags.to_string(path), level=level,
                        ):
                            children = [
                                self._node(
                                    store, c_name, tags.child(path, p, rank),
                                    (pm, pn), (bam, bbn), acc_dtype,
                                )
                                for p in range(rank)
                            ]
                            parent = self._node(
                                store, c_name, path, (pm, pn), (bam, bbn), acc_dtype
                            )
                            self._combine_parent(children, parent, acc_dtype)
                            for child in children:
                                child.free()
                    stats.host_store_peak_bytes = max(
                        stats.host_store_peak_bytes, store.nbytes()
                    )
            tr.end(comb_span)
            stats.combine_s = comb_span.duration

            c_root = self._node(store, c_name, (), (pm, pn), (bam, bbn), acc_dtype)
            result = c_root.to_dense()[:m, :n].astype(dtype, copy=False)
            a_root.free()
            b_root.free()
            c_root.free()
        except BaseException:
            # A failing leaf matmul (or store error) mid-pipeline must not
            # leak the run's artifacts. Release the in-flight device
            # buffers eagerly — the raised exception's traceback pins this
            # frame, so dropping the dict alone would keep them alive as
            # long as the caller holds the exception — and, for
            # caller-provided stores the finally below will NOT close,
            # drop exactly the blocks this run put (tracked per key:
            # tags are not run-scoped, and a shared store may hold other
            # runs' blocks under the same "A:"/"B:"/"C:" tag space).
            for refs in in_flight.values():
                for buf in refs:
                    try:
                        buf.delete()
                    except Exception:
                        pass
            in_flight.clear()
            if tracking is not None:
                tracking.drop_created()
            # Close the root span (end() pops any children the unwind left
            # open) so the tracer's per-thread stack stays consistent for
            # whatever the caller runs next.
            tr.end(root_span, failed=True)
            raise
        finally:
            if owned_store:
                base.close()
        # Fault telemetry: what the wrappers detected/healed this attempt
        # (retries were counted in place; injected counts are cumulative
        # for the flaky shim, whose call counter spans ladder rungs).
        if recovering is not None:
            stats.recovered_blocks = recovering.recovered_blocks
            stats.lost_blocks = recovering.lost_blocks
            stats.corrupt_blocks = recovering.corrupt_blocks
            stats.unrecovered_faults = recovering.recompute_mismatches
        if chaos_store is not None:
            stats.injected_faults += (
                chaos_store.injected_drops + chaos_store.injected_corruptions
            )
        if flaky is not None:
            stats.injected_faults += flaky.injected
        stats.total_s = tr.end(root_span).duration
        stats.finalize_overlap()
        root_span.set(
            overlap_efficiency=stats.overlap_efficiency,
            peak_device_bytes=stats.peak_device_bytes,
            h2d_bytes=stats.h2d_bytes,
            d2h_bytes=stats.d2h_bytes,
        )
        return result, stats


# The historical name: Strassen is now simply the first registered plan
# this executor walks. Kept as the public spelling used across the repo.
StrassenScheduler = PlanScheduler


def strassen_oot_matmul(
    a: np.ndarray,
    b: np.ndarray,
    *,
    depth: int,
    budget_bytes: int,
    scheme: Scheme | str = "strassen",
    plan: "BilinearPlan | str | None" = None,
    backend=None,
    block: Optional[int] = None,
    prefetch: bool = True,
    stage_dtype=None,
    store: str | BlockStore = "dict",
    store_root: Optional[str] = None,
    chaos: Optional[ChaosConfig] = None,
    recovery: Optional[bool] = None,
    retries: int = 2,
    retry_backoff_s: float = 0.05,
    degrade: bool = True,
) -> Tuple[np.ndarray, OotStats]:
    """Functional wrapper: one out-of-core Strassen multiply.

    See :class:`StrassenScheduler` for the parameters; this is the entry
    point :mod:`repro.core.backend` (kind='strassen_oot'), the autotuner's
    ``strassen_oot`` candidate family, ``launch/blocks_demo.py``, and
    ``benchmarks/fig8_scaling.py`` share.
    """
    sched = PlanScheduler(
        depth=depth, budget_bytes=budget_bytes, scheme=scheme, plan=plan,
        backend=backend, block=block, prefetch=prefetch, stage_dtype=stage_dtype,
        chaos=chaos, recovery=recovery, retries=retries,
        retry_backoff_s=retry_backoff_s, degrade=degrade,
    )
    return sched.matmul(a, b, store=store, store_root=store_root)

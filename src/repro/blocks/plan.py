"""Declarative recursive plans: the operator-agnostic layer between tags
and the scheduler.

Stark's recursion-tree-of-tagged-blocks machinery (PAPER.md) is not
specific to Strassen's 7-multiply scheme — the same authors proved it
with SPIN (arxiv 1801.04723), which runs block-recursive matrix
*inversion* over the identical divide/combine stages. This module makes
that generality explicit: a :class:`RecursivePlan` *describes* a
recursive block computation — its divide schema (which tagged sub-blocks
each child needs, with signed coefficients), its leaf op, and its
combine schema — and the executors walk the description instead of
hard-coding an operator:

* :class:`BilinearPlan` — one bilinear (two-operand) recursion whose
  children are all independent: exactly the shape the level-order wave
  scheduler (:mod:`repro.blocks.scheduler`) executes. The Strassen
  base-7 and naive base-4 multiplies are the first two plans, wrapping
  the coefficient tables of :mod:`repro.core.coefficients` unchanged —
  so the refactor is bit-identical by construction (pinned by tests).
* :class:`DataflowPlan` — a sequential per-node step program whose
  recursions and block multiplies *depend on each other* (SPIN's
  Schur-complement inversion, triangular solves). Executed by
  :mod:`repro.blocks.solve`; every ``matmul`` step re-enters the matmul
  scheduler (``kind="auto"`` on device, ``strassen_oot`` when the
  product exceeds the device budget).

The tag algebra (tensor-product expansion of the per-level coefficient
rows) lives here now; :mod:`repro.blocks.tags` keeps thin delegating
wrappers for its historical ``operand_terms``/``combine_terms`` API.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.core.coefficients import Scheme, get_scheme

__all__ = [
    "Q_BASE",
    "Term",
    "expand_terms",
    "apply_divide_schema",
    "apply_combine_schema",
    "RecursivePlan",
    "BilinearPlan",
    "Step",
    "DataflowPlan",
    "matmul_plan",
    "register_plan",
    "get_plan",
    "as_bilinear_plan",
    "plan_names",
    "SPIN_INVERSE",
    "TRSM_LOWER",
    "TRSM_UPPER",
]

Q_BASE = 4  # quadrant alphabet, row-major [11, 12, 21, 22]

TagPath = Tuple[int, ...]
Term = Tuple[TagPath, float]


def expand_terms(m_path: TagPath, coef: np.ndarray, q_base: int = Q_BASE) -> List[Term]:
    """Tensor-product expansion of one coefficient table down a tag path.

    ``coef`` is a (rank, q_base) table; digit ``d`` of ``m_path`` selects
    row ``coef[d]`` at that level and the expansion multiplies the rows
    out into (quadrant path, coefficient) terms — the closed form of
    running a divide (or transposed combine) stage ``len(m_path)`` times.
    """
    terms: List[Term] = [((), 1.0)]
    for digit in m_path:
        nxt: List[Term] = []
        for q_path, c in terms:
            for q in range(q_base):
                cq = float(coef[digit, q])
                if cq != 0.0:
                    nxt.append((q_path + (q,), c * cq))
        terms = nxt
    return terms


def _quadrants(dense: np.ndarray) -> List[np.ndarray]:
    """Row-major 2x2 quadrant views [X11, X12, X21, X22] of a dense array."""
    r, c = dense.shape
    hr, hc = r // 2, c // 2
    return [
        dense[:hr, :hc], dense[:hr, hc:], dense[hr:, :hc], dense[hr:, hc:],
    ]


def apply_divide_schema(
    dense: np.ndarray, coef: np.ndarray, acc_dtype=None
) -> List[np.ndarray]:
    """Apply one divide schema level: child_p = sum_q coef[p, q] * quadrant_q.

    The reference (all-in-memory) semantics of the scheduler's
    block-streamed ``_divide_child`` loop; property tests round-trip
    arbitrary well-formed schemas through this and
    :func:`apply_combine_schema`.
    """
    acc_dtype = np.dtype(acc_dtype) if acc_dtype is not None else dense.dtype
    quads = _quadrants(np.asarray(dense))
    out = []
    for p in range(coef.shape[0]):
        acc = np.zeros(quads[0].shape, acc_dtype)
        for q in range(Q_BASE):
            cq = float(coef[p, q])
            if cq == 1.0:
                acc += quads[q].astype(acc_dtype, copy=False)
            elif cq == -1.0:
                acc -= quads[q].astype(acc_dtype, copy=False)
            elif cq != 0.0:
                acc += cq * quads[q].astype(acc_dtype, copy=False)
        out.append(acc)
    return out


def apply_combine_schema(
    children: Sequence[np.ndarray], coef: np.ndarray, acc_dtype=None
) -> np.ndarray:
    """Apply one combine schema level: quadrant_k = sum_p coef[k, p] * child_p.

    Inverse of :func:`apply_divide_schema` whenever ``coef`` is a left
    inverse of the divide table (``coef @ divide == I``) — the algebraic
    well-formedness condition the plan property tests exercise.
    """
    acc_dtype = np.dtype(acc_dtype) if acc_dtype is not None else children[0].dtype
    hr, hc = children[0].shape
    dense = np.zeros((2 * hr, 2 * hc), acc_dtype)
    quads = _quadrants(dense)
    for k in range(Q_BASE):
        acc = np.zeros((hr, hc), acc_dtype)
        for p in range(len(children)):
            cp = float(coef[k, p])
            if cp == 1.0:
                acc += children[p].astype(acc_dtype, copy=False)
            elif cp == -1.0:
                acc -= children[p].astype(acc_dtype, copy=False)
            elif cp != 0.0:
                acc += cp * children[p].astype(acc_dtype, copy=False)
        quads[k][...] = acc
    return dense


@dataclasses.dataclass(frozen=True)
class RecursivePlan:
    """Metadata every recursive plan shares.

    Attributes:
      name: registry name (``get_plan(name)``).
      op: the operator the plan computes — ``"matmul"``, ``"inverse"``,
        ``"solve"``. Threaded through the executors into the obs layer:
        root spans are ``oot.{op}`` and ``OotStats.op``/``fault.*.{op}``
        counters attribute telemetry to the right operator.
      operands: input names, in call order (``("A", "B")`` for matmul,
        ``("A",)`` for inversion, ``("L", "B")`` for a solve). Operand
        names prefix block tags (``"A:3,0"``) and key the lineage graph.
      result: output name (tag prefix of the result's node tree).
      leaf_kind: the dense op dispatched at the recursion floor —
        ``"matmul"`` through :func:`repro.core.backend.matmul`, or a
        small dense ``"inv"`` / ``"trsm_lower"`` / ``"trsm_upper"``.
    """

    name: str
    op: str
    operands: Tuple[str, ...]
    result: str
    leaf_kind: str


@dataclasses.dataclass(frozen=True)
class BilinearPlan(RecursivePlan):
    """A wave-schedulable bilinear recursion described by coefficient tables.

    ``divide_coef`` maps each operand name to its (rank, 4) table: child
    ``p`` of an operand node is ``sum_q coef[p, q] * quadrant_q`` — the
    divide schema. ``combine_coef`` is the (4, rank) combine schema:
    result quadrant ``k`` is ``sum_p coef[k, p] * child_p``. All rank
    children are mutually independent, which is what lets the scheduler
    batch the ``rank**depth`` leaves into budgeted device waves.

    ``scheme`` retains the source coefficient scheme so telemetry,
    autotune cache keys, and lineage records keep their historical
    names; the tables above are *the same arrays* (not copies), making
    the plan extraction bit-identical to the pre-plan scheduler.
    """

    scheme: Scheme = None  # type: ignore[assignment]
    divide_coef: Mapping[str, np.ndarray] = None  # type: ignore[assignment]
    combine_coef: np.ndarray = None  # type: ignore[assignment]

    @property
    def rank(self) -> int:
        return int(self.combine_coef.shape[1])

    def validate(self) -> None:
        rank = self.rank
        if tuple(sorted(self.divide_coef)) != tuple(sorted(self.operands)):
            raise ValueError(
                f"plan {self.name!r}: divide_coef keys {sorted(self.divide_coef)} "
                f"must match operands {sorted(self.operands)}"
            )
        for name, coef in self.divide_coef.items():
            if coef.shape != (rank, Q_BASE):
                raise ValueError(
                    f"plan {self.name!r}: divide schema for {name!r} has shape "
                    f"{coef.shape}, want {(rank, Q_BASE)}"
                )
        if self.combine_coef.shape != (Q_BASE, rank):
            raise ValueError(
                f"plan {self.name!r}: combine schema has shape "
                f"{self.combine_coef.shape}, want {(Q_BASE, rank)}"
            )

    def operand_terms(self, m_path: TagPath, operand: str) -> List[Term]:
        """Divide algebra: root-operand quadrant paths feeding a leaf.

        For leaf M-path ``m_path``, the (base-4 quadrant path,
        coefficient) terms whose signed sum over the root operand's
        blocks equals the leaf's ``operand`` input.
        """
        try:
            coef = self.divide_coef[operand]
        except KeyError:
            raise ValueError(
                f"plan {self.name!r} has no operand {operand!r}; "
                f"operands: {', '.join(self.operands)}"
            ) from None
        if any(not 0 <= d < self.rank for d in m_path):
            raise ValueError(f"{m_path} has digits outside rank {self.rank}")
        return expand_terms(m_path, coef)

    def combine_terms(self, m_path: TagPath) -> List[Term]:
        """Combine algebra: where a leaf product lands in the result.

        (base-4 quadrant path of the result, coefficient) terms — the
        transposed-combine tensor-product expansion.
        """
        if any(not 0 <= d < self.rank for d in m_path):
            raise ValueError(f"{m_path} has digits outside rank {self.rank}")
        return expand_terms(m_path, self.combine_coef.T)


# Selectors a DataflowPlan's divide/combine schemas may address:
# quadrants of a square operand, or row-halves of a tall RHS panel.
_SELECTORS = ("q0", "q1", "q2", "q3", "r0", "r1")


def select_part(dense: np.ndarray, selector: str) -> np.ndarray:
    """Slice one schema part (quadrant ``q0..q3`` or row-half ``r0/r1``)."""
    if selector.startswith("q"):
        return _quadrants(dense)[int(selector[1])]
    if selector.startswith("r"):
        half = dense.shape[0] // 2
        return dense[:half] if selector == "r0" else dense[half:]
    raise ValueError(f"unknown part selector {selector!r}; have {_SELECTORS}")


@dataclasses.dataclass(frozen=True)
class Step:
    """One instruction of a :class:`DataflowPlan` node program.

    kind:
      ``"recurse"`` — apply ``plan`` (default: the enclosing plan) to the
        symbols named in ``args`` (matched positionally to the child
        plan's operands), producing ``out``. Each recurse step appends
        its ordinal as a tag digit, so solver recursion trees are
        base-(#recursions) tag paths like the bilinear base-7 ones.
      ``"matmul"`` — ``out = alpha * (args[0] @ args[1])``; re-enters the
        matmul scheduler (device ``kind="auto"`` when the product fits
        the budget, the out-of-core wave pipeline when it does not).
      ``"axpy"`` — ``out = sum_i coef_i * sym_i`` over ``terms``; a
        host-side signed block sum, same accumulation discipline as the
        divide/combine stages.
    """

    kind: str
    out: str
    args: Tuple[str, ...] = ()
    terms: Tuple[Tuple[str, float], ...] = ()
    alpha: float = 1.0
    plan: Optional[str] = None


@dataclasses.dataclass(frozen=True)
class DataflowPlan(RecursivePlan):
    """A sequential per-node recursion with data-dependent steps.

    ``divide`` names each symbol a node starts from: a (operand, part
    selector) pair — the plan's divide schema. ``program`` is the node's
    step list (see :class:`Step`); ``combine`` places named symbols into
    the result's parts — the combine schema. The recursion floor runs
    ``leaf_kind`` densely on device.

    Unlike a :class:`BilinearPlan`, the children are *not* independent
    (SPIN's second recursion inverts a Schur complement built from the
    first), so these plans run on :mod:`repro.blocks.solve`'s sequential
    executor rather than the wave scheduler — but every block multiply
    inside the program dispatches back into the wave scheduler, which is
    where the waves/budget/pipeline machinery is reused.
    """

    divide: Tuple[Tuple[str, Tuple[str, str]], ...] = ()
    program: Tuple[Step, ...] = ()
    combine: Tuple[Tuple[str, Optional[str]], ...] = ()

    @property
    def recursions(self) -> int:
        return sum(1 for s in self.program if s.kind == "recurse")

    def validate(self) -> None:
        defined = {sym for sym, _ in self.divide}
        for sym, (op_name, selector) in self.divide:
            if op_name not in self.operands:
                raise ValueError(
                    f"plan {self.name!r}: divide symbol {sym!r} reads unknown "
                    f"operand {op_name!r}; operands: {', '.join(self.operands)}"
                )
            if selector not in _SELECTORS:
                raise ValueError(
                    f"plan {self.name!r}: divide symbol {sym!r} uses unknown "
                    f"selector {selector!r}; have {_SELECTORS}"
                )
        for step in self.program:
            needed = step.args if step.kind != "axpy" else tuple(
                s for s, _ in step.terms
            )
            missing = [s for s in needed if s not in defined]
            if missing:
                raise ValueError(
                    f"plan {self.name!r}: step {step.out!r} reads undefined "
                    f"symbols {missing}"
                )
            defined.add(step.out)
        for selector, sym in self.combine:
            if sym is not None and sym not in defined:
                raise ValueError(
                    f"plan {self.name!r}: combine places undefined symbol {sym!r}"
                )
            if selector not in _SELECTORS:
                raise ValueError(
                    f"plan {self.name!r}: combine uses unknown selector "
                    f"{selector!r}; have {_SELECTORS}"
                )


def matmul_plan(scheme: Scheme | str) -> BilinearPlan:
    """Wrap a coefficient scheme as the equivalent bilinear matmul plan.

    The divide/combine schemas ARE the scheme's coefficient arrays
    (shared, not copied): walking this plan reproduces the pre-plan
    scheduler's arithmetic bit for bit.
    """
    scheme = get_scheme(scheme) if isinstance(scheme, str) else scheme
    return BilinearPlan(
        name=scheme.name,
        op="matmul",
        operands=("A", "B"),
        result="C",
        leaf_kind="matmul",
        scheme=scheme,
        divide_coef={"A": scheme.a_coef, "B": scheme.b_coef},
        combine_coef=scheme.c_coef,
    )


# --- SPIN block-recursive inversion (arxiv 1801.04723, Algorithm 2).
#
# For invertible A with invertible leading block, with X11 = inv(A11) and
# S = A22 - A21 X11 A12 the Schur complement:
#
#   inv(A) = [[ X11 + T2 inv(S) T1, -T2 inv(S) ],
#             [     -inv(S) T1,      inv(S)    ]]
#   where T1 = A21 X11, T2 = X11 A12.
#
# Two recursions (A11, then S) and six half-size multiplies per node.
SPIN_INVERSE = DataflowPlan(
    name="spin_inverse",
    op="inverse",
    operands=("A",),
    result="X",
    leaf_kind="inv",
    divide=(
        ("A11", ("A", "q0")),
        ("A12", ("A", "q1")),
        ("A21", ("A", "q2")),
        ("A22", ("A", "q3")),
    ),
    program=(
        Step("recurse", out="X11", args=("A11",)),
        Step("matmul", out="T1", args=("A21", "X11")),
        Step("matmul", out="T2", args=("X11", "A12")),
        Step("matmul", out="TS", args=("T1", "A12")),
        Step("axpy", out="S", terms=(("A22", 1.0), ("TS", -1.0))),
        Step("recurse", out="X22", args=("S",)),
        Step("matmul", out="B12", args=("T2", "X22"), alpha=-1.0),
        Step("matmul", out="B21", args=("X22", "T1"), alpha=-1.0),
        Step("matmul", out="TB", args=("T2", "B21")),
        Step("axpy", out="B11", terms=(("X11", 1.0), ("TB", -1.0))),
    ),
    combine=(
        ("q0", "B11"),
        ("q1", "B12"),
        ("q2", "B21"),
        ("q3", "X22"),
    ),
)

# --- Block-recursive triangular solve, X = inv(L) B for lower L:
#   X1 = solve(L11, B1);  X2 = solve(L22, B2 - L21 X1)
TRSM_LOWER = DataflowPlan(
    name="spin_trsm_lower",
    op="solve",
    operands=("L", "B"),
    result="X",
    leaf_kind="trsm_lower",
    divide=(
        ("L11", ("L", "q0")),
        ("L21", ("L", "q2")),
        ("L22", ("L", "q3")),
        ("B1", ("B", "r0")),
        ("B2", ("B", "r1")),
    ),
    program=(
        Step("recurse", out="X1", args=("L11", "B1")),
        Step("matmul", out="T", args=("L21", "X1")),
        Step("axpy", out="R", terms=(("B2", 1.0), ("T", -1.0))),
        Step("recurse", out="X2", args=("L22", "R")),
    ),
    combine=(("r0", "X1"), ("r1", "X2")),
)

# --- Upper-triangular solve, X = inv(U) B:
#   X2 = solve(U22, B2);  X1 = solve(U11, B1 - U12 X2)
TRSM_UPPER = DataflowPlan(
    name="spin_trsm_upper",
    op="solve",
    operands=("L", "B"),
    result="X",
    leaf_kind="trsm_upper",
    divide=(
        ("U11", ("L", "q0")),
        ("U12", ("L", "q1")),
        ("U22", ("L", "q3")),
        ("B1", ("B", "r0")),
        ("B2", ("B", "r1")),
    ),
    program=(
        Step("recurse", out="X2", args=("U22", "B2")),
        Step("matmul", out="T", args=("U12", "X2")),
        Step("axpy", out="R", terms=(("B1", 1.0), ("T", -1.0))),
        Step("recurse", out="X1", args=("U11", "R")),
    ),
    combine=(("r0", "X1"), ("r1", "X2")),
)


_PLANS: Dict[str, RecursivePlan] = {}


def register_plan(plan: RecursivePlan) -> RecursivePlan:
    """Validate and register a plan under its name (idempotent by name)."""
    plan.validate()
    _PLANS[plan.name] = plan
    return plan


for _scheme_name in ("strassen", "winograd", "naive8"):
    register_plan(matmul_plan(_scheme_name))
for _p in (SPIN_INVERSE, TRSM_LOWER, TRSM_UPPER):
    register_plan(_p)


def get_plan(name: str) -> RecursivePlan:
    try:
        return _PLANS[name]
    except KeyError:
        raise ValueError(
            f"unknown recursive plan {name!r}; have {sorted(_PLANS)}"
        ) from None


def plan_names() -> List[str]:
    return sorted(_PLANS)


def as_bilinear_plan(plan: "BilinearPlan | Scheme | str") -> BilinearPlan:
    """Coerce a plan name / Scheme / plan to a wave-schedulable plan.

    The scheduler's entry points historically accepted ``scheme=`` names
    and Scheme instances; this keeps them working while the plan layer
    owns the schemas.
    """
    if isinstance(plan, BilinearPlan):
        return plan
    if isinstance(plan, Scheme):
        return matmul_plan(plan)
    if isinstance(plan, str):
        got = _PLANS.get(plan)
        if isinstance(got, BilinearPlan):
            return got
        if got is None:
            # A scheme name that never registered (custom Scheme objects
            # go through matmul_plan): fail with the plan registry error.
            return matmul_plan(plan)
        raise ValueError(
            f"plan {plan!r} is {type(got).__name__}, not wave-schedulable; "
            f"bilinear plans: "
            f"{sorted(n for n, p in _PLANS.items() if isinstance(p, BilinearPlan))}"
        )
    raise TypeError(f"cannot interpret {plan!r} as a bilinear plan")

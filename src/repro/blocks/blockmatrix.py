"""(row, col, tag)-addressed block matrices over pluggable host stores.

The MLlib/Marlin ``BlockMatrix`` layout (Zadeh et al.) as a host-resident
runtime structure: a matrix is a uniform grid of (bm, bn) blocks, each
addressed by ``(row, col, tag)`` where ``tag`` is a recursion tag-path
string (:mod:`repro.blocks.tags`) naming the node of the Strassen tree the
block belongs to — ``""`` for a root operand, ``"A:3,0"`` for the level-2
divide product of A that took M-branches 3 then 0, and so on.

Blocks live in a :class:`BlockStore`, which is deliberately dumb — put /
get / delete numpy arrays by key — so the same :class:`BlockMatrix` code
runs over three residencies:

* :class:`DictStore`   — plain in-memory dict (tests, small problems);
* :class:`ArenaStore`  — one preallocated host-RAM arena of fixed-size
  slots with a free list, so a long multiply churns zero allocations and
  the host footprint is a hard, visible number;
* :class:`MemmapStore` — one ``.npy`` memmap file per block under a spill
  directory, for operands larger than host RAM (the paper's "data far
  larger than memory" regime, with the filesystem playing HDFS).

Edge blocks are zero-padded to the full block shape in storage; the
logical shape is metadata, so ``to_dense`` round-trips odd shapes exactly
(padding contributes zero to every bilinear term — same argument as the
fused kernel's padded wrapper).
"""
from __future__ import annotations

import abc
import os
import shutil
import tempfile
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

__all__ = [
    "BlockKey",
    "BlockStore",
    "DictStore",
    "ArenaStore",
    "MemmapStore",
    "make_store",
    "BlockMatrix",
    "signed_block_sum",
]

BlockKey = Tuple[int, int, str]  # (block row, block col, tag string)


def signed_block_sum(get_block, coefs: np.ndarray, acc_dtype) -> np.ndarray:
    """sum_i coefs[i] * get_block(i) with zero-skip and +/-1 fast paths.

    The one accumulation discipline divide, combine, AND lineage
    recompute (:mod:`repro.blocks.recovery`) share: terms are read
    through ``.astype`` (ml_dtypes/bf16 memmaps fail numpy's direct-cast
    buffer path) and summed in ``acc_dtype``, in ascending index order.
    Recompute replays a block bit-for-bit only because it runs this
    exact loop — keep any change to the ordering or fast paths here.
    """
    acc = None
    for idx in range(len(coefs)):
        c = float(coefs[idx])
        if c == 0.0:
            continue
        blk = np.asarray(get_block(idx)).astype(acc_dtype, copy=False)
        term = blk if c == 1.0 else (-blk if c == -1.0 else c * blk)
        acc = term if acc is None else acc + term
    assert acc is not None, "coefficient row is all zero"
    return acc


class BlockStore(abc.ABC):
    """Minimal key -> numpy-block storage contract."""

    @abc.abstractmethod
    def put(self, key: BlockKey, block: np.ndarray) -> None: ...

    @abc.abstractmethod
    def get(self, key: BlockKey) -> np.ndarray: ...

    @abc.abstractmethod
    def delete(self, key: BlockKey) -> None: ...

    @abc.abstractmethod
    def __contains__(self, key: BlockKey) -> bool: ...

    @abc.abstractmethod
    def keys(self) -> List[BlockKey]: ...

    @abc.abstractmethod
    def nbytes(self) -> int:
        """Bytes currently held (logical block bytes, not slack)."""

    def delete_tag(self, tag: str) -> None:
        """Drop every block of one tree node (combine frees its children)."""
        for key in [k for k in self.keys() if k[2] == tag]:
            self.delete(key)

    def clear(self) -> None:
        for key in list(self.keys()):
            self.delete(key)

    def close(self) -> None:  # releases files/arenas; default no-op
        self.clear()


class DictStore(BlockStore):
    """In-memory dict of blocks — the reference store."""

    def __init__(self) -> None:
        self._blocks: Dict[BlockKey, np.ndarray] = {}

    def put(self, key: BlockKey, block: np.ndarray) -> None:
        self._blocks[key] = np.ascontiguousarray(block)

    def get(self, key: BlockKey) -> np.ndarray:
        return self._blocks[key]

    def delete(self, key: BlockKey) -> None:
        self._blocks.pop(key, None)

    def __contains__(self, key: BlockKey) -> bool:
        return key in self._blocks

    def keys(self) -> List[BlockKey]:
        return list(self._blocks)

    def nbytes(self) -> int:
        return sum(b.nbytes for b in self._blocks.values())


class ArenaStore(BlockStore):
    """Preallocated host-RAM arena: fixed-size byte slots + a free list.

    ``slot_bytes`` must cover the largest block the caller will put (the
    scheduler sizes it as max over the A/B/C block shapes and dtypes —
    slots are raw bytes, so bf16 operands and f32 accumulators share one
    arena). The arena grows by whole segments of ``capacity`` slots when
    full, so steady state churns zero allocations and peak host bytes are
    ``segments * capacity * slot_bytes`` — a number you can print, which
    is the point of an arena.
    """

    def __init__(self, slot_bytes: int, capacity: int = 64) -> None:
        if slot_bytes <= 0 or capacity <= 0:
            raise ValueError("slot_bytes and capacity must be positive")
        self.slot_bytes = int(slot_bytes)
        self.capacity = int(capacity)
        self._segments: List[np.ndarray] = []
        self._free: List[int] = []
        # key -> (global slot index, block shape, block dtype)
        self._index: Dict[BlockKey, Tuple[int, Tuple[int, ...], np.dtype]] = {}

    def _grow(self) -> None:
        base = len(self._segments) * self.capacity
        self._segments.append(
            np.empty((self.capacity, self.slot_bytes), np.uint8)
        )
        self._free.extend(reversed(range(base, base + self.capacity)))

    def _slot(self, idx: int) -> np.ndarray:
        return self._segments[idx // self.capacity][idx % self.capacity]

    def put(self, key: BlockKey, block: np.ndarray) -> None:
        block = np.ascontiguousarray(block)
        if block.nbytes > self.slot_bytes:
            raise ValueError(
                f"block of {block.nbytes} B exceeds slot_bytes={self.slot_bytes}"
            )
        if key in self._index:
            idx = self._index[key][0]
        else:
            if not self._free:
                self._grow()
            idx = self._free.pop()
        self._slot(idx)[: block.nbytes] = block.reshape(-1).view(np.uint8)
        self._index[key] = (idx, block.shape, block.dtype)

    def get(self, key: BlockKey) -> np.ndarray:
        idx, shape, dtype = self._index[key]
        n = int(np.prod(shape)) * dtype.itemsize
        return self._slot(idx)[:n].view(dtype).reshape(shape)

    def delete(self, key: BlockKey) -> None:
        entry = self._index.pop(key, None)
        if entry is not None:
            self._free.append(entry[0])

    def __contains__(self, key: BlockKey) -> bool:
        return key in self._index

    def keys(self) -> List[BlockKey]:
        return list(self._index)

    def nbytes(self) -> int:
        total = 0
        for _, shape, dtype in self._index.values():
            total += int(np.prod(shape)) * dtype.itemsize
        return total

    def arena_bytes(self) -> int:
        """Allocated host footprint (all segments, used or free)."""
        return sum(seg.nbytes for seg in self._segments)

    def close(self) -> None:
        self._index.clear()
        self._free.clear()
        self._segments.clear()


class MemmapStore(BlockStore):
    """npy/memmap spill backend: one ``.npy`` file per block.

    Blocks are written with :func:`numpy.lib.format.open_memmap` (plain
    ``np.load``-able files, bfloat16 included via ml_dtypes) under
    ``root`` — a caller-owned spill directory, or a self-created temp dir
    removed on :meth:`close`. ``get`` returns a read-only memmap, so a
    combine touching 7 children pages in only the bytes it reads.
    """

    def __init__(self, root: Optional[str] = None) -> None:
        self._owned = root is None
        self.root = root or tempfile.mkdtemp(prefix="repro_blocks_")
        os.makedirs(self.root, exist_ok=True)
        # key -> (path, dtype): the npy header cannot name ml_dtypes
        # (bfloat16 round-trips as void '|V2'), so the index keeps the true
        # dtype and get() re-views the mapped bytes.
        self._index: Dict[BlockKey, Tuple[str, np.dtype]] = {}
        self._counter = 0

    def _path(self, key: BlockKey) -> str:
        entry = self._index.get(key)
        if entry is not None:
            return entry[0]
        # filenames are opaque ids: tags contain ':' and ',' which are
        # legal but ugly on some filesystems; the index owns the map.
        path = os.path.join(self.root, f"blk{self._counter:08d}.npy")
        self._counter += 1
        return path

    def put(self, key: BlockKey, block: np.ndarray) -> None:
        block = np.ascontiguousarray(block)
        path = self._path(key)
        mm = np.lib.format.open_memmap(
            path, mode="w+", dtype=block.dtype, shape=block.shape
        )
        mm[...] = block
        mm.flush()
        del mm
        self._index[key] = (path, block.dtype)

    def get(self, key: BlockKey) -> np.ndarray:
        path, dtype = self._index[key]
        mm = np.lib.format.open_memmap(path, mode="r")
        return mm if mm.dtype == dtype else mm.view(dtype)

    def delete(self, key: BlockKey) -> None:
        entry = self._index.pop(key, None)
        if entry is not None and os.path.exists(entry[0]):
            os.remove(entry[0])

    def __contains__(self, key: BlockKey) -> bool:
        return key in self._index

    def keys(self) -> List[BlockKey]:
        return list(self._index)

    def nbytes(self) -> int:
        return sum(
            os.path.getsize(p) for p, _ in self._index.values() if os.path.exists(p)
        )

    def close(self) -> None:
        self._index.clear()
        if self._owned and os.path.isdir(self.root):
            shutil.rmtree(self.root, ignore_errors=True)


def make_store(
    spec: str | BlockStore,
    *,
    slot_bytes: int = 0,
    capacity: int = 64,
    root: Optional[str] = None,
) -> BlockStore:
    """Store factory for CLI/benchmark surfaces: 'dict' | 'arena' | 'memmap'."""
    if isinstance(spec, BlockStore):
        return spec
    if spec == "dict":
        return DictStore()
    if spec == "arena":
        if slot_bytes <= 0:
            raise ValueError("arena store needs slot_bytes > 0")
        return ArenaStore(slot_bytes, capacity=capacity)
    if spec == "memmap":
        return MemmapStore(root)
    raise ValueError(f"unknown store {spec!r}; have 'dict', 'arena', 'memmap'")


class BlockMatrix:
    """A logical (m, n) matrix stored as a tagged grid of uniform blocks.

    ``shape`` is the logical shape; the stored grid covers
    ``grid = (ceil(m / bm), ceil(n / bn))`` blocks of exactly
    ``block_shape``, edge blocks zero-padded. ``tag`` names the recursion
    node every block of this matrix belongs to and is part of each block's
    store key, so many tree nodes share one store.
    """

    def __init__(
        self,
        store: BlockStore,
        shape: Tuple[int, int],
        block_shape: Tuple[int, int],
        dtype,
        tag: str = "",
    ) -> None:
        m, n = shape
        bm, bn = block_shape
        if m <= 0 or n <= 0 or bm <= 0 or bn <= 0:
            raise ValueError(f"bad shape {shape} / block_shape {block_shape}")
        self.store = store
        self.shape = (int(m), int(n))
        self.block_shape = (int(bm), int(bn))
        self.dtype = np.dtype(dtype)
        self.tag = tag
        self.grid = (-(-m // bm), -(-n // bn))

    # ------------------------------------------------------------- metadata
    @property
    def padded_shape(self) -> Tuple[int, int]:
        return (
            self.grid[0] * self.block_shape[0],
            self.grid[1] * self.block_shape[1],
        )

    @property
    def nbytes(self) -> int:
        """Stored bytes of this matrix (full padded grid)."""
        return (
            self.grid[0]
            * self.grid[1]
            * self.block_shape[0]
            * self.block_shape[1]
            * self.dtype.itemsize
        )

    def meta(self) -> Dict:
        """dtype/layout metadata travelling with the blocks."""
        return {
            "shape": self.shape,
            "padded_shape": self.padded_shape,
            "block_shape": self.block_shape,
            "grid": self.grid,
            "dtype": self.dtype.name,
            "tag": self.tag,
            "layout": "row-major",
        }

    def key(self, i: int, j: int) -> BlockKey:
        return (i, j, self.tag)

    def block_keys(self) -> Iterator[BlockKey]:
        for i in range(self.grid[0]):
            for j in range(self.grid[1]):
                yield self.key(i, j)

    # ---------------------------------------------------------- block access
    def block(self, i: int, j: int) -> np.ndarray:
        """The stored (bm, bn) block at grid position (i, j)."""
        if not (0 <= i < self.grid[0] and 0 <= j < self.grid[1]):
            raise IndexError(f"block ({i}, {j}) outside grid {self.grid}")
        return self.store.get(self.key(i, j))

    def put_block(self, i: int, j: int, block: np.ndarray) -> None:
        if tuple(block.shape) != self.block_shape:
            raise ValueError(
                f"block shape {block.shape} != {self.block_shape} (store padded)"
            )
        self.store.put(self.key(i, j), np.asarray(block, self.dtype))

    def free(self) -> None:
        """Delete every block of this matrix from the store."""
        for key in self.block_keys():
            self.store.delete(key)

    # ------------------------------------------------------- dense interop
    @classmethod
    def from_dense(
        cls,
        arr: np.ndarray,
        block_shape: Tuple[int, int],
        store: Optional[BlockStore] = None,
        tag: str = "",
        shape: Optional[Tuple[int, int]] = None,
    ) -> "BlockMatrix":
        """Ingest a dense array block by block.

        ``shape`` (>= ``arr.shape``) zero-extends the matrix to a larger
        logical shape without materializing the padded dense copy — the
        scheduler uses it to align operands to the recursion grain.
        """
        arr = np.asarray(arr)
        if arr.ndim != 2:
            raise ValueError(f"need a 2-D array, got shape {arr.shape}")
        shape = tuple(shape) if shape is not None else arr.shape
        if shape[0] < arr.shape[0] or shape[1] < arr.shape[1]:
            raise ValueError(f"shape {shape} smaller than data {arr.shape}")
        store = store if store is not None else DictStore()
        bm_mat = cls(store, shape, block_shape, arr.dtype, tag)
        bm, bn = bm_mat.block_shape
        for i in range(bm_mat.grid[0]):
            for j in range(bm_mat.grid[1]):
                chunk = arr[i * bm : (i + 1) * bm, j * bn : (j + 1) * bn]
                if chunk.shape != (bm, bn):
                    full = np.zeros((bm, bn), bm_mat.dtype)
                    full[: chunk.shape[0], : chunk.shape[1]] = chunk
                    chunk = full
                bm_mat.put_block(i, j, np.asarray(chunk, bm_mat.dtype))
        return bm_mat

    @classmethod
    def zeros(
        cls,
        shape: Tuple[int, int],
        block_shape: Tuple[int, int],
        store: BlockStore,
        dtype,
        tag: str = "",
    ) -> "BlockMatrix":
        out = cls(store, shape, block_shape, dtype, tag)
        zero = np.zeros(out.block_shape, out.dtype)
        for i in range(out.grid[0]):
            for j in range(out.grid[1]):
                out.put_block(i, j, zero)
        return out

    def to_dense(self) -> np.ndarray:
        m, n = self.shape
        bm, bn = self.block_shape
        out = np.empty(self.padded_shape, self.dtype)
        for i in range(self.grid[0]):
            for j in range(self.grid[1]):
                out[i * bm : (i + 1) * bm, j * bn : (j + 1) * bn] = self.block(i, j)
        return out[:m, :n]

    def __repr__(self) -> str:
        return (
            f"BlockMatrix(shape={self.shape}, block={self.block_shape}, "
            f"grid={self.grid}, dtype={self.dtype.name}, tag={self.tag!r}, "
            f"store={type(self.store).__name__})"
        )

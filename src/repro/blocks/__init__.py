"""Tagged BlockMatrix runtime: out-of-core Strassen over host block stores.

The paper's defining mechanism is an RDD of *tagged* matrix blocks whose
base-7 tag paths encode the recursion tree. This package is that mechanism
re-expressed for a single-host JAX runtime whose device memory is the
scarce resource:

  tags         — the base-7 (M-index) / base-4 (quadrant) tag-path codec
                 (delegating the schema algebra to the plan layer).
  plan         — declarative RecursivePlans: divide schema, leaf op,
                 combine schema. Strassen/winograd/naive8 matmul plans
                 (bit-identical Scheme wrappers) plus SPIN inversion and
                 triangular-solve dataflow plans.
  blockmatrix  — (row, col, tag)-addressed blocks over a pluggable host
                 store (dict, preallocated RAM arena, npy/memmap spill).
  scheduler    — a level-order wave executor that walks a BilinearPlan,
                 staging the rank^q leaf ops through device memory in
                 budgeted waves.
  solve        — the sequential DataflowPlan executor: SPIN
                 block-recursive inversion / triangular solves whose
                 multiplies re-enter the wave scheduler.
  recovery     — lineage-based fault tolerance: the tag algebra IS the
                 lineage graph, so any lost/corrupt block recomputes from
                 its parents (RecoveringStore), with a deterministic
                 chaos-injection harness (ChaosStore / FlakyLeaf).

Where Stark bounds per-executor memory by partitioning the RDD, this
subsystem bounds peak *device* memory by a configurable byte budget while
the operands live in host RAM or on disk — the out-of-core regime the
paper's 16384^2-class experiments need on real hosts.
"""
from repro.blocks.blockmatrix import (
    ArenaStore,
    BlockMatrix,
    BlockStore,
    DictStore,
    MemmapStore,
    make_store,
    signed_block_sum,
)
from repro.blocks.recovery import (
    BlockLossError,
    ChaosConfig,
    ChaosStore,
    FaultError,
    FlakyLeaf,
    InjectedFault,
    Lineage,
    RecoveringStore,
    recompute_block,
)
from repro.blocks.plan import (
    BilinearPlan,
    DataflowPlan,
    RecursivePlan,
    as_bilinear_plan,
    get_plan,
    matmul_plan,
    plan_names,
    register_plan,
)
from repro.blocks.scheduler import (
    OotStats,
    PlanScheduler,
    StrassenScheduler,
    leaf_bytes,
    min_depth_for_budget,
    strassen_oot_matmul,
)
from repro.blocks.solve import (
    SolveScheduler,
    solver_min_depth_for_budget,
    spin_inverse_oot,
    triangular_solve_oot,
)
from repro.blocks import tags

__all__ = [
    "tags",
    "RecursivePlan",
    "BilinearPlan",
    "DataflowPlan",
    "matmul_plan",
    "register_plan",
    "get_plan",
    "plan_names",
    "as_bilinear_plan",
    "PlanScheduler",
    "SolveScheduler",
    "solver_min_depth_for_budget",
    "spin_inverse_oot",
    "triangular_solve_oot",
    "BlockStore",
    "DictStore",
    "ArenaStore",
    "MemmapStore",
    "make_store",
    "BlockMatrix",
    "signed_block_sum",
    "StrassenScheduler",
    "OotStats",
    "strassen_oot_matmul",
    "leaf_bytes",
    "min_depth_for_budget",
    "FaultError",
    "InjectedFault",
    "BlockLossError",
    "ChaosConfig",
    "ChaosStore",
    "FlakyLeaf",
    "Lineage",
    "RecoveringStore",
    "recompute_block",
]

"""Tagged BlockMatrix runtime: out-of-core Strassen over host block stores.

The paper's defining mechanism is an RDD of *tagged* matrix blocks whose
base-7 tag paths encode the recursion tree. This package is that mechanism
re-expressed for a single-host JAX runtime whose device memory is the
scarce resource:

  tags         — the base-7 (M-index) / base-4 (quadrant) tag-path codec
                 and the full divide/combine tag algebra.
  blockmatrix  — (row, col, tag)-addressed blocks over a pluggable host
                 store (dict, preallocated RAM arena, npy/memmap spill).
  scheduler    — a level-order Strassen executor that stages the 7^q leaf
                 multiplies through device memory in budgeted waves.
  recovery     — lineage-based fault tolerance: the tag algebra IS the
                 lineage graph, so any lost/corrupt block recomputes from
                 its parents (RecoveringStore), with a deterministic
                 chaos-injection harness (ChaosStore / FlakyLeaf).

Where Stark bounds per-executor memory by partitioning the RDD, this
subsystem bounds peak *device* memory by a configurable byte budget while
the operands live in host RAM or on disk — the out-of-core regime the
paper's 16384^2-class experiments need on real hosts.
"""
from repro.blocks.blockmatrix import (
    ArenaStore,
    BlockMatrix,
    BlockStore,
    DictStore,
    MemmapStore,
    make_store,
    signed_block_sum,
)
from repro.blocks.recovery import (
    BlockLossError,
    ChaosConfig,
    ChaosStore,
    FaultError,
    FlakyLeaf,
    InjectedFault,
    Lineage,
    RecoveringStore,
    recompute_block,
)
from repro.blocks.scheduler import (
    OotStats,
    StrassenScheduler,
    leaf_bytes,
    min_depth_for_budget,
    strassen_oot_matmul,
)
from repro.blocks import tags

__all__ = [
    "tags",
    "BlockStore",
    "DictStore",
    "ArenaStore",
    "MemmapStore",
    "make_store",
    "BlockMatrix",
    "signed_block_sum",
    "StrassenScheduler",
    "OotStats",
    "strassen_oot_matmul",
    "leaf_bytes",
    "min_depth_for_budget",
    "FaultError",
    "InjectedFault",
    "BlockLossError",
    "ChaosConfig",
    "ChaosStore",
    "FlakyLeaf",
    "Lineage",
    "RecoveringStore",
    "recompute_block",
]

"""Tag-path codec and divide/combine tag algebra (paper §III).

Stark tags every RDD block with a comma-separated index string recording,
per recursion level, which branch the block took through the recursion
tree. Two alphabets appear in the paper's pipeline:

* the 7-way **M-index** (which of the scheme's rank products a divide
  level routed the block into) — base-``rank`` digits, rank 7 for
  Strassen/Winograd, 8 for the naive baseline scheme;
* the 4-way **quadrant index** (which quarter of a sub-matrix a block
  addresses) — base-4 digits, row-major [11, 12, 21, 22].

A *tag path* here is a tuple of digits, most-significant (outermost
recursion level) first, exactly the digit order of
:func:`repro.core.coefficients.leaf_tag_path`; ``encode``/``decode`` are
the generic-radix generalization of that function and its inverse.

The *tag algebra* the out-of-core scheduler runs on — for a leaf M-path,
which (quadrant-path, coefficient) terms of the root operands form its
left/right operand, and with which coefficient the leaf product lands in
each quadrant path of C — lives in :mod:`repro.blocks.plan` now (it is a
property of a recursive plan's divide/combine schemas, not of the tag
codec). :func:`operand_terms` / :func:`combine_terms` remain here as thin
wrappers over the scheme's matmul plan for the historical
(scheme, side)-keyed API.
"""
from __future__ import annotations

from typing import Iterator, List, Sequence, Tuple

import numpy as np

from repro.core.coefficients import Scheme, get_scheme

__all__ = [
    "M_BASE",
    "Q_BASE",
    "encode",
    "decode",
    "to_string",
    "from_string",
    "child",
    "parent",
    "leaf_paths",
    "operand_terms",
    "combine_terms",
    "validate_algebra",
]

M_BASE = 7  # M-index alphabet of the rank-7 schemes (paper's base-7 tags)
Q_BASE = 4  # quadrant alphabet, row-major [11, 12, 21, 22]

TagPath = Tuple[int, ...]
Term = Tuple[TagPath, float]


def encode(path: Sequence[int], base: int = M_BASE) -> int:
    """Tag path -> flat index, most-significant digit first.

    ``encode(leaf_tag_path(i, d)) == i`` for every base-7 path: this is
    :func:`repro.core.coefficients.leaf_index_from_path` generalized to
    any radix (base-4 quadrant paths address blocks inside a sub-matrix).
    """
    index = 0
    for digit in path:
        if not 0 <= digit < base:
            raise ValueError(f"digit {digit} out of range for base {base}")
        index = index * base + digit
    return index


def decode(index: int, depth: int, base: int = M_BASE) -> TagPath:
    """Flat index -> tag path of ``depth`` digits (inverse of :func:`encode`)."""
    if not 0 <= index < base**depth:
        raise ValueError(f"index {index} out of range for depth {depth} base {base}")
    digits: List[int] = []
    for _ in range(depth):
        digits.append(index % base)
        index //= base
    return tuple(reversed(digits))


def to_string(path: Sequence[int]) -> str:
    """The paper's comma-separated tag string: (3, 0, 5) -> ``"3,0,5"``."""
    return ",".join(str(d) for d in path)


def from_string(s: str) -> TagPath:
    """Inverse of :func:`to_string`; the empty string is the root path."""
    if not s:
        return ()
    return tuple(int(d) for d in s.split(","))


def child(path: TagPath, digit: int, base: int = M_BASE) -> TagPath:
    """Descend one recursion level (append a branch digit)."""
    if not 0 <= digit < base:
        raise ValueError(f"digit {digit} out of range for base {base}")
    return path + (digit,)


def parent(path: TagPath) -> TagPath:
    """Ascend one recursion level; the root has no parent."""
    if not path:
        raise ValueError("root tag path has no parent")
    return path[:-1]


def leaf_paths(depth: int, base: int = M_BASE) -> Iterator[TagPath]:
    """All level-``depth`` tag paths in index order (lexicographic)."""
    for i in range(base**depth):
        yield decode(i, depth, base)


def _expand(m_path: TagPath, coef: np.ndarray) -> List[Term]:
    """Tensor-product expansion down a tag path (now plan-layer algebra)."""
    from repro.blocks.plan import expand_terms

    return expand_terms(m_path, coef, Q_BASE)


def operand_terms(
    m_path: TagPath, scheme: Scheme | str, side: str
) -> List[Term]:
    """The divide algebra of a scheme's matmul plan, (scheme, side)-keyed.

    For leaf M-path ``m_path``, returns the (base-4 quadrant path,
    coefficient) terms such that the leaf's ``side`` operand ('a' or 'b')
    equals the signed sum of the root operand's blocks at those quadrant
    paths. Delegates to
    :meth:`repro.blocks.plan.BilinearPlan.operand_terms` — the schemas
    live on the plan; this keeps the historical scheme-keyed spelling.
    """
    from repro.blocks.plan import matmul_plan

    if side == "a":
        operand = "A"
    elif side == "b":
        operand = "B"
    else:
        raise ValueError(f"side must be 'a' or 'b', got {side!r}")
    return matmul_plan(scheme).operand_terms(m_path, operand)


def combine_terms(m_path: TagPath, scheme: Scheme | str) -> List[Term]:
    """The combine algebra of a scheme's matmul plan: where a leaf lands.

    Returns (base-4 quadrant path of C, coefficient) terms. Delegates to
    :meth:`repro.blocks.plan.BilinearPlan.combine_terms`.
    """
    from repro.blocks.plan import matmul_plan

    return matmul_plan(scheme).combine_terms(m_path)


def validate_algebra(scheme: Scheme | str, depth: int) -> None:
    """Check the depth-level tag algebra reproduces the matmul tensor.

    Summing ``c_term * a_term * b_term`` over every leaf M-path must give
    exactly the block-matmul tensor over 4^depth-quadrant addresses:

        T[c, qa, qb] = 1  iff  row(c)==row(qa), col(qa)==row(qb),
                               col(qb)==col(c)  (per level)

    — the multi-level generalization of ``Scheme.validate``. Used by
    tests; O((4^depth)^3) so keep depth small.
    """
    if isinstance(scheme, str):
        scheme = get_scheme(scheme)
    nq = Q_BASE**depth
    got = np.zeros((nq, nq, nq))
    for m_path in leaf_paths(depth, scheme.n_mults):
        a_terms = operand_terms(m_path, scheme, "a")
        b_terms = operand_terms(m_path, scheme, "b")
        c_terms = combine_terms(m_path, scheme)
        for cq, cc in c_terms:
            for aq, ac in a_terms:
                for bq, bc in b_terms:
                    got[encode(cq, Q_BASE), encode(aq, Q_BASE), encode(bq, Q_BASE)] += (
                        cc * ac * bc
                    )
    want = np.zeros((nq, nq, nq))
    for c in range(nq):
        cp = decode(c, depth, Q_BASE)
        for a in range(nq):
            ap = decode(a, depth, Q_BASE)
            for b in range(nq):
                bp = decode(b, depth, Q_BASE)
                ok = all(
                    (cd // 2 == ad // 2) and (ad % 2 == bd // 2) and (bd % 2 == cd % 2)
                    for cd, ad, bd in zip(cp, ap, bp)
                )
                if ok:
                    want[c, a, b] = 1.0
    if not np.array_equal(got, want):
        raise ValueError(f"tag algebra of {scheme.name} fails at depth {depth}")

"""Lineage-based fault tolerance for the tagged block runtime.

Stark inherits resilience from Spark for free: every RDD block is
recomputable from its lineage, so a lost partition never kills the job.
This module gives the jax_pallas runtime the same property by exploiting
the fact that **the tag algebra IS the lineage graph**: a block's tag
(``"A:3,0"``, ``"C:5"``, ...) names its node in the recursion tree, and
:func:`repro.blocks.tags.operand_terms` / :func:`~repro.blocks.tags
.combine_terms` are closed forms for how that node derives from its
parents. Any block — a divided operand, a leaf product ``M_t``, a
combine partial — can therefore be rebuilt on demand:

* ``A:``/``B:`` root blocks re-ingest from the retained dense operands;
* deeper divide blocks are one signed quadrant sum of the parent node
  (the single-level ``operand_terms`` row);
* leaf products re-run the leaf multiply over recomputed operands;
* combine partials re-run the single-level ``combine_terms`` sum over
  the (recursively recovered) child products.

Recompute replays the **same computation path** the scheduler took —
same :func:`~repro.blocks.blockmatrix.signed_block_sum` accumulation
order, same staging casts, same leaf kernel — so a recovered block is
bit-identical to the lost one, and the stored put-time checksum proves
it.

Three layers build on :func:`recompute_block`:

:class:`RecoveringStore`
    Transparent wrapper over any :class:`~repro.blocks.blockmatrix
    .BlockStore`: crc32 checksum metadata on put, verify-on-get, and
    lineage recompute on loss (``KeyError``) or corruption (checksum
    mismatch), surfaced through ``fault.*`` obs counters and
    ``fault.recompute`` spans.

:class:`ChaosStore` / :class:`FlakyLeaf`
    The deterministic fault-injection harness: a seeded store wrapper
    that drops or bit-flips blocks on read, and a leaf-multiply shim
    that fails chosen (or randomly sampled) dispatch calls. Both are
    pure injectors — detection and recovery stay in the layers above —
    and both count what they injected, so tests and the CI chaos gate
    can assert every injected fault was observed and healed.

:class:`ChaosConfig`
    One bundle of injection knobs shared by the scheduler's ``chaos=``
    parameter, the benchmarks' ``--fault-rate`` modes, and the CI
    chaos-smoke job.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.blocks import tags
from repro.blocks.blockmatrix import BlockKey, BlockStore, signed_block_sum
from repro.blocks.plan import BilinearPlan, matmul_plan
from repro.core.coefficients import Scheme, get_scheme
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs_tracer

__all__ = [
    "FaultError",
    "InjectedFault",
    "BlockLossError",
    "ChaosConfig",
    "ChaosStore",
    "FlakyLeaf",
    "Lineage",
    "RecoveringStore",
    "block_checksum",
    "recompute_block",
]


class FaultError(RuntimeError):
    """Base of the runtime's recoverable fault family.

    The scheduler's degradation ladder steps down on this (and on
    device-OOM); anything else propagates as a plain bug.
    """


class InjectedFault(FaultError):
    """Raised by the chaos harness (FlakyLeaf / poisoned requests)."""


class BlockLossError(FaultError):
    """A block is gone/corrupt and lineage cannot rebuild it."""


def block_checksum(block: np.ndarray) -> int:
    """crc32 of the block's raw bytes (dtype-agnostic, bf16 included)."""
    return zlib.crc32(np.ascontiguousarray(block).tobytes())


# --------------------------------------------------------------- injection
@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Deterministic fault-injection knobs for one scheduler run.

    ``drop``/``corrupt`` are per-``get`` probabilities applied by
    :class:`ChaosStore`; ``leaf_fail_rate`` / ``fail_leaf_calls`` drive
    :class:`FlakyLeaf` (the Nth-leaf-multiply failure shim). All draws
    come from generators seeded off ``seed``, so a fixed access sequence
    replays the identical fault schedule.
    """

    drop: float = 0.0
    corrupt: float = 0.0
    leaf_fail_rate: float = 0.0
    fail_leaf_calls: Tuple[int, ...] = ()
    seed: int = 0

    def __post_init__(self):
        for name in ("drop", "corrupt", "leaf_fail_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} must be a probability in [0, 1]")

    @property
    def injects_store_faults(self) -> bool:
        return self.drop > 0.0 or self.corrupt > 0.0

    @property
    def injects_leaf_faults(self) -> bool:
        return self.leaf_fail_rate > 0.0 or bool(self.fail_leaf_calls)


class ChaosStore(BlockStore):
    """Seeded block drop/corrupt injector between the runtime and a store.

    Sits *beneath* :class:`RecoveringStore` (faults must hit the raw
    bytes the checksums guard). On ``get`` it may first delete the block
    (a loss the reader sees as ``KeyError``) or flip one byte of the
    stored copy in place (a corruption only a checksum can catch). Pure
    injection: no detection, no recovery, but every injection is counted
    here and on the ``fault.injected_*`` counters so gates can demand
    injected == detected+healed.
    """

    def __init__(
        self,
        inner: BlockStore,
        *,
        drop: float = 0.0,
        corrupt: float = 0.0,
        seed: int = 0,
    ) -> None:
        self.inner = inner
        self.drop = float(drop)
        self.corrupt = float(corrupt)
        self._rng = np.random.default_rng(seed)
        self.injected_drops = 0
        self.injected_corruptions = 0

    def put(self, key: BlockKey, block: np.ndarray) -> None:
        self.inner.put(key, block)

    def get(self, key: BlockKey) -> np.ndarray:
        mx = obs_metrics.get_metrics()
        if self.drop and key in self.inner and self._rng.random() < self.drop:
            self.inner.delete(key)
            self.injected_drops += 1
            mx.counter("fault.injected_drops").inc()
        elif self.corrupt and key in self.inner and self._rng.random() < self.corrupt:
            blk = np.array(self.inner.get(key))  # memmap gets are read-only
            flat = blk.view(np.uint8).reshape(-1)
            flat[int(self._rng.integers(flat.size))] ^= 0xFF
            self.inner.put(key, blk)
            self.injected_corruptions += 1
            mx.counter("fault.injected_corruptions").inc()
        return self.inner.get(key)

    def delete(self, key: BlockKey) -> None:
        self.inner.delete(key)

    def __contains__(self, key: BlockKey) -> bool:
        return key in self.inner

    def keys(self) -> List[BlockKey]:
        return self.inner.keys()

    def nbytes(self) -> int:
        return self.inner.nbytes()

    def close(self) -> None:
        self.inner.close()


class FlakyLeaf:
    """Flaky-backend shim: fail selected leaf-multiply dispatch calls.

    The scheduler calls :meth:`check` once per leaf dispatch (and per
    retry — a retry is a new call, so transient faults clear and
    ``fail_leaf_calls`` can model persistent ones by listing consecutive
    indices). Counts land on ``fault.injected_leaf_failures``.
    """

    def __init__(
        self,
        *,
        fail_calls: Tuple[int, ...] = (),
        fail_rate: float = 0.0,
        seed: int = 0,
    ) -> None:
        self.fail_calls = frozenset(fail_calls)
        self.fail_rate = float(fail_rate)
        self._rng = np.random.default_rng(seed)
        self.calls = 0
        self.injected = 0

    def check(self) -> None:
        idx = self.calls
        self.calls += 1
        if idx in self.fail_calls or (
            self.fail_rate and self._rng.random() < self.fail_rate
        ):
            self.injected += 1
            obs_metrics.get_metrics().counter("fault.injected_leaf_failures").inc()
            raise InjectedFault(f"injected leaf failure at dispatch call {idx}")


# ----------------------------------------------------------------- lineage
@dataclasses.dataclass
class Lineage:
    """Everything :func:`recompute_block` needs to rebuild any run block.

    Built by the scheduler at the top of a run: the retained dense
    operands (the lineage roots — references to the caller's arrays, not
    copies), the run's padded geometry, its dtype discipline, and a
    callable replaying one leaf multiply through the same backend /
    staging path the waves used. With these, every tag in the run's
    ``A:``/``B:``/``C:`` space is recomputable — and bit-identical to
    the original, because each derivation step replays the scheduler's
    own accumulation loop.
    """

    scheme: Scheme
    depth: int
    a: np.ndarray
    b: np.ndarray
    pm: int
    pk: int
    pn: int
    bam: int
    bak: int
    bbn: int
    acc_dtype: np.dtype
    stage_dtype: np.dtype
    leaf_matmul: Optional[Callable[[np.ndarray, np.ndarray], np.ndarray]] = None
    # The recursive plan whose schemas key the recompute derivations.
    # ``None`` (the historical scheme-keyed construction) means the
    # scheme's matmul plan — so lineage built before the plan layer, or
    # by callers that only know a scheme, replays identically.
    plan: Optional[BilinearPlan] = None

    def get_plan(self) -> BilinearPlan:
        if self.plan is None:
            # Cache on the (non-frozen) dataclass: recompute chains call
            # this per derivation step.
            object.__setattr__(self, "plan", matmul_plan(self.scheme))
        return self.plan

    def geometry(self, op: str) -> Tuple[int, int, int, int, np.ndarray]:
        """(root rows, root cols, block rows, block cols, dense-or-None)."""
        plan = self.get_plan()
        a_name, b_name = plan.operands
        if op == a_name:
            return self.pm, self.pk, self.bam, self.bak, self.a
        if op == b_name:
            return self.pk, self.pn, self.bak, self.bbn, self.b
        if op == plan.result:
            return self.pm, self.pn, self.bam, self.bbn, None
        raise BlockLossError(f"tag operand {op!r} is not lineage-addressable")


def _parse_tag(
    tag: str, plan: Optional[BilinearPlan] = None
) -> Tuple[str, tags.TagPath]:
    names = (
        plan.operands + (plan.result,) if plan is not None else ("A", "B", "C")
    )
    op, sep, path_s = tag.partition(":")
    if not sep or op not in names:
        raise BlockLossError(f"tag {tag!r} is not a lineage-addressable node tag")
    try:
        return op, tags.from_string(path_s)
    except ValueError as e:
        raise BlockLossError(f"tag {tag!r}: malformed path ({e})") from e


def _node_dense(
    op: str,
    path: tags.TagPath,
    lineage: Lineage,
    fetch: Callable[[BlockKey], np.ndarray],
) -> np.ndarray:
    """Assemble a node's dense padded matrix from its (fetched) blocks."""
    rows, cols, bm, bn, _ = lineage.geometry(op)
    level = len(path)
    rows, cols = rows >> level, cols >> level
    tag = f"{op}:{tags.to_string(path)}"
    out = np.empty((rows, cols), lineage.acc_dtype)
    for i in range(rows // bm):
        for j in range(cols // bn):
            out[i * bm : (i + 1) * bm, j * bn : (j + 1) * bn] = fetch((i, j, tag))
    return out


def recompute_block(
    key: BlockKey,
    lineage: Lineage,
    fetch: Callable[[BlockKey], np.ndarray],
    _depth: int = 0,
) -> np.ndarray:
    """Rebuild one block from its lineage, bit-identical to the original.

    ``fetch`` resolves any *other* block key the derivation needs — a
    :class:`RecoveringStore` passes a memoized reader that falls back to
    this function recursively, so a recompute whose parents are also
    gone walks the lineage all the way to the dense roots. The recursion
    is well-founded (divide ascends to the roots, combine descends to
    the leaves whose operands ascend), but a malformed tag space could
    loop, hence the explicit depth guard.
    """
    if _depth > 2 * lineage.depth + 8:
        raise BlockLossError(f"lineage recursion too deep recomputing {key}")
    plan = lineage.get_plan()
    a_name, b_name = plan.operands
    i, j, tag = key
    op, path = _parse_tag(tag, plan)
    level = len(path)
    rows, cols, bm, bn, dense = lineage.geometry(op)
    gr, gc = (rows >> level) // bm, (cols >> level) // bn
    if not (0 <= i < gr and 0 <= j < gc):
        raise BlockLossError(f"{key} outside the level-{level} grid {(gr, gc)}")

    if op in (a_name, b_name):
        if level == 0:
            # Root re-ingest: the same slice/zero-pad/cast as from_dense.
            chunk = dense[i * bm : (i + 1) * bm, j * bn : (j + 1) * bn]
            if chunk.shape != (bm, bn):
                full = np.zeros((bm, bn), dense.dtype)
                full[: chunk.shape[0], : chunk.shape[1]] = chunk
                chunk = full
            return np.ascontiguousarray(np.asarray(chunk, dense.dtype))
        # One divide level: the single-digit operand_terms row is exactly
        # the plan's divide-coefficient row _divide_child applied; parent
        # blocks are read through fetch (recovering recursively if they
        # are gone too).
        parent_tag = f"{op}:{tags.to_string(path[:-1])}"
        row = np.zeros(tags.Q_BASE)
        for (q,), c in plan.operand_terms((path[-1],), op):
            row[q] = c
        acc = signed_block_sum(
            lambda q: fetch(((q // 2) * gr + i, (q % 2) * gc + j, parent_tag)),
            row,
            lineage.acc_dtype,
        )
        return np.ascontiguousarray(
            np.asarray(acc.astype(lineage.acc_dtype), lineage.acc_dtype)
        )

    # op == the plan's result
    if level == lineage.depth:
        # Leaf product: re-run the leaf multiply over recomputed operands,
        # through the same staging cast and backend the wave used.
        if lineage.leaf_matmul is None:
            raise BlockLossError(
                f"cannot recompute leaf product {key}: lineage has no leaf_matmul"
            )
        a_host = _node_dense(a_name, path, lineage, fetch).astype(
            lineage.stage_dtype, copy=False
        )
        b_host = _node_dense(b_name, path, lineage, fetch).astype(
            lineage.stage_dtype, copy=False
        )
        host = np.asarray(lineage.leaf_matmul(a_host, b_host)).astype(
            lineage.acc_dtype, copy=False
        )
        return np.ascontiguousarray(
            np.asarray(
                host[i * bm : (i + 1) * bm, j * bn : (j + 1) * bn],
                lineage.acc_dtype,
            )
        )

    # Combine partial: one combine level over the rank child products.
    # The block's quadrant inside the parent picks the combine-coefficient
    # row; the single-digit combine_terms expansion per child rebuilds it.
    cgr, cgc = gr // 2, gc // 2
    kq = 2 * (i // cgr) + (j // cgc)
    ci, cj = i % cgr, j % cgc
    rank = plan.rank
    row = np.zeros(rank)
    for p in range(rank):
        for (q,), c in plan.combine_terms((p,)):
            if q == kq:
                row[p] = c
    child_tags = [
        f"{op}:{tags.to_string(tags.child(path, p, rank))}" for p in range(rank)
    ]
    acc = signed_block_sum(
        lambda p: fetch((ci, cj, child_tags[p])), row, lineage.acc_dtype
    )
    return np.ascontiguousarray(
        np.asarray(acc.astype(lineage.acc_dtype), lineage.acc_dtype)
    )


# ---------------------------------------------------------------- recovery
class RecoveringStore(BlockStore):
    """Checksum-verified store wrapper with transparent lineage recompute.

    ``put`` records crc32 metadata; ``get`` verifies it and, on a missing
    (``KeyError``) or corrupt (checksum-mismatch) block, rebuilds the
    block from lineage, re-puts it, and returns it as if nothing
    happened. A recovered block must reproduce the put-time checksum —
    the bit-exactness proof — or it counts as ``fault.recompute_mismatch``
    (surfaced as ``unrecovered_faults`` in the scheduler's stats).

    Counters: ``fault.lost_blocks``, ``fault.corrupt_blocks``,
    ``fault.recomputed_blocks``, ``fault.recompute_mismatch``,
    ``fault.unrecoverable``; every recompute is a ``fault.recompute``
    span tagged with the block's tag.
    """

    def __init__(
        self,
        inner: BlockStore,
        lineage: Optional[Lineage] = None,
        *,
        verify: bool = True,
    ) -> None:
        self.inner = inner
        self.lineage = lineage
        self.verify = verify
        self._meta: Dict[BlockKey, int] = {}
        self.lost_blocks = 0
        self.corrupt_blocks = 0
        self.recovered_blocks = 0
        self.recompute_mismatches = 0

    def put(self, key: BlockKey, block: np.ndarray) -> None:
        arr = np.ascontiguousarray(block)
        self._meta[key] = zlib.crc32(arr.tobytes())
        self.inner.put(key, arr)

    def get(self, key: BlockKey) -> np.ndarray:
        try:
            blk = self.inner.get(key)
        except KeyError:
            return self._recover(key, "lost")
        if (
            self.verify
            and key in self._meta
            and block_checksum(blk) != self._meta[key]
        ):
            return self._recover(key, "corrupt")
        return blk

    def delete(self, key: BlockKey) -> None:
        self.inner.delete(key)
        self._meta.pop(key, None)

    def __contains__(self, key: BlockKey) -> bool:
        return key in self.inner

    def keys(self) -> List[BlockKey]:
        return self.inner.keys()

    def nbytes(self) -> int:
        return self.inner.nbytes()

    def close(self) -> None:
        self._meta.clear()
        self.inner.close()

    # ------------------------------------------------------------ internals
    def _recover(self, key: BlockKey, reason: str) -> np.ndarray:
        mx = obs_metrics.get_metrics()
        if reason == "lost":
            self.lost_blocks += 1
            mx.counter("fault.lost_blocks").inc()
        else:
            self.corrupt_blocks += 1
            mx.counter("fault.corrupt_blocks").inc()
        if self.lineage is None:
            mx.counter("fault.unrecoverable").inc()
            raise BlockLossError(
                f"block {key} {reason} and no lineage is attached to recover it"
            )
        tr = obs_tracer.get_tracer()
        i, j, tag = key
        with tr.span(
            "fault.recompute", cat="fault", tag=f"{tag}[{i},{j}]", reason=reason
        ):
            # Memoized lineage reader: intermediate parents rebuilt along
            # the way serve this one recovery without being re-persisted —
            # only the requested key is re-put, so a healed store holds
            # exactly the blocks the run would have held anyway. The
            # counter guards the recompute<->fetch mutual recursion (well-
            # founded for real tag spaces, but fail loudly, not with a
            # RecursionError, if the store is handed garbage tags).
            memo: Dict[BlockKey, np.ndarray] = {}
            nested = [0]

            def fetch(k: BlockKey) -> np.ndarray:
                got = memo.get(k)
                if got is not None:
                    return got
                try:
                    blk = self.inner.get(k)
                    ok = (
                        not self.verify
                        or k not in self._meta
                        or block_checksum(blk) == self._meta[k]
                    )
                except KeyError:
                    blk, ok = None, False
                if not ok:
                    nested[0] += 1
                    try:
                        blk = recompute_block(k, self.lineage, fetch, nested[0])
                    finally:
                        nested[0] -= 1
                memo[k] = blk
                return blk

            try:
                blk = recompute_block(key, self.lineage, fetch)
            except BlockLossError:
                mx.counter("fault.unrecoverable").inc()
                raise
        want = self._meta.get(key)
        got = zlib.crc32(blk.tobytes())
        if want is not None and got != want:
            # Recovered, but not bit-identical to what was stored: surfaced
            # so the chaos gate can hold recompute to exact replay.
            self.recompute_mismatches += 1
            mx.counter("fault.recompute_mismatch").inc()
        self.recovered_blocks += 1
        mx.counter("fault.recomputed_blocks").inc()
        self.inner.put(key, blk)
        self._meta[key] = got
        return blk

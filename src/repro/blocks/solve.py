"""SPIN-style block-recursive solvers over dataflow plans.

Executes the :class:`repro.blocks.plan.DataflowPlan` family — SPIN's
Schur-complement inversion (arxiv 1801.04723) and the lower/upper
triangular solves — on the same host/device machinery the matmul waves
use. Each node of the recursion:

* **divide** — slices the plan's named sub-blocks (quadrants / row
  halves) from its host-resident operands;
* **program** — runs the plan's step list: recursions descend, ``axpy``
  steps are host signed block sums in the accumulation dtype (the same
  one-rounding-per-value discipline as the matmul divide/combine), and
  every ``matmul`` step *re-enters the matmul scheduler* — direct device
  dispatch through ``backend.matmul(kind="auto")`` when the product's
  working set fits the device budget, the full out-of-core wave pipeline
  (:func:`repro.blocks.scheduler.strassen_oot_matmul`, with chaos
  injection + lineage recovery threaded through) when it does not;
* **leaf** — at the recursion floor, one small dense device op
  (``jnp.linalg.inv`` / ``jax.scipy.linalg.solve_triangular``), staged
  in the accumulation dtype.

Because all heavy arithmetic happens inside scheduler runs, the solver
inherits their guarantees: device residency stays under ``budget_bytes``
(asserted per sub-run and reported as the aggregate peak), and seeded
``ChaosStore`` faults during an out-of-core inversion heal
bit-identically through the sub-runs' lineage recompute.

Telemetry mirrors the matmul path: one ``oot.{inverse,solve}`` root span
per run (wave lanes come from the nested scheduler runs), solver node
spans tagged with their base-2 recursion path, and one *aggregate*
:class:`~repro.blocks.scheduler.OotStats` (``op`` set from the plan)
appended to the stats rings alongside the per-multiply entries.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.blocks.blockmatrix import BlockStore
from repro.blocks.plan import (
    SPIN_INVERSE,
    TRSM_LOWER,
    TRSM_UPPER,
    DataflowPlan,
    Step,
    get_plan,
    select_part,
)
from repro.blocks.recovery import ChaosConfig
from repro.blocks.scheduler import (
    OotStats,
    _record_run,
    min_depth_for_budget,
    strassen_oot_matmul,
)
from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs_tracer

__all__ = [
    "SolveScheduler",
    "spin_inverse_oot",
    "triangular_solve_oot",
    "solver_min_depth_for_budget",
]


def _leaf_device_bytes(n: int, nrhs: int, dtype, leaf_kind: str) -> int:
    """Device bytes one dense leaf op needs (operands + result)."""
    item = np.dtype(np.result_type(np.dtype(dtype), np.float32)).itemsize
    if leaf_kind == "inv":
        return 2 * n * n * item
    # trsm: triangular factor + RHS + solution
    return (n * n + 2 * n * nrhs) * item


def solver_min_depth_for_budget(
    n: int,
    budget_bytes: int,
    dtype,
    *,
    nrhs: Optional[int] = None,
    leaf_kind: str = "inv",
    max_depth: int = 12,
) -> int:
    """Smallest solver recursion depth whose dense leaf fits the budget.

    Depth 0 is legal (the whole problem runs as one dense device op);
    every added level halves the leaf side. The inner block multiplies
    pick their own (matmul) depths against the same budget.
    """
    if budget_bytes <= 0:
        raise ValueError("budget_bytes must be positive")
    # The RHS panel splits by rows only, so its column count survives to
    # the leaves untouched.
    nrhs = n if nrhs is None else nrhs
    for depth in range(max_depth + 1):
        s = -(-n // (1 << depth))
        if _leaf_device_bytes(s, nrhs, dtype, leaf_kind) <= budget_bytes:
            return depth
    raise ValueError(
        f"no depth <= {max_depth} fits a {n}x{n} {np.dtype(dtype).name} "
        f"{leaf_kind} leaf into {budget_bytes} bytes"
    )


class SolveScheduler:
    """Budgeted executor for one dataflow plan (inversion / trsm).

    Args:
      plan: a :class:`~repro.blocks.plan.DataflowPlan` or its registry
        name (``spin_inverse`` | ``spin_trsm_lower`` | ``spin_trsm_upper``).
      depth: solver recursion depth (2^depth dense leaves down the
        Schur/forward chain). The dense leaf must fit ``budget_bytes``;
        see :func:`solver_min_depth_for_budget`.
      budget_bytes: peak device bytes — bounds the dense leaves, the
        direct device multiplies, and every nested out-of-core run.
      scheme: coefficient scheme for the nested out-of-core multiplies.
      backend: leaf-multiply routing for nested runs (default
        ``kind="auto"`` as in the matmul scheduler).
      store / store_root: block residency spec for nested out-of-core
        runs (each run owns and clears its own tag space).
      chaos / recovery / retries / retry_backoff_s / degrade: threaded
        into every nested out-of-core multiply. Each multiply derives a
        distinct deterministic chaos seed (``seed + 7919 * call_index``)
        so a fixed input replays the identical fault schedule.
    """

    def __init__(
        self,
        *,
        plan: "DataflowPlan | str",
        depth: int,
        budget_bytes: int,
        scheme: str = "strassen",
        backend=None,
        block: Optional[int] = None,
        prefetch: bool = True,
        stage_dtype=None,
        store: "str | BlockStore" = "dict",
        store_root: Optional[str] = None,
        chaos: Optional[ChaosConfig] = None,
        recovery: Optional[bool] = None,
        retries: int = 2,
        retry_backoff_s: float = 0.05,
        degrade: bool = True,
    ) -> None:
        plan = get_plan(plan) if isinstance(plan, str) else plan
        if not isinstance(plan, DataflowPlan):
            raise ValueError(
                f"plan {getattr(plan, 'name', plan)!r} is not a dataflow plan; "
                f"bilinear plans run on the wave scheduler"
            )
        if depth < 0:
            raise ValueError("solver depth must be >= 0")
        if budget_bytes <= 0:
            raise ValueError("budget_bytes must be positive")
        self.plan = plan
        self.depth = int(depth)
        self.budget_bytes = int(budget_bytes)
        self.scheme = scheme
        self.block = block
        self.prefetch = prefetch
        self.stage_dtype = stage_dtype
        self.store = store
        self.store_root = store_root
        self.chaos = chaos
        self.recovery = recovery
        self.retries = int(retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self.degrade = degrade
        if backend is None:
            from repro.core.backend import MatmulBackend

            backend = MatmulBackend(kind="auto", depth=2, min_dim=1024)
        if hasattr(backend, "configure"):
            backend.configure()
        self.backend = backend

    # ------------------------------------------------------------ execution
    def run(self, *operands: np.ndarray) -> Tuple[np.ndarray, OotStats]:
        """Execute the plan; returns (result, aggregate OotStats)."""
        import jax

        plan = self.plan
        if len(operands) != len(plan.operands):
            raise ValueError(
                f"plan {plan.name!r} takes operands "
                f"{', '.join(plan.operands)}; got {len(operands)}"
            )
        tr = obs_tracer.get_tracer()
        if not tr.enabled:
            tr = obs_tracer.Tracer(enabled=True)
        mx = obs_metrics.get_metrics()

        arrays = [np.asarray(x) for x in operands]
        primary = arrays[0]
        if primary.ndim != 2 or primary.shape[0] != primary.shape[1]:
            raise ValueError(
                f"plan {plan.name!r} needs a square primary operand, got "
                f"{primary.shape}"
            )
        n = primary.shape[0]
        nrhs = arrays[1].shape[1] if len(arrays) > 1 else n
        if len(arrays) > 1 and arrays[1].shape[0] != n:
            raise ValueError(
                f"operand shapes {primary.shape} vs {arrays[1].shape} disagree"
            )
        dtype = np.result_type(*(x.dtype for x in arrays))
        acc_dtype = np.result_type(dtype, np.float32)

        # Pad to a multiple of 2^depth with an identity extension on the
        # square operand (inv([[A,0],[0,I]]) = [[inv(A),0],[0,I]], and a
        # unit-diagonal extension keeps triangular factors invertible)
        # and zero rows on the RHS; the extension columns never couple
        # back into the result slice.
        step = 1 << self.depth
        pn = -(-n // step) * step
        if pn != n:
            ext = np.eye(pn, dtype=acc_dtype)
            ext[:n, :n] = primary.astype(acc_dtype, copy=False)
            arrays[0] = ext
            if len(arrays) > 1:
                rhs = np.zeros((pn, nrhs), acc_dtype)
                rhs[:n] = arrays[1].astype(acc_dtype, copy=False)
                arrays[1] = rhs
        # All host-side solver math runs in acc_dtype (one final rounding
        # at the output cast), matching the matmul divide/combine chains.
        arrays = [x.astype(acc_dtype, copy=False) for x in arrays]

        leaf_need = _leaf_device_bytes(
            pn >> self.depth, nrhs, dtype, plan.leaf_kind
        )
        if leaf_need > self.budget_bytes:
            raise ValueError(
                f"device budget {self.budget_bytes} B cannot hold one "
                f"{pn >> self.depth}-sized {plan.leaf_kind} leaf "
                f"({leaf_need} B); use depth >= "
                f"{solver_min_depth_for_budget(n, self.budget_bytes, dtype, nrhs=nrhs, leaf_kind=plan.leaf_kind)}"
            )

        stats = OotStats(
            m=n, k=n, n=nrhs if len(arrays) > 1 else n,
            depth=self.depth, scheme=plan.name, op=plan.op,
            leaves=0, waves=0, wave_size=0, prefetch=self.prefetch,
            stage_dtype=np.dtype(acc_dtype).name,
            budget_bytes=self.budget_bytes, per_leaf_bytes=leaf_need,
            peak_device_bytes=0,
        )
        # Mutable run state the recursion threads through: the nested
        # multiply counter (distinct chaos seeds), aggregated sub-run
        # stats, and transfer/overlap accounting.
        run = {"mul_calls": 0, "oot_runs": 0, "overlap_num": 0.0, "overlap_den": 0.0}

        root_span = tr.begin(
            f"oot.{plan.op}", cat="oot", op=plan.op, plan=plan.name,
            n=n, nrhs=stats.n, depth=self.depth,
            budget_bytes=self.budget_bytes,
        )
        try:
            result = self._run_node(
                plan, dict(zip(plan.operands, arrays)), self.depth, (),
                tr, mx, stats, run, jax, acc_dtype,
            )
        except BaseException:
            tr.end(root_span, failed=True)
            raise
        result = np.asarray(result)[:n, : stats.n].astype(dtype, copy=False)
        stats.total_s = tr.end(root_span).duration
        stats.oot_runs = run["oot_runs"]
        if run["overlap_den"] > 0.0:
            stats.overlap_efficiency = run["overlap_num"] / run["overlap_den"]
        root_span.set(
            overlap_efficiency=stats.overlap_efficiency,
            peak_device_bytes=stats.peak_device_bytes,
            h2d_bytes=stats.h2d_bytes,
            d2h_bytes=stats.d2h_bytes,
            oot_runs=run["oot_runs"],
        )
        _record_run(stats)
        return result, stats

    # ------------------------------------------------------------ internals
    def _run_node(
        self,
        plan: DataflowPlan,
        ops: Dict[str, np.ndarray],
        depth: int,
        path: Tuple[int, ...],
        tr,
        mx,
        stats: OotStats,
        run: dict,
        jax,
        acc_dtype,
    ) -> np.ndarray:
        tag = ",".join(str(d) for d in path)
        if depth == 0:
            return self._leaf(plan, ops, tag, tr, mx, stats, jax, acc_dtype)
        with tr.span(
            "solve.node", cat="oot", op=plan.op, tag=tag, level=len(path)
        ):
            syms: Dict[str, np.ndarray] = {
                sym: select_part(ops[op_name], sel)
                for sym, (op_name, sel) in plan.divide
            }
            branch = 0
            for step in plan.program:
                if step.kind == "recurse":
                    child = (
                        plan if step.plan is None else get_plan(step.plan)
                    )
                    child_ops = dict(
                        zip(child.operands, (syms[s] for s in step.args))
                    )
                    syms[step.out] = self._run_node(
                        child, child_ops, depth - 1, path + (branch,),
                        tr, mx, stats, run, jax, acc_dtype,
                    )
                    branch += 1
                elif step.kind == "matmul":
                    syms[step.out] = self._mul(
                        syms[step.args[0]], syms[step.args[1]], step.alpha,
                        tr, mx, stats, run, jax, acc_dtype,
                    )
                elif step.kind == "axpy":
                    syms[step.out] = self._axpy(step, syms, acc_dtype)
                else:
                    raise ValueError(
                        f"plan {plan.name!r}: unknown step kind {step.kind!r}"
                    )
            return self._assemble(plan, syms, ops, acc_dtype)

    @staticmethod
    def _axpy(step: Step, syms: Dict[str, np.ndarray], acc_dtype) -> np.ndarray:
        # Same accumulation discipline as signed_block_sum: ascending term
        # order, acc dtype throughout, so replays are bit-exact.
        names = [s for s, _ in step.terms]
        coefs = [c for _, c in step.terms]
        acc = np.zeros(syms[names[0]].shape, acc_dtype)
        for s, c in zip(names, coefs):
            if c == 1.0:
                acc += syms[s]
            elif c == -1.0:
                acc -= syms[s]
            elif c != 0.0:
                acc += c * syms[s]
        return acc

    @staticmethod
    def _assemble(
        plan: DataflowPlan,
        syms: Dict[str, np.ndarray],
        ops: Dict[str, np.ndarray],
        acc_dtype,
    ) -> np.ndarray:
        sel0, sym0 = plan.combine[0]
        part = syms[sym0]
        if sel0.startswith("q"):
            h, w = part.shape
            out = np.zeros((2 * h, 2 * w), acc_dtype)
            for sel, sym in plan.combine:
                q = int(sel[1])
                blk = syms[sym] if sym is not None else 0.0
                out[(q // 2) * h : (q // 2 + 1) * h, (q % 2) * w : (q % 2 + 1) * w] = blk
            return out
        # row halves
        h, w = part.shape
        out = np.zeros((2 * h, w), acc_dtype)
        for sel, sym in plan.combine:
            r = int(sel[1])
            out[r * h : (r + 1) * h] = syms[sym] if sym is not None else 0.0
        return out

    def _leaf(
        self, plan, ops, tag, tr, mx, stats: OotStats, jax, acc_dtype
    ) -> np.ndarray:
        """One dense leaf op on device, staged in the accumulation dtype."""
        import jax.numpy as jnp
        import jax.scipy.linalg as jsl

        arrays = [ops[name] for name in plan.operands]
        in_bytes = sum(x.nbytes for x in arrays)
        with tr.span(
            f"leaf.{plan.leaf_kind}", cat="oot", op=plan.op, tag=tag,
            h2d_bytes=in_bytes,
        ) as lsp:
            devs = [jax.device_put(np.ascontiguousarray(x)) for x in arrays]
            if plan.leaf_kind == "inv":
                out = jnp.linalg.inv(devs[0])
            elif plan.leaf_kind == "trsm_lower":
                out = jsl.solve_triangular(devs[0], devs[1], lower=True)
            elif plan.leaf_kind == "trsm_upper":
                out = jsl.solve_triangular(devs[0], devs[1], lower=False)
            else:
                raise ValueError(f"unknown leaf kind {plan.leaf_kind!r}")
            host = np.asarray(jax.block_until_ready(out)).astype(
                acc_dtype, copy=False
            )
            lsp.set(d2h_bytes=host.nbytes)
        stats.leaves += 1
        stats.h2d_bytes += in_bytes
        stats.d2h_bytes += host.nbytes
        stats.peak_device_bytes = max(
            stats.peak_device_bytes, in_bytes + host.nbytes
        )
        mx.counter("oot.h2d_bytes").inc(in_bytes)
        mx.counter("oot.d2h_bytes").inc(host.nbytes)
        return host

    def _mul(
        self, x: np.ndarray, y: np.ndarray, alpha: float,
        tr, mx, stats: OotStats, run: dict, jax, acc_dtype,
    ) -> np.ndarray:
        """One program multiply: device direct if it fits, else out-of-core."""
        call_idx = run["mul_calls"]
        run["mul_calls"] = call_idx + 1
        need = x.nbytes + y.nbytes + x.shape[0] * y.shape[1] * x.itemsize
        if need <= self.budget_bytes:
            from repro.core import backend as _backend

            with tr.span(
                "solve.mul", cat="oot", op=self.plan.op, mode="device",
                h2d_bytes=x.nbytes + y.nbytes,
            ):
                out = _backend.matmul(
                    jax.device_put(np.ascontiguousarray(x)),
                    jax.device_put(np.ascontiguousarray(y)),
                    self.backend,
                    site="blocks.solve",
                )
                host = np.asarray(jax.block_until_ready(out)).astype(
                    acc_dtype, copy=False
                )
            stats.h2d_bytes += x.nbytes + y.nbytes
            stats.d2h_bytes += host.nbytes
            stats.peak_device_bytes = max(stats.peak_device_bytes, need)
            mx.counter("oot.h2d_bytes").inc(x.nbytes + y.nbytes)
            mx.counter("oot.d2h_bytes").inc(host.nbytes)
        else:
            # Out-of-core: the full wave pipeline, with this run's chaos /
            # recovery / degradation policy and a per-call deterministic
            # chaos seed so fault schedules replay.
            chaos = self.chaos
            if chaos is not None:
                chaos = dataclasses.replace(
                    chaos, seed=chaos.seed + 7919 * (call_idx + 1)
                )
            mm_depth = min_depth_for_budget(
                x.shape[0], x.shape[1], y.shape[1], self.budget_bytes,
                np.dtype(x.dtype), pipelined=self.prefetch,
            ) if self.prefetch else min_depth_for_budget(
                x.shape[0], x.shape[1], y.shape[1], self.budget_bytes,
                np.dtype(x.dtype),
            )
            host, sub = strassen_oot_matmul(
                x, y,
                depth=mm_depth, budget_bytes=self.budget_bytes,
                scheme=self.scheme, backend=self.backend, block=self.block,
                prefetch=self.prefetch, stage_dtype=self.stage_dtype,
                store=self.store, store_root=self.store_root,
                chaos=chaos, recovery=self.recovery, retries=self.retries,
                retry_backoff_s=self.retry_backoff_s, degrade=self.degrade,
            )
            host = host.astype(acc_dtype, copy=False)
            self._fold_substats(stats, sub, run)
        if alpha == -1.0:
            host = -host
        elif alpha != 1.0:
            host = alpha * host
        return host

    @staticmethod
    def _fold_substats(stats: OotStats, sub: OotStats, run: dict) -> None:
        """Aggregate a nested out-of-core run into the solver's stats."""
        run["oot_runs"] += 1
        stats.leaves += sub.leaves
        stats.waves += sub.waves
        stats.wave_size = max(stats.wave_size, sub.wave_size)
        stats.h2d_bytes += sub.h2d_bytes
        stats.d2h_bytes += sub.d2h_bytes
        stats.peak_device_bytes = max(
            stats.peak_device_bytes, sub.peak_device_bytes
        )
        stats.host_store_peak_bytes = max(
            stats.host_store_peak_bytes, sub.host_store_peak_bytes
        )
        stats.divide_s += sub.divide_s
        stats.leaf_s += sub.leaf_s
        stats.combine_s += sub.combine_s
        stats.stage_s += sub.stage_s
        stats.fetch_s += sub.fetch_s
        stats.leaf_retries += sub.leaf_retries
        stats.recovered_blocks += sub.recovered_blocks
        stats.lost_blocks += sub.lost_blocks
        stats.corrupt_blocks += sub.corrupt_blocks
        stats.injected_faults += sub.injected_faults
        stats.unrecovered_faults += sub.unrecovered_faults
        stats.degrades += sub.degrades
        stats.degrade_events.extend(sub.degrade_events)
        # Keep the *worst* rung any sub-run completed on.
        order = ["pipeline", "sync", "halved-wave", "deeper"]
        if order.index(sub.rung) > order.index(stats.rung):
            stats.rung = sub.rung
        # Transfer-time-weighted overlap aggregate across sub-runs.
        w = sub.stage_s + sub.fetch_s
        run["overlap_num"] += sub.overlap_efficiency * w
        run["overlap_den"] += w


def spin_inverse_oot(
    a: np.ndarray,
    *,
    depth: Optional[int] = None,
    budget_bytes: int,
    scheme: str = "strassen",
    backend=None,
    block: Optional[int] = None,
    prefetch: bool = True,
    stage_dtype=None,
    store: "str | BlockStore" = "dict",
    store_root: Optional[str] = None,
    chaos: Optional[ChaosConfig] = None,
    recovery: Optional[bool] = None,
    retries: int = 2,
    retry_backoff_s: float = 0.05,
    degrade: bool = True,
) -> Tuple[np.ndarray, OotStats]:
    """Block-recursive inverse of a square matrix under a device budget.

    ``depth=None`` picks the smallest depth whose dense leaf inverse fits
    the budget (the nested multiplies size themselves independently).
    The leading principal blocks must be invertible — guaranteed for the
    SPD inputs this path targets (whitening / solver workloads).
    """
    a = np.asarray(a)
    if depth is None:
        depth = solver_min_depth_for_budget(
            a.shape[0], budget_bytes, a.dtype, leaf_kind="inv"
        )
    sched = SolveScheduler(
        plan=SPIN_INVERSE, depth=depth, budget_bytes=budget_bytes,
        scheme=scheme, backend=backend, block=block, prefetch=prefetch,
        stage_dtype=stage_dtype, store=store, store_root=store_root,
        chaos=chaos, recovery=recovery, retries=retries,
        retry_backoff_s=retry_backoff_s, degrade=degrade,
    )
    return sched.run(a)


def triangular_solve_oot(
    l: np.ndarray,
    b: np.ndarray,
    *,
    lower: bool = True,
    depth: Optional[int] = None,
    budget_bytes: int,
    scheme: str = "strassen",
    backend=None,
    block: Optional[int] = None,
    prefetch: bool = True,
    stage_dtype=None,
    store: "str | BlockStore" = "dict",
    store_root: Optional[str] = None,
    chaos: Optional[ChaosConfig] = None,
    recovery: Optional[bool] = None,
    retries: int = 2,
    retry_backoff_s: float = 0.05,
    degrade: bool = True,
) -> Tuple[np.ndarray, OotStats]:
    """Solve ``T @ X = B`` for triangular ``T`` under a device budget."""
    l = np.asarray(l)
    b = np.asarray(b)
    plan = TRSM_LOWER if lower else TRSM_UPPER
    if depth is None:
        depth = solver_min_depth_for_budget(
            l.shape[0], budget_bytes, np.result_type(l.dtype, b.dtype),
            nrhs=b.shape[1], leaf_kind=plan.leaf_kind,
        )
    sched = SolveScheduler(
        plan=plan, depth=depth, budget_bytes=budget_bytes,
        scheme=scheme, backend=backend, block=block, prefetch=prefetch,
        stage_dtype=stage_dtype, store=store, store_root=store_root,
        chaos=chaos, recovery=recovery, retries=retries,
        retry_backoff_s=retry_backoff_s, degrade=degrade,
    )
    return sched.run(l, b)

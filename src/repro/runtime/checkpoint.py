"""Fault-tolerant checkpointing: atomic writes, manifests, keep-last-k.

Layout: <dir>/step_<n>/  arrays.npz  manifest.json
Writes go to a temp directory then os.replace() — a crash mid-write never
corrupts the latest checkpoint (restore scans for the newest COMPLETE
manifest). The manifest records step, mesh shape, tree structure, and a
sha256 digest of the array payload; ``load_pytree`` re-hashes the payload
and raises :class:`CheckpointError` on any mismatch, torn write, or
partial checkpoint instead of silently loading corrupt parameters.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import tracer as obs_tracer

__all__ = ["CheckpointError", "CheckpointManager", "save_pytree", "load_pytree"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


class CheckpointError(RuntimeError):
    """A checkpoint is missing, torn, partial, or fails digest verification."""


def _digest_file(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return "sha256:" + h.hexdigest()


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out.append((key, leaf))
    return out


def save_pytree(tree, directory: str, *, step: int, extra: Optional[Dict] = None) -> str:
    """Atomic save of a pytree; returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory)
    span = obs_tracer.get_tracer().begin(
        "ckpt.save", cat="runtime", track="runtime", step=step
    )
    try:
        arrays = {}
        for key, leaf in _flatten_with_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype == jnp.bfloat16:
                arrays[key + "::bf16"] = arr.view(np.uint16)
            else:
                arrays[key] = arr
        np.savez(os.path.join(tmp, _ARRAYS), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_arrays": len(arrays),
            "devices": jax.device_count(),
            "digest": _digest_file(os.path.join(tmp, _ARRAYS)),
            "extra": extra or {},
            "complete": True,
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        obs_tracer.get_tracer().end(
            span, n_arrays=len(arrays), bytes=sum(a.nbytes for a in arrays.values())
        )
        return final
    except BaseException:
        obs_tracer.get_tracer().end(span, failed=True)
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_pytree(template, path: str):
    """Load arrays into the structure of ``template`` (shapes must match).

    Verifies the checkpoint before handing parameters back: the manifest
    must exist, parse, and be marked complete; when it carries a payload
    digest (checkpoints from older versions may not), the array file is
    re-hashed and compared. Any violation — missing files, torn JSON,
    digest mismatch, keys absent from the payload — raises
    :class:`CheckpointError` naming the failure.
    """
    with obs_tracer.get_tracer().span(
        "ckpt.load", cat="runtime", track="runtime", path=os.path.basename(path)
    ):
        manifest_path = os.path.join(path, _MANIFEST)
        arrays_path = os.path.join(path, _ARRAYS)
        if not os.path.exists(manifest_path):
            raise CheckpointError(f"checkpoint {path}: missing {_MANIFEST}")
        try:
            with open(manifest_path) as f:
                manifest = json.load(f)
        except (ValueError, json.JSONDecodeError) as e:
            raise CheckpointError(
                f"checkpoint {path}: torn manifest ({e})"
            ) from e
        if not manifest.get("complete"):
            raise CheckpointError(
                f"checkpoint {path}: manifest not marked complete "
                "(partial or interrupted write)"
            )
        if not os.path.exists(arrays_path):
            raise CheckpointError(f"checkpoint {path}: missing {_ARRAYS}")
        want = manifest.get("digest")
        if want is not None:
            got = _digest_file(arrays_path)
            if got != want:
                raise CheckpointError(
                    f"checkpoint {path}: array payload digest mismatch "
                    f"(manifest {want}, file {got}) — corrupt checkpoint"
                )
        try:
            data = np.load(arrays_path)
        except (ValueError, OSError) as e:
            raise CheckpointError(
                f"checkpoint {path}: unreadable {_ARRAYS} ({e})"
            ) from e
        by_key = {}
        for key in data.files:
            if key.endswith("::bf16"):
                by_key[key[: -len("::bf16")]] = data[key].view(jnp.bfloat16)
            else:
                by_key[key] = data[key]
        leaves = []
        for key, leaf in _flatten_with_paths(template):
            if key not in by_key:
                raise CheckpointError(
                    f"checkpoint {path}: payload missing array {key!r} "
                    "(partial checkpoint?)"
                )
            arr = by_key[key]
            if arr.shape != tuple(leaf.shape):
                raise CheckpointError(
                    f"checkpoint {path}: shape mismatch for {key!r}: "
                    f"saved {arr.shape}, template {tuple(leaf.shape)}"
                )
            leaves.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves
        )


@dataclasses.dataclass
class CheckpointManager:
    """save-every / keep-last-k / resume-latest policy around save/load."""

    directory: str
    save_every: int = 100
    keep_last: int = 3

    def maybe_save(self, tree, step: int, extra: Optional[Dict] = None) -> Optional[str]:
        if step % self.save_every:
            return None
        path = save_pytree(tree, self.directory, step=step, extra=extra)
        self._gc()
        return path

    def _steps(self) -> List[int]:
        if not os.path.isdir(self.directory):
            return []
        out = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name, _MANIFEST)
            if name.startswith("step_") and os.path.exists(full):
                try:
                    with open(full) as f:
                        if json.load(f).get("complete"):
                            out.append(int(name.split("_")[1]))
                except (ValueError, json.JSONDecodeError):
                    continue  # torn manifest -> not a valid checkpoint
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore_latest(self, template) -> Tuple[Optional[int], Any]:
        """(step, tree) of the newest complete checkpoint, or (None, template)."""
        step = self.latest_step()
        if step is None:
            return None, template
        path = os.path.join(self.directory, f"step_{step:08d}")
        return step, load_pytree(template, path)

    def manifest(self, step: int) -> Dict:
        with open(os.path.join(self.directory, f"step_{step:08d}", _MANIFEST)) as f:
            return json.load(f)

    def _gc(self):
        steps = self._steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

"""Fault-tolerant checkpointing: atomic writes, manifests, keep-last-k.

Layout: <dir>/step_<n>/  arrays.npz  manifest.json
Writes go to a temp directory then os.replace() — a crash mid-write never
corrupts the latest checkpoint (restore scans for the newest COMPLETE
manifest). The manifest records step, mesh shape, and tree structure so an
elastic restart can validate (and re-mesh) before loading.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import tracer as obs_tracer

__all__ = ["CheckpointManager", "save_pytree", "load_pytree"]

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))) for p in path)
        out.append((key, leaf))
    return out


def save_pytree(tree, directory: str, *, step: int, extra: Optional[Dict] = None) -> str:
    """Atomic save of a pytree; returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory)
    span = obs_tracer.get_tracer().begin(
        "ckpt.save", cat="runtime", track="runtime", step=step
    )
    try:
        arrays = {}
        for key, leaf in _flatten_with_paths(tree):
            arr = np.asarray(jax.device_get(leaf))
            if arr.dtype == jnp.bfloat16:
                arrays[key + "::bf16"] = arr.view(np.uint16)
            else:
                arrays[key] = arr
        np.savez(os.path.join(tmp, _ARRAYS), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_arrays": len(arrays),
            "devices": jax.device_count(),
            "extra": extra or {},
            "complete": True,
        }
        with open(os.path.join(tmp, _MANIFEST), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        obs_tracer.get_tracer().end(
            span, n_arrays=len(arrays), bytes=sum(a.nbytes for a in arrays.values())
        )
        return final
    except BaseException:
        obs_tracer.get_tracer().end(span, failed=True)
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def load_pytree(template, path: str):
    """Load arrays into the structure of ``template`` (shapes must match)."""
    with obs_tracer.get_tracer().span(
        "ckpt.load", cat="runtime", track="runtime", path=os.path.basename(path)
    ):
        data = np.load(os.path.join(path, _ARRAYS))
        by_key = {}
        for key in data.files:
            if key.endswith("::bf16"):
                by_key[key[: -len("::bf16")]] = data[key].view(jnp.bfloat16)
            else:
                by_key[key] = data[key]
        leaves = []
        for key, leaf in _flatten_with_paths(template):
            arr = by_key[key]
            assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(template), leaves
        )


@dataclasses.dataclass
class CheckpointManager:
    """save-every / keep-last-k / resume-latest policy around save/load."""

    directory: str
    save_every: int = 100
    keep_last: int = 3

    def maybe_save(self, tree, step: int, extra: Optional[Dict] = None) -> Optional[str]:
        if step % self.save_every:
            return None
        path = save_pytree(tree, self.directory, step=step, extra=extra)
        self._gc()
        return path

    def _steps(self) -> List[int]:
        if not os.path.isdir(self.directory):
            return []
        out = []
        for name in os.listdir(self.directory):
            full = os.path.join(self.directory, name, _MANIFEST)
            if name.startswith("step_") and os.path.exists(full):
                try:
                    with open(full) as f:
                        if json.load(f).get("complete"):
                            out.append(int(name.split("_")[1]))
                except (ValueError, json.JSONDecodeError):
                    continue  # torn manifest -> not a valid checkpoint
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore_latest(self, template) -> Tuple[Optional[int], Any]:
        """(step, tree) of the newest complete checkpoint, or (None, template)."""
        step = self.latest_step()
        if step is None:
            return None, template
        path = os.path.join(self.directory, f"step_{step:08d}")
        return step, load_pytree(template, path)

    def manifest(self, step: int) -> Dict:
        with open(os.path.join(self.directory, f"step_{step:08d}", _MANIFEST)) as f:
            return json.load(f)

    def _gc(self):
        steps = self._steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

"""Elastic scaling + fault tolerance + straggler policy.

At 1000+ nodes the failure model is: a host (or its TPU slice) disappears;
the job must resume on the survivors. TPU SPMD programs are synchronous, so
the recovery unit is the whole job, and the mechanism is:

  1. Checkpoint/restart (runtime/checkpoint.py): atomic, manifest-gated.
  2. Re-mesh: on restart, :func:`plan_mesh` fits the canonical logical mesh
     to the surviving device count — the data axis shrinks/grows (pure DP
     change, zero resharding of the TP dimension), the model axis stays
     fixed so parameter shards remain valid. global_batch is preserved by
     raising grad-accumulation (:func:`rebalance_accum`).
  3. Straggler mitigation: synchronous SPMD turns a straggler into a global
     slowdown, not an error. Policy implemented in :class:`StragglerMonitor`:
     per-step wall-clock is tracked against a rolling median; sustained
     degradation beyond ``threshold`` flags the job for checkpoint+restart
     (at which point the slow host is dropped by the scheduler and
     plan_mesh re-fits). This is MaxText/Borg-style "fail fast and remesh",
     which beats in-band work-stealing on TPUs where collectives are
     topology-locked.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Optional, Tuple

from repro.obs import metrics as obs_metrics
from repro.obs import tracer as obs_tracer

__all__ = ["plan_mesh", "rebalance_accum", "StragglerMonitor", "ElasticError"]


class ElasticError(RuntimeError):
    pass


def plan_mesh(
    n_devices: int,
    *,
    model_parallel: int = 16,
    pods: Optional[int] = None,
) -> Tuple[Tuple[int, ...], Tuple[str, ...]]:
    """Fit the canonical (pod, data, model) mesh to a device count.

    The model axis is immutable (parameter shards must stay valid across
    restarts); the data axis absorbs all elasticity. Returns (shape, axes).
    """
    if n_devices % model_parallel:
        raise ElasticError(
            f"{n_devices} devices not divisible by model_parallel={model_parallel}"
        )
    rest = n_devices // model_parallel
    if pods and pods > 1:
        if rest % pods:
            raise ElasticError(f"data x pod mismatch: {rest} vs pods={pods}")
        return (pods, rest // pods, model_parallel), ("pod", "data", "model")
    return (rest, model_parallel), ("data", "model")


def rebalance_accum(
    global_batch: int, seq_len: int, n_data_shards: int, *, per_shard_tokens_budget: int
) -> int:
    """Grad-accumulation steps preserving global batch on fewer devices."""
    per_shard = (global_batch // max(n_data_shards, 1)) * seq_len
    accum = max(1, -(-per_shard // per_shard_tokens_budget))
    while global_batch % (accum * n_data_shards) and accum < global_batch:
        accum += 1
    return accum


@dataclasses.dataclass
class StragglerMonitor:
    """Rolling-median step-time watchdog; flags sustained slowdowns.

    The flag has two components, both surfaced as obs gauges every step
    so the slowdown is diagnosable from the metrics stream alone:

      ``elastic.step_over_median`` — the *median* signal: last step's
        wall-clock as a multiple of the rolling median (> ``threshold``
        counts the step as slow).
      ``elastic.slow_streak`` — the *streak* signal: consecutive slow
        steps so far (>= ``patience`` raises the flag).

    :meth:`flag_reason` returns the same pair for the caller that acts
    on the flag (checkpoint + clean exit in ``launch/train.py``).
    """

    window: int = 32
    threshold: float = 2.0  # x median
    patience: int = 8  # consecutive slow steps before flagging

    def __post_init__(self):
        self._times: Deque[float] = deque(maxlen=self.window)
        self._slow_streak = 0
        self._span: Optional[obs_tracer.Span] = None
        self._step_idx = 0
        self._last_ratio = 0.0

    def start_step(self):
        # begin() hands back a timed Span even when tracing is disabled, so
        # the watchdog math below is independent of the tracer's enabled bit.
        self._span = obs_tracer.get_tracer().begin(
            "train.step", cat="train", track="train", step=self._step_idx
        )

    def end_step(self) -> bool:
        """Record one step; True -> checkpoint + restart recommended."""
        assert self._span is not None, "end_step without start_step"
        obs_tracer.get_tracer().end(self._span)
        dt = self._span.duration
        self._span = None
        self._step_idx += 1
        median = sorted(self._times)[len(self._times) // 2] if self._times else dt
        self._times.append(dt)
        self._last_ratio = dt / median if median > 0 else 0.0
        if len(self._times) >= self.window // 2 and dt > self.threshold * median:
            self._slow_streak += 1
        else:
            self._slow_streak = 0
        mx = obs_metrics.get_metrics()
        mx.gauge("elastic.step_over_median").set(self._last_ratio)
        mx.gauge("elastic.slow_streak").set(self._slow_streak)
        flagged = self._slow_streak >= self.patience
        if flagged:
            mx.counter("elastic.straggler_flags").inc()
            obs_tracer.get_tracer().event(
                "elastic.straggler_flag", cat="train", track="train",
                median=self._last_ratio, streak=self._slow_streak,
            )
        return flagged

    def flag_reason(self) -> dict:
        """The flag's evidence: {'median': last step / rolling median,
        'streak': consecutive slow steps}."""
        return {"median": self._last_ratio, "streak": self._slow_streak}

    @property
    def median_step_time(self) -> float:
        return sorted(self._times)[len(self._times) // 2] if self._times else 0.0

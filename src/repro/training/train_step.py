"""Train step: grad + AdamW update, with microbatched gradient accumulation.

The step is a pure function of (TrainState, batch) so it lowers cleanly for
the dry-run, jit-compiles once, and donates its inputs. Microbatching
splits the per-step batch into ``accum_steps`` slices scanned sequentially
— activation memory scales with the slice, not the global batch (the
standard large-scale recipe; combined with per-group remat in the model).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.sharding import constrain
from repro.obs import tracer as obs_tracer
from repro.optim.adamw import AdamWConfig, OptState, apply_updates, init_opt_state

__all__ = ["TrainState", "init_train_state", "make_train_step"]


class TrainState(NamedTuple):
    params: Any
    opt: OptState


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, key) -> TrainState:
    params = M.init_params(cfg, key)
    return TrainState(params=params, opt=init_opt_state(params, opt_cfg))


def _reshape_microbatches(batch: Dict[str, jax.Array], accum: int):
    """(GB, ...) -> (accum, GB/accum, ...) with the microbatch dim sharded.

    Reshape (a STATIC split) instead of dynamic_slice: slicing a sharded
    batch axis at a traced offset forces GSPMD to all-gather the whole
    batch onto every device — the reshape keeps shard boundaries aligned
    so each accumulation step touches only local data.
    """

    def rs(x):
        mb = x.shape[0] // accum
        out = x.reshape(accum, mb, *x.shape[1:])
        return constrain(out, None, "batch", *([None] * (out.ndim - 2)))

    return jax.tree.map(rs, batch)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    accum_steps: int = 1,
):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_of(params, mb):
        return M.loss_fn(params, mb, cfg)

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        # The step is jitted, so this span fires once per compile (trace
        # time), not per executed step — per-step wall clock lives in
        # StragglerMonitor's train.step spans.
        with obs_tracer.get_tracer().span(
            "train.step.trace", cat="train", track="train", accum=accum_steps
        ):
            return _train_step_body(state, batch)

    def _train_step_body(state: TrainState, batch: Dict[str, jax.Array]):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            micro = _reshape_microbatches(batch, accum_steps)

            def accum_body(carry, mb):
                g_acc, l_acc = carry
                (l, _), g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                accum_body, (g0, jnp.zeros((), jnp.float32)), micro
            )
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss_sum / accum_steps
            metrics = {"loss": loss}

        params, opt, opt_metrics = apply_updates(
            state.params, grads, state.opt, opt_cfg
        )
        metrics = {**metrics, **opt_metrics}
        metrics = {k: v for k, v in metrics.items() if v.ndim == 0}
        return TrainState(params=params, opt=opt), metrics

    return train_step

"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Model code never names mesh axes directly; it tags tensor dims with logical
names ("batch", "heads", "d_ff", ...). A :class:`ShardingRules` maps each
logical name to a tuple of mesh axes. Because the production mesh shape is
fixed (16x16 and 2x16x16) while arch head counts vary (1..64 kv heads),
:meth:`ShardingRules.spec` drops any mapping whose dim is not divisible by
the mesh-axis product — jit in_shardings reject uneven dims, and uneven
activation shardings waste pad compute. The fallback is recorded so the
roofline notes can attribute replication cost.

The active mesh+rules are held in a contextvar set by the launcher
(:func:`use_sharding`); :func:`constrain` is a no-op outside that context,
so single-device smoke tests run the exact same model code.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "ShardingRules",
    "DEFAULT_RULES",
    "use_sharding",
    "constrain",
    "current_mesh",
    "make_named_sharding",
]

Axes = Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Map logical dim names -> mesh axis tuples.

    Defaults implement DP over (pod, data), TP over model:
      batch    -> (pod, data)   data parallel / FSDP batch axis
      fsdp     -> (data,)       parameter dim sharded ZeRO-style
      heads    -> (model,)      attention-head tensor parallelism
      kv_heads -> (model,)      falls back when kv heads % 16 != 0
      d_ff     -> (model,)      MLP tensor parallelism
      vocab    -> (model,)      embedding/logits TP
      experts  -> (model,)      expert parallelism for MoE
      seq      -> ()            sequence kept local by default
      seq_sp   -> (pod, data)   sequence parallelism for batch=1 cells
    """

    rules: Dict[str, Axes] = dataclasses.field(
        default_factory=lambda: {
            "batch": ("pod", "data"),
            "fsdp": ("data",),
            "heads": ("model",),
            "kv_heads": ("model",),
            "d_ff": ("model",),
            "vocab": ("model",),
            "experts": ("model",),
            "d_model": (),
            "head_dim": (),
            "seq": (),
            "seq_sp": ("pod", "data"),
            "cache_seq": ("model",),  # KV-cache fallback when kv_heads won't divide
            "ep_flat": ("pod", "data", "model"),  # flattened (group, expert) dim
            "layers": (),
            "state": ("model",),
        }
    )

    def axes_for(
        self, mesh: Mesh, logical: Optional[str], dim: int, *, allow_uneven: bool = False
    ) -> Optional[Axes]:
        """Mesh axes for one logical dim, or None when not shardable.

        allow_uneven: jit INPUT shardings must divide evenly, but internal
        with_sharding_constraint tolerates GSPMD padding — activations pass
        True so e.g. 24 heads shard over 16 (25% pad beats 16x replication).
        """
        if logical is None:
            return None
        axes = tuple(a for a in self.rules.get(logical, ()) if a in mesh.shape)
        if not axes:
            return None
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        if dim % size != 0:
            if not (allow_uneven and dim > size // 2):
                return None  # replicate instead of (heavy) padding
        return axes

    def spec(
        self,
        mesh: Mesh,
        logical_axes: Sequence[Optional[str]],
        shape: Sequence[int],
        *,
        allow_uneven: bool = False,
    ) -> P:
        assert len(logical_axes) == len(shape), (logical_axes, shape)
        parts = []
        used: set = set()
        for name, dim in zip(logical_axes, shape):
            axes = self.axes_for(mesh, name, dim, allow_uneven=allow_uneven)
            if axes is None or any(a in used for a in axes):
                parts.append(None)
            else:
                used.update(axes)
                parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)


DEFAULT_RULES = ShardingRules()

_CTX: contextvars.ContextVar[Optional[Tuple[Mesh, ShardingRules]]] = contextvars.ContextVar(
    "repro_sharding_ctx", default=None
)


@contextlib.contextmanager
def use_sharding(mesh: Optional[Mesh], rules: ShardingRules = DEFAULT_RULES):
    """Activate mesh+rules for all constrain() calls in model code."""
    token = _CTX.set((mesh, rules) if mesh is not None else None)
    try:
        yield
    finally:
        _CTX.reset(token)


def current_mesh() -> Optional[Mesh]:
    ctx = _CTX.get()
    return ctx[0] if ctx else None


def constrain(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical names; identity with no context.

    Activations allow uneven (padded) shardings — inputs use spec() with
    allow_uneven=False via make_named_sharding.
    """
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.spec(mesh, logical_axes, x.shape, allow_uneven=True)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def make_named_sharding(
    mesh: Mesh, logical_axes: Sequence[Optional[str]], shape: Sequence[int],
    rules: ShardingRules = DEFAULT_RULES,
) -> NamedSharding:
    """NamedSharding for jit in_shardings/out_shardings (divisible only)."""
    return NamedSharding(mesh, rules.spec(mesh, logical_axes, shape))

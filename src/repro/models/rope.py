"""Rotary position embeddings: standard RoPE and qwen2-vl style M-RoPE.

M-RoPE (multimodal RoPE) splits each head's rotary dims into three
sections (temporal / height / width), each rotated by its own position
stream. The vision frontend is a stub here (the assignment specifies the
backbone only), so positions arrive precomputed as (B, S, 3); for pure
text all three streams are equal and M-RoPE reduces exactly to RoPE —
asserted in tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["rope_frequencies", "apply_rope", "apply_mrope"]


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies (head_dim/2,) in fp32."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Rotate pairs (x[..., :half], x[..., half:]) by angles (..., half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """x: (B, H, S, d); positions: (B, S) int."""
    inv = rope_frequencies(x.shape[-1], theta)  # (half,)
    angles = positions.astype(jnp.float32)[:, None, :, None] * inv  # (B, 1, S, half)
    return _rotate(x.astype(jnp.float32), angles).astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    theta: float,
    sections: Tuple[int, int, int],
) -> jax.Array:
    """M-RoPE: x (B, H, S, d); positions (B, S, 3) [t, h, w] streams.

    sections partition the half-dim: sum(sections) == d // 2. Each section's
    frequency band uses its own position stream — the qwen2-vl layout where
    the bands are interleaved by section over the ORIGINAL frequency order.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    inv = rope_frequencies(x.shape[-1], theta)  # (half,)
    pos = positions.astype(jnp.float32)  # (B, S, 3)
    # Build per-frequency position selection: frequency slot j belongs to
    # section s(j); use stream s(j)'s positions.
    stream_idx = jnp.concatenate(
        [jnp.full((n,), i, dtype=jnp.int32) for i, n in enumerate(sections)]
    )  # (half,)
    pos_per_freq = jnp.take_along_axis(
        pos[:, :, :], stream_idx[None, None, :], axis=2
    )  # (B, S, half)
    angles = pos_per_freq[:, None, :, :] * inv  # (B, 1, S, half)
    return _rotate(x.astype(jnp.float32), angles).astype(x.dtype)

"""Primitive layers: linear (backend-routed), norms, embeddings.

Every dense projection funnels through :func:`linear`, which routes the
matmul to the configured backend — this is where the paper's Strassen
engine plugs into the model stack.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.backend import MatmulBackend, NAIVE_BACKEND, matmul as backend_matmul
from repro.models.sharding import constrain

__all__ = ["linear", "rmsnorm", "layernorm", "embed", "unembed", "init_linear"]


def init_linear(key, d_in: int, shape_out, dtype, *, bias: bool = False, scale: Optional[float] = None):
    """He-style init for a (d_in, *shape_out) projection stored 2D+."""
    if isinstance(shape_out, int):
        shape_out = (shape_out,)
    fan_out = 1
    for s in shape_out:
        fan_out *= s
    scale = scale if scale is not None else d_in**-0.5
    w = jax.random.normal(key, (d_in, *shape_out), dtype=jnp.float32) * scale
    params = {"w": w.astype(dtype)}
    if bias:
        params["b"] = jnp.zeros(shape_out, dtype=dtype)
    return params


def linear(
    params,
    x: jax.Array,
    backend: MatmulBackend = NAIVE_BACKEND,
    w_logical=None,
    site: Optional[str] = None,
) -> jax.Array:
    """y = x @ w (+ b), with w (d_in, *out_dims) flattened for routing.

    The backend decides per-shape whether this projection runs as a naive
    XLA matmul or through the Strassen pipeline (paper integration point).
    w_logical (in, out) logical dim names keep the Strassen levels pinned
    to the layer's tensor-parallel layout. ``site`` tags the projection for
    per-call-site autotune cache keys and decision telemetry.
    """
    w = params["w"]
    d_in = w.shape[0]
    out_dims = w.shape[1:]
    w2 = w.reshape(d_in, -1)
    y = backend_matmul(x, w2, backend, w_logical=w_logical, site=site)
    y = y.reshape(*x.shape[:-1], *out_dims)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def rmsnorm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + params["scale"].astype(jnp.float32))
    return y.astype(x.dtype)


def layernorm(params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def embed(params, tokens: jax.Array) -> jax.Array:
    """Token embedding lookup: (B, S) int -> (B, S, D)."""
    out = jnp.take(params["embedding"], tokens, axis=0)
    return constrain(out, "batch", "seq", "d_model")


def unembed(params, x: jax.Array, *, tied: bool = False, softcap: float = 0.0) -> jax.Array:
    """(B, S, D) -> (B, S, V) logits."""
    w = params["embedding"].T if tied else params["unembedding"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
    if softcap > 0.0:
        logits = jnp.tanh(logits / softcap) * softcap
    return constrain(logits, "batch", "seq", "vocab")

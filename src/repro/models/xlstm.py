"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Sequential stabilized recurrences after arXiv:2405.04517. The recurrence
itself is not a matmul, so the paper's Strassen technique is inapplicable
here (DESIGN.md §Arch-applicability); the q/k/v/out projections still
route through the configured backend.

Both blocks run as a lax.scan over time for training/prefill (compact HLO,
state never materialized over S) and expose a single-step path for decode
whose state pytree is the serving "KV cache" equivalent — O(1) in sequence
length, which is why xlstm-1.3b is a long_500k-eligible architecture.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_linear, linear
from repro.models.sharding import constrain

__all__ = [
    "init_mlstm",
    "mlstm_block",
    "init_mlstm_state",
    "init_slstm",
    "slstm_block",
    "init_slstm_state",
]


# ----------------------------------------------------------------- mLSTM
def init_mlstm(key, cfg: ModelConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    qk, dv = cfg.mlstm_qk_dim, cfg.mlstm_v_dim
    keys = jax.random.split(key, 7)
    # Wide projections stored flat (divisible by the model axis); reshaped
    # to (B, S, H, *) inside the block.
    return {
        "wq": init_linear(keys[0], d, (qk,), dtype),
        "wk": init_linear(keys[1], d, (qk,), dtype),
        "wv": init_linear(keys[2], d, (dv,), dtype),
        "wi": init_linear(keys[3], d, (h,), jnp.float32, bias=True),
        "wf": init_linear(keys[4], d, (h,), jnp.float32, bias=True),
        "wo": init_linear(keys[5], d, (dv,), dtype),
        "out": init_linear(keys[6], dv, (d,), dtype, scale=dv**-0.5),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    h = cfg.n_heads
    dk, dv = cfg.mlstm_qk_dim // h, cfg.mlstm_v_dim // h
    return {
        "C": jnp.zeros((batch, h, dk, dv), jnp.float32),
        "n": jnp.zeros((batch, h, dk), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_chunkwise(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    i_pre: jax.Array,
    f_pre: jax.Array,
    state: dict,
    chunk: int,
) -> Tuple[dict, jax.Array]:
    """Chunkwise-parallel mLSTM — exact (same stabilizers as the scan).

    The sequential form writes the (dk x dv) matrix state EVERY timestep:
    O(S * dk * dv) HBM traffic per head, which makes xlstm train_4k the
    most memory-bound cell in the roofline table. The chunkwise form
    (cf. the xLSTM paper's kernels) writes state once per chunk and turns
    the intra-chunk work into (L x L) matmuls for the MXU:

      B_t = cumsum(log f);  m_t = max(m_prev + b_t, b_t + cummax(li - b))
      W_ij = exp(b_i - b_j + li_j - m_i)   (j <= i, the intra decay matrix)
      h_i  = [e_i q_i C_prev + ((q K^T) o W) V] / max(|den_i|, exp(-m_i))

    with e_i = exp(m_prev + b_i - m_i). The per-row stabilizer m_i equals
    the sequential recurrence's m_t exactly (tests assert equivalence).

    Shapes: q,k (B,H,S,dk); v (B,H,S,dv); i_pre,f_pre (B,H,S).
    Returns (new_state, h (B,H,S,dv)).
    """
    b, h, s, dk = q.shape
    dv = v.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc, L = s // chunk, chunk

    # chunked views: (B, H, nc, L, *)
    qc = q.reshape(b, h, nc, L, dk)
    kc = k.reshape(b, h, nc, L, dk)
    vc = v.reshape(b, h, nc, L, dv)
    li = i_pre.reshape(b, h, nc, L)
    lf = jax.nn.log_sigmoid(f_pre).reshape(b, h, nc, L)

    bcum = jnp.cumsum(lf, axis=-1)  # (B,H,nc,L) local log-decay prefix
    u = li - bcum
    cummax_u = jax.lax.cummax(u, axis=3)

    tri = jnp.tril(jnp.ones((L, L), bool))  # j <= i

    def chunk_step(carry, xs):
        c_st, n_st, m_st = carry  # (B,H,dk,dv), (B,H,dk), (B,H)
        qj, kj, vj, bj, lij, cmx = xs  # (B,H,L,*) for this chunk
        m_rows = jnp.maximum(m_st[..., None] + bj, bj + cmx)  # (B,H,L)
        e = jnp.exp(m_st[..., None] + bj - m_rows)  # inter coeff (B,H,L)
        # intra decay matrix W_ij = exp(b_i - b_j + li_j - m_i), j<=i
        logw = (
            bj[..., :, None] - bj[..., None, :] + lij[..., None, :]
            - m_rows[..., :, None]
        )
        w = jnp.where(tri, jnp.exp(logw), 0.0)  # (B,H,L,L)
        scores = jnp.einsum("bhld,bhmd->bhlm", qj, kj) * w
        num = (
            e[..., None] * jnp.einsum("bhld,bhdv->bhlv", qj, c_st)
            + jnp.einsum("bhlm,bhmv->bhlv", scores, vj)
        )
        den = (
            e * jnp.einsum("bhld,bhd->bhl", qj, n_st)
            + jnp.sum(scores, axis=-1)
        )
        h_out = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

        # state update with the chunk-end stabilizer m_last
        m_last = m_rows[..., -1]
        b_last = bj[..., -1]
        carry_decay = jnp.exp(m_st + b_last - m_last)  # (B,H)
        src_w = jnp.exp(b_last[..., None] - bj + lij - m_last[..., None])  # (B,H,L)
        c_new = (
            carry_decay[..., None, None] * c_st
            + jnp.einsum("bhl,bhld,bhlv->bhdv", src_w, kj, vj)
        )
        n_new = carry_decay[..., None] * n_st + jnp.einsum("bhl,bhld->bhd", src_w, kj)
        return (c_new, n_new, m_last), h_out

    xs = tuple(
        jnp.moveaxis(t, 2, 0)
        for t in (qc, kc, vc, bcum, li, cummax_u)
    )
    (c_f, n_f, m_f), hs = jax.lax.scan(
        chunk_step, (state["C"], state["n"], state["m"]), xs
    )
    h_seq = jnp.moveaxis(hs, 0, 2).reshape(b, h, s, dv)
    return {"C": c_f, "n": n_f, "m": m_f}, h_seq


def _mlstm_step(state, inputs):
    """One stabilized mLSTM step. inputs per t: q,k,v (B,H,*), i,f (B,H)."""
    q, k, v, i_pre, f_pre = inputs
    c_st, n_st, m_st = state["C"], state["n"], state["m"]
    log_f = jax.nn.log_sigmoid(f_pre)  # (B, H)
    m_new = jnp.maximum(log_f + m_st, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + m_st - m_new)
    c_new = f_g[..., None, None] * c_st + i_g[..., None, None] * (
        k[..., :, None] * v[..., None, :]
    )
    n_new = f_g[..., None] * n_st + i_g[..., None] * k
    h_num = jnp.einsum("bhk,bhkv->bhv", q, c_new)
    h_den = jnp.abs(jnp.einsum("bhk,bhk->bh", q, n_new))
    h = h_num / jnp.maximum(h_den, 1.0)[..., None]
    return {"C": c_new, "n": n_new, "m": m_new}, h


def mlstm_block(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """(B, S, D) -> (B, S, D). state given -> recurrent continuation (decode)."""
    b, s, d = x.shape
    backend = cfg.matmul_backend
    h = cfg.n_heads
    dk = cfg.mlstm_qk_dim // h

    dv_h = cfg.mlstm_v_dim // h
    q = (
        linear(params["wq"], x, backend, site="mlstm.wq")
        .reshape(b, s, h, dk).astype(jnp.float32) * dk**-0.5
    )
    k = (
        linear(params["wk"], x, backend, site="mlstm.wk")
        .reshape(b, s, h, dk).astype(jnp.float32) * dk**-0.5
    )
    v = (
        linear(params["wv"], x, backend, site="mlstm.wv")
        .reshape(b, s, h, dv_h).astype(jnp.float32)
    )
    i_pre = linear(params["wi"], x.astype(jnp.float32))
    f_pre = linear(params["wf"], x.astype(jnp.float32))
    o_gate = jax.nn.sigmoid(
        linear(params["wo"], x, backend).reshape(b, s, h, dv_h).astype(jnp.float32)
    )

    st = state if state is not None else init_mlstm_state(cfg, b)
    if cfg.mlstm_chunk and s > 1 and s % cfg.mlstm_chunk == 0:
        # chunkwise-parallel path (perf): heads-first layout
        to_hf = lambda t: jnp.moveaxis(t, 2, 1)  # (B,S,H,*) -> (B,H,S,*)
        new_state, h_hf = mlstm_chunkwise(
            to_hf(q), to_hf(k), to_hf(v),
            jnp.moveaxis(i_pre, 2, 1), jnp.moveaxis(f_pre, 2, 1),
            st, cfg.mlstm_chunk,
        )
        hs = jnp.moveaxis(h_hf, 1, 2)  # (B,S,H,dv_h)
    else:
        # sequential scan over time: move S to the front of each stream.
        xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i_pre, f_pre))
        new_state, hs = jax.lax.scan(_mlstm_step, st, xs)  # (S, B, H, dv_h)
        hs = jnp.moveaxis(hs, 0, 1)  # (B, S, H, dv_h)
    hs = hs * o_gate
    out = linear(
        params["out"], hs.reshape(b, s, cfg.mlstm_v_dim).astype(x.dtype), backend,
        site="mlstm.out",
    )
    out = constrain(out, "batch", "seq", "d_model")
    return out, (new_state if state is not None else None)


# ----------------------------------------------------------------- sLSTM
def init_slstm(key, cfg: ModelConfig, dtype) -> dict:
    d, h = cfg.d_model, cfg.n_heads
    dh = d // h
    keys = jax.random.split(key, 6)
    r_scale = dh**-0.5
    return {
        # input projections for z/i/f/o stacked: (D, 4, H, dh)
        "w": init_linear(keys[0], d, (4, h, dh), dtype, bias=True),
        # per-head recurrent mixing: (4, H, dh, dh)
        "r": (jax.random.normal(keys[1], (4, h, dh, dh)) * r_scale).astype(jnp.float32),
        "out": init_linear(keys[2], d, (d,), dtype, scale=d**-0.5),
    }


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    h = cfg.n_heads
    dh = cfg.d_model // h
    z = jnp.zeros((batch, h, dh), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full((batch, h, dh), -1e30, jnp.float32), "h": z}


def _slstm_step(r, state, wx_t):
    """wx_t: (B, 4, H, dh) input pre-activations at step t."""
    h_prev = state["h"]
    rec = jnp.einsum("bhd,ghde->bghe", h_prev, r)  # (B, 4, H, dh)
    pre = wx_t + rec
    z = jnp.tanh(pre[:, 0])
    i_pre = pre[:, 1]
    log_f = jax.nn.log_sigmoid(pre[:, 2])
    o = jax.nn.sigmoid(pre[:, 3])
    m_new = jnp.maximum(log_f + state["m"], i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(log_f + state["m"] - m_new)
    c_new = f_g * state["c"] + i_g * z
    n_new = f_g * state["n"] + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}, h_new


def slstm_block(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d = x.shape
    h = cfg.n_heads
    wx = linear(params["w"], x.astype(jnp.float32))  # (B, S, 4, H, dh)
    st = state if state is not None else init_slstm_state(cfg, b)
    r = params["r"]
    new_state, hs = jax.lax.scan(
        lambda c, w_t: _slstm_step(r, c, w_t), st, jnp.moveaxis(wx, 1, 0)
    )
    hs = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)  # (B, S, D)
    out = linear(params["out"], hs.astype(x.dtype), cfg.matmul_backend)
    out = constrain(out, "batch", "seq", "d_model")
    return out, (new_state if state is not None else None)

"""Unified model API over decoder-only and encoder-decoder stacks.

Everything downstream (training/ serving/ launch/ benchmarks) talks to
these five functions; the family dispatch lives here and nowhere else.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import encdec, transformer
from repro.models.config import ModelConfig

__all__ = [
    "init_params",
    "init_cache",
    "apply_train",
    "apply_prefill",
    "apply_decode",
    "loss_fn",
]


def init_params(cfg: ModelConfig, key) -> dict:
    if cfg.is_encdec:
        return encdec.init_encdec_params(cfg, key)
    return transformer.init_params(cfg, key)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    if cfg.is_encdec:
        return encdec.init_encdec_cache(cfg, batch, max_seq, dtype)
    return transformer.init_cache(cfg, batch, max_seq, dtype)


def apply_train(
    params, batch: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array]:
    """Teacher-forced logits. batch: tokens (B,S) [+ frames / positions]."""
    if cfg.is_encdec:
        enc_out = encdec.encode(params, batch["frames"], cfg)
        logits, _, aux = encdec.decode_forward(
            params, batch["tokens"], cfg, enc_out=enc_out
        )
        return logits, aux
    logits, _, aux = transformer.forward(
        params, batch["tokens"], cfg, positions=batch.get("positions")
    )
    return logits, aux


def apply_prefill(
    params,
    batch: Dict[str, jax.Array],
    cache: dict,
    cfg: ModelConfig,
) -> Tuple[jax.Array, dict]:
    """Fill the cache with a prompt; return last-position logits + cache."""
    if cfg.is_encdec:
        enc_out = encdec.encode(params, batch["frames"], cfg)
        logits, new_cache, _ = encdec.decode_forward(
            params, batch["tokens"], cfg, enc_out=enc_out, cache=cache
        )
        return logits[:, -1], new_cache
    logits, new_cache, _ = transformer.forward(
        params, batch["tokens"], cfg,
        positions=batch.get("positions"), cache=cache,
    )
    return logits[:, -1], new_cache


def apply_decode(
    params,
    tokens: jax.Array,  # (B, 1)
    cache: dict,
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, dict]:
    """One decode step against the cache; returns (B, V) logits."""
    if cfg.is_encdec:
        logits, new_cache, _ = encdec.decode_forward(
            params, tokens, cfg, enc_out=None, cache=cache
        )
        return logits[:, -1], new_cache
    logits, new_cache, _ = transformer.forward(
        params, tokens, cfg, positions=positions, cache=cache
    )
    return logits[:, -1], new_cache


def loss_fn(
    params, batch: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Next-token cross entropy (+ router aux), fp32 logits math."""
    logits, aux = apply_train(params, batch, cfg)
    targets = batch["labels"]
    mask = batch.get("mask")
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        ce = jnp.sum(nll * mask) / denom
    else:
        ce = jnp.mean(nll)
    loss = ce + aux
    metrics = {"loss": loss, "ce": ce, "aux": aux, "ppl": jnp.exp(ce)}
    return loss, metrics

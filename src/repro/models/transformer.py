"""Decoder-LM assembly: pattern-cycled blocks, scan-over-groups, caches.

Layers are grouped by the config's block_pattern period P: consecutive
groups of P layers share a stacked parameter pytree and run under ONE
jax.lax.scan (compact HLO — essential to keep the 40-cell dry-run
compile times sane), with any remainder layers unrolled at the end.
Per-group remat (jax.checkpoint) implements activation checkpointing.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import attention_block, init_attention, init_kv_cache
from repro.models.config import ModelConfig
from repro.models.layers import embed, layernorm, rmsnorm, unembed
from repro.models.mlp import init_mlp, mlp_block
from repro.models.moe import init_moe, moe_block
from repro.models.rglru import init_rglru, init_rglru_state, rglru_block
from repro.models.sharding import constrain
from repro.models.xlstm import (
    init_mlstm,
    init_mlstm_state,
    init_slstm,
    init_slstm_state,
    mlstm_block,
    slstm_block,
)

__all__ = [
    "init_params",
    "forward",
    "init_cache",
    "Mode",
]

Mode = str  # "train" | "prefill" | "decode"


def _norm(cfg: ModelConfig, params, x):
    if cfg.norm == "layernorm":
        return layernorm(params, x, cfg.norm_eps)
    return rmsnorm(params, x, cfg.norm_eps)


def _init_norm(cfg: ModelConfig, dtype):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((cfg.d_model,), dtype), "bias": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.zeros((cfg.d_model,), dtype)}


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    if cfg.is_moe:
        return True
    return cfg.d_ff > 0


def _init_layer(key, cfg: ModelConfig, kind: str, dtype) -> dict:
    kmix, kffn = jax.random.split(key)
    params: Dict[str, Any] = {"ln1": _init_norm(cfg, dtype)}
    if kind in ("attn", "local_attn"):
        params["mixer"] = init_attention(kmix, cfg, dtype)
    elif kind == "mlstm":
        params["mixer"] = init_mlstm(kmix, cfg, dtype)
    elif kind == "slstm":
        params["mixer"] = init_slstm(kmix, cfg, dtype)
    elif kind == "rglru":
        params["mixer"] = init_rglru(kmix, cfg, dtype)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if _has_ffn(cfg, kind):
        params["ln2"] = _init_norm(cfg, dtype)
        params["ffn"] = init_moe(kffn, cfg, dtype) if cfg.is_moe else init_mlp(kffn, cfg, dtype)
    return params


def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int, dtype):
    if kind == "attn":
        return init_kv_cache(cfg, batch, max_seq, dtype)
    if kind == "local_attn":
        # ring buffer: O(window) regardless of context length
        window_seq = min(max_seq, cfg.local_window) if cfg.local_window else max_seq
        return init_kv_cache(cfg, batch, window_seq, dtype)
    if kind == "mlstm":
        return init_mlstm_state(cfg, batch)
    if kind == "slstm":
        return init_slstm_state(cfg, batch)
    if kind == "rglru":
        return init_rglru_state(cfg, batch)
    raise ValueError(kind)


def _apply_layer(
    lparams,
    x: jax.Array,
    cfg: ModelConfig,
    kind: str,
    *,
    positions,
    cache_entry,
    cache_pos,
    causal: bool,
):
    """One block: pre-norm mixer + residual (+ pre-norm FFN + residual)."""
    h = _norm(cfg, lparams["ln1"], x)
    if kind in ("attn", "local_attn"):
        window = cfg.local_window if kind == "local_attn" and cfg.local_window else None
        ring = kind == "local_attn" and bool(cfg.local_window)
        mix, new_cache = attention_block(
            lparams["mixer"], h, cfg,
            positions=positions, causal=causal, window=window,
            cache=cache_entry, cache_pos=cache_pos, ring=ring,
        )
    elif kind == "mlstm":
        mix, new_cache = mlstm_block(lparams["mixer"], h, cfg, state=cache_entry)
    elif kind == "slstm":
        mix, new_cache = slstm_block(lparams["mixer"], h, cfg, state=cache_entry)
    elif kind == "rglru":
        mix, new_cache = rglru_block(lparams["mixer"], h, cfg, state=cache_entry)
    else:
        raise ValueError(kind)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if "ffn" in lparams:
        h2 = _norm(cfg, lparams["ln2"], x)
        if cfg.is_moe:
            f, aux = moe_block(lparams["ffn"], h2, cfg)
        else:
            f = mlp_block(lparams["ffn"], h2, cfg)
        x = x + f
    return constrain(x, "batch", "seq", "d_model"), new_cache, aux


# ------------------------------------------------------------------ init


def init_params(cfg: ModelConfig, key) -> dict:
    """Full decoder-LM parameter pytree with scan-stacked layer groups."""
    dtype = jnp.dtype(cfg.dtype)
    pattern = cfg.block_pattern
    period = len(pattern)
    n_groups = cfg.n_layers // period
    n_tail = cfg.n_layers - n_groups * period

    k_embed, k_layers, k_tail, k_out = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": {
            "embedding": (
                jax.random.normal(k_embed, (cfg.vocab, cfg.d_model)) * cfg.d_model**-0.5
            ).astype(dtype)
        },
        "final_norm": _init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["embed"]["unembedding"] = (
            jax.random.normal(k_out, (cfg.d_model, cfg.vocab)) * cfg.d_model**-0.5
        ).astype(dtype)

    # groups: dict pos{j} -> params stacked over n_groups
    if n_groups:
        group_keys = jax.random.split(k_layers, n_groups * period).reshape(
            n_groups, period, 2
        )
        groups = {}
        for j in range(period):
            per_group = [
                _init_layer(group_keys[g, j], cfg, pattern[j], dtype)
                for g in range(n_groups)
            ]
            groups[f"pos{j}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per_group)
        params["groups"] = groups
    if n_tail:
        tail_keys = jax.random.split(k_tail, n_tail)
        params["tail"] = [
            _init_layer(tail_keys[i], cfg, pattern[i % period], dtype)
            for i in range(n_tail)
        ]
    return params


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    """Serving cache pytree matching the grouped layer layout."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    pattern = cfg.block_pattern
    period = len(pattern)
    n_groups = cfg.n_layers // period
    n_tail = cfg.n_layers - n_groups * period
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if n_groups:
        cache["groups"] = {
            f"pos{j}": jax.tree.map(
                lambda *xs: jnp.stack(xs),
                *[
                    _init_layer_cache(cfg, pattern[j], batch, max_seq, dtype)
                    for _ in range(n_groups)
                ],
            )
            for j in range(period)
        }
    if n_tail:
        cache["tail"] = [
            _init_layer_cache(cfg, pattern[i % period], batch, max_seq, dtype)
            for i in range(n_tail)
        ]
    return cache


# ------------------------------------------------------------------ forward


def forward(
    params,
    tokens_or_embeds: jax.Array,
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
    causal: bool = True,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Run the decoder stack.

    Args:
      tokens_or_embeds: (B, S) int tokens, or (B, S, D) precomputed embeds
        (modality frontends are stubs that hand embeddings directly).
      positions: (B, S) or (B, S, 3) for mrope; defaults to arange (train)
        or cache.pos offset (decode/prefill).
      cache: serving cache -> decode/prefill mode; None -> train mode.

    Returns:
      (logits (B, S, V), new_cache or None, aux_loss scalar)
    """
    pattern = cfg.block_pattern
    period = len(pattern)
    n_groups = cfg.n_layers // period

    if tokens_or_embeds.ndim == 2:
        x = embed(params["embed"], tokens_or_embeds)
    else:
        x = tokens_or_embeds.astype(jnp.dtype(cfg.dtype))
    b, s = x.shape[0], x.shape[1]

    # cache["pos"] is scalar for lockstep batches, or (B,) for the
    # continuous-batching engine's slot-indexed decode (each slot at its
    # own sequence position).
    cache_pos = cache["pos"] if cache is not None else None
    if positions is None:
        if cache_pos is None:
            off = 0
        elif cache_pos.ndim == 1:
            off = cache_pos[:, None]  # (B, 1) broadcasts over seq
        else:
            off = cache_pos
        base = jnp.arange(s)[None, :] + off
        positions = jnp.broadcast_to(base, (b, s))
        if cfg.mrope:
            positions = jnp.broadcast_to(positions[..., None], (b, s, 3))

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: Optional[dict] = {"pos": (cache_pos + s)} if cache is not None else None

    # --- scanned groups
    if n_groups:
        gparams = params["groups"]
        gcache = cache["groups"] if cache is not None else None

        def body(x_carry, xs):
            gp, gc = xs

            def inner(x_in):
                aux = jnp.zeros((), jnp.float32)
                ncs = {}
                x_cur = x_in
                for j in range(period):
                    x_cur, nc, a = _apply_layer(
                        gp[f"pos{j}"], x_cur, cfg, pattern[j],
                        positions=positions,
                        cache_entry=(gc[f"pos{j}"] if gc is not None else None),
                        cache_pos=cache_pos,
                        causal=causal,
                    )
                    aux = aux + a
                    if nc is not None:
                        ncs[f"pos{j}"] = nc
                return x_cur, ncs, aux

            fn = jax.checkpoint(inner) if (cfg.remat and cache is None) else inner
            x_out, ncs, aux = fn(x_carry)
            return x_out, (ncs, aux)

        xs = (gparams, gcache) if gcache is not None else (gparams, None)
        if gcache is None:
            # replace None with a dummy zero-leaf pytree scan can carry
            xs = (gparams, jnp.zeros((n_groups,), jnp.int8))

            def body_nocache(x_carry, xs2):
                gp, _ = xs2
                return body(x_carry, (gp, None))

            x, (ncs, auxes) = jax.lax.scan(body_nocache, x, xs)
        else:
            x, (ncs, auxes) = jax.lax.scan(body, x, xs)
        aux_total = aux_total + jnp.sum(auxes)
        if cache is not None:
            new_cache["groups"] = ncs

    # --- unrolled tail layers (remat per layer in train mode, like groups)
    if "tail" in params:
        new_tail = []
        for i, lparams in enumerate(params["tail"]):
            kind = pattern[i % period]
            centry = cache["tail"][i] if cache is not None else None

            def tail_layer(lp, x_in, ce):
                return _apply_layer(
                    lp, x_in, cfg, kind,
                    positions=positions, cache_entry=ce,
                    cache_pos=cache_pos, causal=causal,
                )

            fn = (
                jax.checkpoint(tail_layer)
                if (cfg.remat and cache is None)
                else tail_layer
            )
            x, nc, a = fn(lparams, x, centry)
            aux_total = aux_total + a
            new_tail.append(nc)
        if cache is not None:
            new_cache["tail"] = new_tail

    x = _norm(cfg, params["final_norm"], x)
    logits = unembed(
        params["embed"], x, tied=cfg.tie_embeddings, softcap=cfg.logit_softcap
    )
    return logits, new_cache, aux_total

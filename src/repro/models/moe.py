"""Mixture-of-Experts FFN: top-k routing with capacity, shared experts.

Switch/MaxText-style "dropping" implementation: token->expert assignments
get a position-in-expert via a cumulative-sum over the one-hot assignment
matrix; assignments past the expert capacity are dropped (their tokens pass
through the residual unchanged). Dispatch/return are scatter/gathers, and
the expert FFN itself is ONE batched einsum over the (E, C, D) buffer —
sharded expert-parallel over the 'model' mesh axis (so dispatch lowers to
an all-to-all under GSPMD).

qwen2-moe additionally has shared experts that see every token; olmoe does
not. Router aux (load-balancing) loss follows Switch: E * sum_e f_e * p_e.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_linear, linear
from repro.models.mlp import init_mlp, mlp_block
from repro.models.sharding import constrain

__all__ = ["init_moe", "moe_block"]


def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_expert
    keys = jax.random.split(key, 5)
    scale = d**-0.5
    params = {
        "router": init_linear(keys[0], d, (e,), jnp.float32),  # fp32 router
        # Batched expert weights: (E, D, F) / (E, F, D).
        "w_gate": (jax.random.normal(keys[1], (e, d, f)) * scale).astype(dtype),
        "w_up": (jax.random.normal(keys[2], (e, d, f)) * scale).astype(dtype),
        "w_down": (jax.random.normal(keys[3], (e, f, d)) * f**-0.5).astype(dtype),
    }
    if cfg.n_shared_experts:
        params["shared"] = init_mlp(
            keys[4], cfg, dtype, d_ff=cfg.d_expert * cfg.n_shared_experts
        )
    return params


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(cap, cfg.top_k)


def _route(params, xt: jax.Array, cfg: ModelConfig):
    """Router top-k (fp32): returns (gates (T,k), experts (T,k), aux)."""
    e, k = cfg.n_experts, cfg.top_k
    logits = linear(params["router"], xt.astype(jnp.float32))  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    # aux load-balance loss (Switch eq. 4)
    me = jnp.mean(probs, axis=0)
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * frac) * cfg.router_aux_coef
    return gate_vals, expert_idx, aux


def _dispatch_compute_combine(
    params, xt: jax.Array, gate_vals, expert_idx, cfg: ModelConfig, cap: int,
    ep_constrain: bool = False,
) -> jax.Array:
    """Capacity dispatch -> batched expert FFN -> weighted combine.

    xt: (T, D) tokens of ONE dispatch group. The scatter/gather use only
    group-local indices, so when the group dim is the sharded batch axis
    (moe_group_dispatch) nothing here crosses shards.
    """
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    e_flat = expert_idx.reshape(-1)  # (T*k,)
    g_flat = gate_vals.reshape(-1)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = jnp.sum(jnp.cumsum(onehot, axis=0) * onehot, axis=-1) - 1
    keep = pos_in_e < cap
    slot = jnp.where(keep, pos_in_e, cap)  # dropped -> overflow slot

    token_of = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e, cap + 1, d), xt.dtype)
    buf = buf.at[e_flat, slot].add(xt[token_of] * keep[:, None].astype(xt.dtype))
    expert_in = buf[:, :cap]  # (E, C, D)
    if ep_constrain:
        expert_in = constrain(expert_in, "experts", None, "d_model")

    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    # Pin the expert weights' D/F dims unsharded for the contraction:
    # without this GSPMD contracts over the FSDP-sharded D and ALL-REDUCES
    # the (E, C, F) partial activations (~20x the weight bytes) — measured
    # 21.5 GB/device/step on olmoe train. This constraint makes it gather
    # the (small) weights instead: standard weight-gathered FSDP.
    w_gate = constrain(params["w_gate"], "experts", None, None)
    w_up = constrain(params["w_up"], "experts", None, None)
    w_down = constrain(params["w_down"], "experts", None, None)
    gate = jnp.einsum("ecd,edf->ecf", expert_in, w_gate)
    up = jnp.einsum("ecd,edf->ecf", expert_in, w_up)
    hidden = act(gate) * up
    expert_out = jnp.einsum("ecf,efd->ecd", hidden, w_down)
    if ep_constrain:
        expert_out = constrain(expert_out, "experts", None, "d_model")

    padded = jnp.concatenate(
        [expert_out, jnp.zeros((e, 1, d), expert_out.dtype)], axis=1
    )  # overflow slot reads zeros
    gathered = padded[e_flat, slot]  # (T*k, D)
    weighted = gathered * (g_flat * keep.astype(jnp.float32)).astype(xt.dtype)[:, None]
    return jnp.zeros((t, d), xt.dtype).at[token_of].add(weighted)


def _grouped_moe(params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """Grouped dispatch, explicitly batched over groups (no vmap).

    vmap hides the expert dim from sharding constraints (the batched
    constraint would pin the group dim replicated), so groups are threaded
    through every op as a leading axis with hand-placed constraints:
    group dim -> data shards, expert dim -> model shards. Scatter/gather
    indices are group-local; the only cross-shard traffic left is the
    expert all-to-all implied by (batch->experts) resharding around the
    FFN einsums — the canonical EP pattern.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(s, cfg)
    g = b  # one dispatch group per batch row (= data-shard granularity)

    xg = constrain(x, "batch", None, None)  # (G, S, D)
    gate_vals, expert_idx, aux = _route(params, xg.reshape(b * s, d), cfg)
    gv = gate_vals.reshape(g, s * k)  # fp32
    ei = expert_idx.reshape(g, s * k)

    # position-in-expert WITHIN each group: cumsum over the group's tokens
    onehot = jax.nn.one_hot(ei, e, dtype=jnp.int32)  # (G, S*k, E)
    pos_in_e = jnp.sum(jnp.cumsum(onehot, axis=1) * onehot, axis=-1) - 1  # (G, S*k)
    keep = pos_in_e < cap
    slot = jnp.where(keep, pos_in_e, cap)  # (G, S*k)

    # flat scatter: buf (G*E*(C+1), D); index = ((g*E)+e)*(C+1)+slot
    token_of = jnp.repeat(jnp.arange(s), k)[None, :]  # (1, S*k) within-group
    flat_idx = (jnp.arange(g)[:, None] * e + ei) * (cap + 1) + slot  # (G, S*k)
    gathered_tokens = jnp.take_along_axis(
        xg, jnp.broadcast_to(token_of[..., None], (g, s * k, d)), axis=1
    )  # (G, S*k, D)
    masked = gathered_tokens * keep[..., None].astype(x.dtype)
    buf = jnp.zeros((g * e * (cap + 1), d), x.dtype)
    buf = buf.at[flat_idx.reshape(-1)].add(masked.reshape(-1, d))
    expert_in = buf.reshape(g, e, cap + 1, d)[:, :, :cap]  # (G, E, C, D)
    # Expert placement: 'ep' shards experts over the model axis (canonical
    # expert parallelism, pays the token all-to-all); 'replicated' keeps
    # expert compute group-local (replicated over model). For small-expert
    # MoEs (d_expert ~1k) the all-to-all costs more than the redundant
    # GEMMs — measured bound 22.9s (ep) vs 10.6s (replicated) on olmoe —
    # so replicated is the default; flip with moe_expert_parallel=True.
    e_ax = "experts" if cfg.moe_expert_parallel else None
    expert_in = constrain(expert_in, "batch", e_ax, None, None)

    # expert FFN: weights pinned D/F-unsharded (weight-gathered FSDP — see
    # _dispatch_compute_combine notes), compute sharded (G:data, E:model).
    act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu
    w_gate = constrain(params["w_gate"], "experts", None, None)
    w_up = constrain(params["w_up"], "experts", None, None)
    w_down = constrain(params["w_down"], "experts", None, None)
    gate = jnp.einsum("gecd,edf->gecf", expert_in, w_gate)
    up = jnp.einsum("gecd,edf->gecf", expert_in, w_up)
    hidden = constrain(act(gate) * up, "batch", e_ax, None, None)
    expert_out = jnp.einsum("gecf,efd->gecd", hidden, w_down)
    expert_out = constrain(expert_out, "batch", e_ax, None, None)

    # combine: flat gather + weighted scatter-add back to tokens
    padded = jnp.concatenate(
        [expert_out, jnp.zeros((g, e, 1, d), expert_out.dtype)], axis=2
    ).reshape(g * e * (cap + 1), d)
    gathered = padded[flat_idx.reshape(-1)].reshape(g, s * k, d)
    weighted = gathered * (gv * keep.astype(jnp.float32)).astype(x.dtype)[..., None]
    out = jnp.zeros((g, s, d), x.dtype)
    out = out.at[
        jnp.arange(g)[:, None], jnp.broadcast_to(token_of, (g, s * k))
    ].add(weighted)
    return out, aux


def moe_block(params, x: jax.Array, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """(B, S, D) -> (B, S, D), plus scalar router aux loss.

    Baseline: one GLOBAL dispatch group (exact Switch semantics; GSPMD must
    reshard the data-dependent scatter -> all-gather/all-to-all heavy).
    moe_group_dispatch: one group per batch row -> scatter/gather stay on
    the row's data shard; only the expert FFN einsum touches the expert
    (model) axis. Capacity is enforced per group.
    """
    b, s, d = x.shape
    t = b * s

    if cfg.moe_group_dispatch:
        if cfg.moe_expert_parallel:
            # explicit EP layout (canonical all-to-all MoE): measured bound
            # 22.9s vs 10.6s for the vmapped/replicated path on olmoe —
            # kept as the research knob for large-expert configs.
            out, aux = _grouped_moe(params, x, cfg)
        else:
            cap = _capacity(s, cfg)
            xg = constrain(x, "batch", None, None)
            gate_vals, expert_idx, aux = _route(params, xg.reshape(t, d), cfg)
            gv = gate_vals.reshape(b, s, cfg.top_k)
            ei = expert_idx.reshape(b, s, cfg.top_k)
            out = jax.vmap(
                lambda xr, gr, er: _dispatch_compute_combine(
                    params, xr, gr, er, cfg, cap
                )
            )(xg, gv, ei)
    else:
        cap = _capacity(t, cfg)
        xt = x.reshape(t, d)
        gate_vals, expert_idx, aux = _route(params, xt, cfg)
        out = _dispatch_compute_combine(
            params, xt, gate_vals, expert_idx, cfg, cap, ep_constrain=True
        )
        out = out.reshape(b, s, d)

    if "shared" in params:
        out = out + mlp_block(params["shared"], x, cfg)

    return constrain(out, "batch", "seq", "d_model"), aux

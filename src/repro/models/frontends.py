"""Modality frontend STUBS (per assignment: backbone only).

[audio] whisper-tiny: the real model has a 2-conv mel-spectrogram stem.
Here ``input_specs()`` provides precomputed frame embeddings of shape
(B, enc_seq, d_model) — :func:`audio_frames_spec` — and the encoder
consumes them directly.

[vlm] qwen2-vl-72b: the real model has a ViT with dynamic resolution.
Here the backbone receives ordinary token ids plus precomputed M-RoPE
position triplets (B, S, 3) — :func:`mrope_positions_spec`. For text-only
inputs all three streams equal arange(S) and M-RoPE == RoPE (tested).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

__all__ = [
    "audio_frames_spec",
    "mrope_positions_spec",
    "make_stub_frames",
    "make_stub_positions",
]


def audio_frames_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype))


def mrope_positions_spec(batch: int, seq: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, seq, 3), jnp.int32)


def make_stub_frames(cfg: ModelConfig, batch: int, key=None) -> jax.Array:
    """Deterministic pseudo-frames for smoke tests / examples."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.random.normal(
        key, (batch, cfg.enc_seq, cfg.d_model), jnp.float32
    ).astype(jnp.dtype(cfg.dtype))


def make_stub_positions(batch: int, seq: int, offset: int = 0) -> jax.Array:
    """Text-only M-RoPE positions: all three streams identical."""
    base = jnp.arange(seq, dtype=jnp.int32) + offset
    return jnp.broadcast_to(base[None, :, None], (batch, seq, 3))

"""Griffin-style recurrent block: causal conv + RG-LRU (recurrentgemma).

RG-LRU (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t)                     recurrence gate
    i_t = sigmoid(W_x x_t)                     input gate
    log a_t = -c * r_t * softplus(Lambda)      per-channel learnable decay
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The recurrence is a first-order linear scan with input-dependent decay —
parallelized over S with jax.lax.associative_scan (train/prefill) and O(1)
state for decode. This is why recurrentgemma-9b is long_500k-eligible: its
"cache" is (conv tail, h state) per block plus a 2048-token local-attention
window, independent of total context length.

Strassen applicability: the gated scan has no matmul — the paper's
technique applies only to this block's in/out projections (DESIGN.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_linear, linear
from repro.models.sharding import constrain

__all__ = ["init_rglru", "rglru_block", "init_rglru_state"]


def init_rglru(key, cfg: ModelConfig, dtype) -> dict:
    d, w = cfg.d_model, cfg.rnn_width
    keys = jax.random.split(key, 7)
    # Lambda init so a^c in [0.9, 0.999] at r=1 (paper's stable range).
    lam = jax.random.uniform(keys[0], (w,), minval=2.0, maxval=6.0)
    return {
        "in_gate": init_linear(keys[1], d, (w,), dtype),  # gelu branch
        "in_rec": init_linear(keys[2], d, (w,), dtype),  # recurrent branch
        "conv_w": (jax.random.normal(keys[3], (cfg.conv_width, w)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "wa": init_linear(keys[4], w, (w,), jnp.float32, bias=True),
        "wx": init_linear(keys[5], w, (w,), jnp.float32, bias=True),
        "lam": lam.astype(jnp.float32),
        "out": init_linear(keys[6], w, (d,), dtype, scale=w**-0.5),
    }


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    w = cfg.rnn_width
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), jnp.float32),
    }


def _causal_conv(x: jax.Array, conv_w: jax.Array, conv_b: jax.Array, tail: Optional[jax.Array]):
    """Depthwise causal conv via shifted adds. x: (B, S, W); tail: (B, cw-1, W)."""
    cw = conv_w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    padded = jnp.concatenate([tail.astype(x.dtype), x], axis=1)  # (B, S+cw-1, W)
    s = x.shape[1]
    out = None
    for j in range(cw):
        term = padded[:, j : j + s, :] * conv_w[cw - 1 - j].astype(x.dtype)
        out = term if out is None else out + term
    new_tail = padded[:, -(cw - 1) :, :] if cw > 1 else tail
    return out + conv_b.astype(x.dtype), new_tail


def _rglru_scan(xr: jax.Array, params, cfg: ModelConfig, h0: Optional[jax.Array]):
    """xr: (B, S, W) conv output -> (B, S, W) recurrence output, final h."""
    r = jax.nn.sigmoid(linear(params["wa"], xr.astype(jnp.float32)))
    i = jax.nn.sigmoid(linear(params["wx"], xr.astype(jnp.float32)))
    log_a = -cfg.rglru_c * r * jax.nn.softplus(params["lam"])  # (B, S, W)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * xr.astype(jnp.float32)
    )
    if h0 is not None:
        # fold the carried state in as a virtual step 0 contribution
        gated = gated.at[:, 0, :].add(a[:, 0, :] * h0)
    # associative first-order recurrence h_t = a_t h_{t-1} + b_t
    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h, h[:, -1, :]


def rglru_block(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Griffin recurrent block: gelu gate branch x (conv -> RG-LRU) branch."""
    b, s, d = x.shape
    backend = cfg.matmul_backend
    gate = jax.nn.gelu(
        linear(params["in_gate"], x, backend, site="rglru.in_gate"), approximate=True
    )
    rec_in = linear(params["in_rec"], x, backend, site="rglru.in_rec")
    rec_in = constrain(rec_in, "batch", "seq", "d_ff")

    tail = state["conv"] if state is not None else None
    conv_out, new_tail = _causal_conv(rec_in, params["conv_w"], params["conv_b"], tail)
    h0 = state["h"] if state is not None else None
    h, h_last = _rglru_scan(conv_out, params, cfg, h0)

    merged = gate * h.astype(x.dtype)
    out = linear(params["out"], merged, backend, site="rglru.out")
    out = constrain(out, "batch", "seq", "d_model")
    new_state = None
    if state is not None:
        new_state = {"h": h_last, "conv": new_tail.astype(jnp.float32)}
    return out, new_state

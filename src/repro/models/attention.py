"""Attention blocks: GQA/MQA/MHA, full/causal/local, train + decode paths.

Three execution paths, one semantics (cross-validated in tests):
  * chunked_attention — double-chunked online-softmax in pure JAX:
    differentiable, never materializes (Sq, Sk); the training/prefill path.
    This is the XLA-level equivalent of kernels/flash_attention (the Pallas
    kernel is the TPU-target fast path, validated in interpret mode).
  * decode_attention — single-token query against a preallocated KV cache.
  * kernels.flash_attention — opt-in Pallas path for serving.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import init_linear, linear
from repro.models.rope import apply_mrope, apply_rope
from repro.models.sharding import constrain

__all__ = [
    "init_attention",
    "attention_block",
    "decode_attention",
    "chunked_attention",
    "init_kv_cache",
]

_NEG_INF = -1e30


def _chunk(dim: int, preferred: int) -> int:
    """Largest divisor of dim that is <= preferred."""
    if dim <= preferred:
        return dim
    for c in range(preferred, 0, -1):
        if dim % c == 0:
            return c
    return 1


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_chunk: int = 512,
    k_chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention, chunked over BOTH Sq and Sk.

    q: (B, Hq, Sq, d); k, v: (B, Hkv, Sk, d). Peak temp is
    (B, Hq, q_chunk, k_chunk) fp32 — independent of sequence length.
    """
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5
    cq = _chunk(sq, q_chunk)
    ck = _chunk(sk, k_chunk)
    nq, nk = sq // cq, sk // ck

    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # (nq, B, Hkv, g, cq, d) — q chunks as a scannable leading axis.
    q_chunks = jnp.moveaxis(qg.reshape(b, hkv, g, nq, cq, d), 3, 0)
    k_chunks = jnp.moveaxis(kf.reshape(b, hkv, nk, ck, d), 2, 0)
    v_chunks = jnp.moveaxis(vf.reshape(b, hkv, nk, ck, d), 2, 0)

    rows_base = jnp.arange(cq)
    cols_base = jnp.arange(ck)

    def one_q_chunk(args):
        iq, q_blk = args  # q_blk: (B, Hkv, g, cq, d)
        q_off = iq * cq

        def kv_step(carry, xs):
            acc, m, l = carry
            ik, k_blk, v_blk = xs  # (B, Hkv, ck, d)
            k_off = ik * ck
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_blk, k_blk)  # fp32
            live = jnp.ones((cq, ck), dtype=bool)
            rows = q_off + rows_base[:, None]
            cols = k_off + cols_base[None, :]
            if causal:
                live &= rows >= cols
            if window is not None:
                live &= rows - cols < window
            s = jnp.where(live, s, _NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.where(live, jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m - m_new)
            l_new = alpha * l + jnp.sum(p, axis=-1, keepdims=True)
            acc_new = acc * alpha + jnp.einsum("bhgqk,bhkd->bhgqd", p, v_blk)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hkv, g, cq, d), jnp.float32)
        m0 = jnp.full((b, hkv, g, cq, 1), _NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, cq, 1), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (jnp.arange(nk), k_chunks, v_chunks)
        )
        l = jnp.where(l == 0.0, 1.0, l)
        return acc / l

    # Remat each q-chunk: the backward pass recomputes its KV sweep instead
    # of storing O(nq * nk) online-softmax residuals (this is what makes the
    # 32k-token training/prefill cells fit in HBM).
    out = jax.lax.map(
        jax.checkpoint(one_q_chunk), (jnp.arange(nq), q_chunks)
    )  # (nq, B, Hkv, g, cq, d)
    out = jnp.moveaxis(out, 0, 3).reshape(b, hkv, g, sq, d)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """One-token attention against a cache: q (B, Hq, 1, d), cache (B, Hkv, S, d).

    Positions > pos (unwritten cache) and, with a window, <= pos - window
    are masked. ``pos`` is a scalar (lockstep batch) or (B,) / (B, 1)
    per-row positions (continuous-batching slots).
    """
    b, hq, one, d = q.shape
    _, hkv, s, _ = k_cache.shape
    g = hq // hkv
    scale = scale if scale is not None else d**-0.5
    pos = jnp.asarray(pos)
    if pos.ndim == 1:
        pos = pos[:, None]  # (B, 1) -> per-row mask rows
    # Keep the cache in its storage dtype; accumulate in fp32 via
    # preferred_element_type — upcasting the cache materializes a 2x-cache
    # fp32 temp, the dominant decode HBM cost.
    qg = (q.reshape(b, hkv, g, d).astype(jnp.float32) * scale).astype(k_cache.dtype)
    scores = jnp.einsum(
        "bhgd,bhkd->bhgk", qg, k_cache, preferred_element_type=jnp.float32
    )
    cols = jnp.arange(s)
    live = cols[None, :] <= pos  # (1, S) broadcast over batch if pos scalar
    if window is not None:
        live = jnp.logical_and(live, cols[None, :] > pos - window)
    scores = jnp.where(live[:, None, None, :], scores, _NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum(
        "bhgk,bhkd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# ------------------------------------------------------------------ block


def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    """Projection weights are stored FLAT (d_in, n*hd): the flattened head
    dim is divisible by the 16-wide model axis for every assigned arch,
    so jit input shardings stay even; heads are reshaped inside the block
    (activation constraints tolerate uneven head counts)."""
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 4)
    return {
        "wq": init_linear(keys[0], d, (h * hd,), dtype, bias=cfg.qkv_bias),
        "wk": init_linear(keys[1], d, (hkv * hd,), dtype, bias=cfg.qkv_bias),
        "wv": init_linear(keys[2], d, (hkv * hd,), dtype, bias=cfg.qkv_bias),
        "wo": init_linear(keys[3], h * hd, (d,), dtype, scale=(h * hd) ** -0.5),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    cache_dtype = jnp.dtype(cfg.cache_dtype) if cfg.cache_dtype else dtype
    shape = (batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
    return {"k": jnp.zeros(shape, cache_dtype), "v": jnp.zeros(shape, cache_dtype)}


def _project_qkv(params, x, cfg: ModelConfig, positions):
    backend = cfg.matmul_backend
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = linear(
        params["wq"], x, backend, w_logical=("fsdp", "heads"), site="attn.wq"
    ).reshape(b, s, h, hd)
    k = linear(
        params["wk"], x, backend, w_logical=("fsdp", "heads"), site="attn.wk"
    ).reshape(b, s, hkv, hd)
    v = linear(
        params["wv"], x, backend, w_logical=("fsdp", "heads"), site="attn.wv"
    ).reshape(b, s, hkv, hd)
    q = jnp.moveaxis(q, 2, 1)  # (B, H, S, hd)
    k = jnp.moveaxis(k, 2, 1)
    v = jnp.moveaxis(v, 2, 1)
    if cfg.mrope:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.rope_theta > 0:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "heads", "seq", "head_dim")
    k = constrain(k, "batch", "kv_heads", "seq", "head_dim")
    v = constrain(v, "batch", "kv_heads", "seq", "head_dim")
    return q, k, v


def _cache_write(cache: jax.Array, kv: jax.Array, pos, vec: bool) -> jax.Array:
    """Write one token's K/V at ``pos``: lockstep (scalar pos, dynamic
    update slice) or per-row (vector pos, one scatter per batch row —
    the continuous-batching decode where every slot sits at its own
    sequence position)."""
    if not vec:
        return jax.lax.dynamic_update_slice_in_dim(cache, kv, pos, axis=2)
    b = cache.shape[0]
    return cache.at[jnp.arange(b), :, pos, :].set(kv[:, :, 0, :])


def attention_block(
    params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    cache: Optional[dict] = None,
    cache_pos: Optional[jax.Array] = None,
    ring: bool = False,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full attention sub-block (pre-norm residual handled by caller).

    Train/prefill: cache None -> chunked flash over the whole sequence
    (cache may be RETURNED for prefill when cache_pos is provided).
    Decode: cache given and S == 1 -> cache update + decode_attention.
    ring: sliding-window ring-buffer cache of size == window (token t lives
    in slot t % W) — O(window) serving memory regardless of context length,
    which is what makes recurrentgemma long_500k-serveable.
    """
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions)

    new_cache = None
    if cache is not None:
        # cache storage dtype may be quantized (cfg.cache_dtype)
        k = k.astype(cache["k"].dtype) if cache["k"].dtype != k.dtype else k
        v = v.astype(cache["v"].dtype) if cache["v"].dtype != v.dtype else v
    if cache is not None and s == 1:
        vec = jnp.ndim(cache_pos) == 1  # per-row write positions (slot batch)
        if ring:
            w_size = cache["k"].shape[2]
            slot = cache_pos % w_size
            kc = _cache_write(cache["k"], k, slot, vec)
            vc = _cache_write(cache["v"], v, slot, vec)
            new_cache = {"k": kc, "v": vc}
            # every resident token is in-window by construction; mask only
            # the not-yet-written slots before the first wrap.
            pos_eff = jnp.minimum(cache_pos, w_size - 1)
            out = decode_attention(q, kc, vc, pos_eff, window=None)
        else:
            kc = _cache_write(cache["k"], k, cache_pos, vec)
            vc = _cache_write(cache["v"], v, cache_pos, vec)
            new_cache = {"k": kc, "v": vc}
            out = decode_attention(q, kc, vc, cache_pos, window=window)
    else:
        out = chunked_attention(
            q, k, v, causal=causal, window=window,
            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk,
        )
        if cache is not None and ring:
            w_size = cache["k"].shape[2]
            if s >= w_size:
                # keep only the last W tokens; token t -> slot t % W.
                shift = (s - w_size) % w_size
                kc = jnp.roll(k[:, :, -w_size:], shift, axis=2)
                vc = jnp.roll(v[:, :, -w_size:], shift, axis=2)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, axis=2)
                vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, axis=2)
            new_cache = {"k": kc, "v": vc}
        elif cache is not None:
            # prefill: write the whole K/V prefix.
            kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_pos, axis=2)
            vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_pos, axis=2)
            new_cache = {"k": kc, "v": vc}

    out = jnp.moveaxis(out, 1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    out = linear(
        params["wo"], out, cfg.matmul_backend, w_logical=("heads", "fsdp"),
        site="attn.wo",
    )
    return constrain(out, "batch", "seq", "d_model"), new_cache


def init_cross_attention(key, cfg: ModelConfig, dtype) -> dict:
    return init_attention(key, cfg, dtype)


def cross_attention_block(
    params,
    x: jax.Array,
    enc_kv: Tuple[jax.Array, jax.Array],
    cfg: ModelConfig,
) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V (whisper)."""
    b, s, _ = x.shape
    backend = cfg.matmul_backend
    q = linear(params["wq"], x, backend, site="xattn.wq").reshape(
        b, s, cfg.n_heads, cfg.head_dim
    )
    q = jnp.moveaxis(q, 1, 2)  # (B, H, S, hd)
    k, v = enc_kv  # (B, Hkv, S_enc, hd)
    out = chunked_attention(q, k, v, causal=False)
    out = jnp.moveaxis(out, 1, 2).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return linear(params["wo"], out, backend, site="xattn.wo")


def encode_cross_kv(params, enc_out: jax.Array, cfg: ModelConfig):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    backend = cfg.matmul_backend
    b, s, _ = enc_out.shape
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    k = linear(params["wk"], enc_out, backend, site="xattn.wk").reshape(b, s, hkv, hd)
    v = linear(params["wv"], enc_out, backend, site="xattn.wv").reshape(b, s, hkv, hd)
    return jnp.moveaxis(k, 1, 2), jnp.moveaxis(v, 1, 2)

"""Model configuration shared by all 10 assigned architectures.

A single frozen dataclass describes every family (dense / moe / ssm /
audio / vlm / hybrid); the block_pattern drives which layer kinds are
instantiated. Frozen + hashable so configs can be static jit arguments.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.backend import MatmulBackend, NAIVE_BACKEND

__all__ = ["ModelConfig"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | audio | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: Optional[int] = None  # default d_model // n_heads
    act: str = "silu"  # silu | gelu
    glu: bool = True  # gated MLP (SwiGLU / GeGLU)
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    mrope: bool = False  # qwen2-vl multimodal RoPE (3 position streams)
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w head_dim split
    norm_eps: float = 1e-6
    norm: str = "rmsnorm"  # rmsnorm | layernorm (whisper)
    tie_embeddings: bool = False
    logit_softcap: float = 0.0  # gemma-style final-logit softcap (0 = off)

    # Layer pattern, cycled over n_layers: attn | local_attn | mlstm | slstm | rglru
    # Every block is followed by an MLP unless the kind manages its own FFN.
    block_pattern: Tuple[str, ...] = ("attn",)
    local_window: int = 0  # for local_attn blocks

    # MoE (olmoe / qwen2-moe)
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0  # per-expert FFN width
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # Grouped dispatch (perf): scatter/gather stay LOCAL to each batch row
    # (= data shard), so MoE routing induces no cross-shard collectives;
    # capacity is enforced per group (slightly different drop pattern).
    moe_group_dispatch: bool = False
    # canonical expert parallelism (token all-to-all) vs model-axis
    # replicated expert compute; see models/moe.py for the measured trade
    moe_expert_parallel: bool = False

    # mLSTM / sLSTM (xlstm)
    mlstm_qk_dim: int = 0  # defaults to d_model // 2
    mlstm_v_dim: int = 0  # defaults to d_model
    mlstm_chunk: int = 0  # 0 = sequential scan; >0 = chunkwise-parallel (perf)
    conv_width: int = 4  # short conv in recurrent blocks (griffin/xlstm)

    # RG-LRU (recurrentgemma)
    rglru_c: float = 8.0
    rnn_width: int = 0  # recurrent branch width (defaults to d_model)

    # Encoder-decoder (whisper): if enc_layers > 0, model is enc-dec.
    enc_layers: int = 0
    enc_seq: int = 1500  # fixed encoder frames (whisper stub frontend)

    # Modality frontend stub: none | audio_stub | vision_stub
    frontend: str = "none"

    dtype: str = "bfloat16"
    cache_dtype: str = ""  # KV-cache storage dtype ("" = model dtype;
    #                        "float8_e4m3fn" halves serving cache memory)
    # The paper's technique as a first-class feature: matmul routing.
    matmul_backend: MatmulBackend = NAIVE_BACKEND
    # Turn on the calibrated autotune dispatcher for every dense projection:
    # rewrites matmul_backend to kind='auto' (keeping its min_dim/precision/
    # cache settings), so each projection shape picks naive-vs-Strassen from
    # the cost model instead of a hand-set kind/depth.
    matmul_autotune: bool = False

    # Training-time knobs used by train_step lowering.
    remat: bool = True
    # chunked-attention tile sizes (per-perf-iteration tunables)
    attn_q_chunk: int = 512
    attn_k_chunk: int = 1024

    def __post_init__(self):
        if self.matmul_autotune and self.matmul_backend.kind != "auto":
            object.__setattr__(
                self,
                "matmul_backend",
                dataclasses.replace(self.matmul_backend, kind="auto", depth=3),
            )
        if self.n_heads and self.d_model and self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family in ("ssm", "hybrid") or "mlstm" in self.block_pattern:
            if not self.mlstm_qk_dim:
                object.__setattr__(self, "mlstm_qk_dim", max(self.d_model // 2, 1))
            if not self.mlstm_v_dim:
                object.__setattr__(self, "mlstm_v_dim", self.d_model)
        if not self.rnn_width:
            object.__setattr__(self, "rnn_width", self.d_model)

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    def block_kind(self, layer_idx: int) -> str:
        return self.block_pattern[layer_idx % len(self.block_pattern)]

    @property
    def sub_quadratic(self) -> bool:
        """True when no block needs a full-length dense KV cache (long_500k OK)."""
        kinds = set(self.block_pattern)
        return "attn" not in kinds and not self.is_encdec

    def layer_kinds(self) -> Tuple[str, ...]:
        return tuple(self.block_kind(i) for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND math."""
        d, v = self.d_model, self.vocab
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        hd = self.head_dim or (d // max(self.n_heads, 1))
        for i in range(self.n_layers):
            kind = self.block_kind(i)
            if kind in ("attn", "local_attn"):
                total += d * self.n_heads * hd  # q
                total += 2 * d * self.n_kv_heads * hd  # k, v
                total += self.n_heads * hd * d  # o
                total += self._ffn_params()
            elif kind == "mlstm":
                qk, vd = self.mlstm_qk_dim, self.mlstm_v_dim
                total += d * (2 * qk + 2 * vd) + vd * d + 2 * d  # q,k,v,gate,out,if-gates
                total += self._ffn_params()
            elif kind == "slstm":
                total += 4 * d * d + 4 * d * (d // max(self.n_heads, 1))  # W, R per head
                total += self._ffn_params()
            elif kind == "rglru":
                w = self.rnn_width
                total += 2 * d * w + w * d + 2 * w * self.conv_width + 2 * w
                total += self._ffn_params()
            total += 2 * d  # norms
        if self.is_encdec:
            # encoder blocks (self-attn + mlp)
            per = d * self.n_heads * hd * 2 + 2 * d * self.n_kv_heads * hd + self._ffn_params()
            total += self.enc_layers * per
            total += self.n_layers * (d * self.n_heads * hd * 2 + 2 * d * self.n_kv_heads * hd)  # cross-attn
        return total

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.is_moe:
            e = self.n_experts + self.n_shared_experts
            return e * 3 * d * self.d_expert + d * self.n_experts
        if self.d_ff == 0:
            return 0
        mult = 3 if self.glu else 2
        return mult * d * self.d_ff

    def active_param_count(self) -> int:
        """Params active per token (MoE: only routed top_k + shared)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        total = self.param_count()
        # subtract inactive experts
        inactive = self.n_experts - self.top_k
        total -= self.n_layers * inactive * 3 * d * self.d_expert
        return total

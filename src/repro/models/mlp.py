"""Dense MLP blocks: SwiGLU (llama/phi/qwen), GeGLU (gemma), plain GELU."""
from __future__ import annotations

import jax

from repro.models.config import ModelConfig
from repro.models.layers import init_linear, linear
from repro.models.sharding import constrain

__all__ = ["init_mlp", "mlp_block"]

_ACTS = {
    "silu": jax.nn.silu,
    "gelu": lambda x: jax.nn.gelu(x, approximate=True),
    "relu": jax.nn.relu,
}


def init_mlp(key, cfg: ModelConfig, dtype, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    keys = jax.random.split(key, 3)
    params = {
        "up": init_linear(keys[0], d, (f,), dtype),
        "down": init_linear(keys[1], f, (d,), dtype, scale=f**-0.5),
    }
    if cfg.glu:
        params["gate"] = init_linear(keys[2], d, (f,), dtype)
    return params


def mlp_block(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    backend = cfg.matmul_backend
    act = _ACTS[cfg.act]
    up = linear(params["up"], x, backend, w_logical=("fsdp", "d_ff"), site="mlp.up")
    up = constrain(up, "batch", "seq", "d_ff")
    if "gate" in params:
        gate = linear(
            params["gate"], x, backend, w_logical=("fsdp", "d_ff"), site="mlp.gate"
        )
        gate = constrain(gate, "batch", "seq", "d_ff")
        h = act(gate) * up
    else:
        h = act(up)
    out = linear(params["down"], h, backend, w_logical=("d_ff", "fsdp"), site="mlp.down")
    return constrain(out, "batch", "seq", "d_model")

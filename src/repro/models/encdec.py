"""Whisper-style encoder-decoder backbone (audio frontend is a stub).

Encoder: bidirectional self-attention blocks over precomputed frame
embeddings (the conv frontend is stubbed per the assignment — input_specs
hands (B, enc_seq, d_model) frames directly) with sinusoidal positions.
Decoder: causal self-attention + cross-attention + MLP, with a KV cache
for the self-attention and precomputed cross K/V from the encoder.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.attention import (
    attention_block,
    cross_attention_block,
    encode_cross_kv,
    init_attention,
    init_kv_cache,
)
from repro.models.config import ModelConfig
from repro.models.layers import embed, layernorm, unembed
from repro.models.mlp import init_mlp, mlp_block
from repro.models.sharding import constrain

__all__ = ["init_encdec_params", "encode", "decode_forward", "init_encdec_cache"]


def _sinusoid_at(positions: jax.Array, d: int) -> jax.Array:
    """Sinusoidal embeddings evaluated at integer positions (..., S)."""
    pos = positions.astype(jnp.float32)[..., None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    angle = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _init_ln(cfg, dtype):
    return {
        "scale": jnp.ones((cfg.d_model,), dtype),
        "bias": jnp.zeros((cfg.d_model,), dtype),
    }


def _init_enc_layer(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": _init_ln(cfg, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln2": _init_ln(cfg, dtype),
        "mlp": init_mlp(k2, cfg, dtype),
    }


def _init_dec_layer(key, cfg: ModelConfig, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": _init_ln(cfg, dtype),
        "self_attn": init_attention(k1, cfg, dtype),
        "ln_x": _init_ln(cfg, dtype),
        "cross_attn": init_attention(k2, cfg, dtype),
        "ln2": _init_ln(cfg, dtype),
        "mlp": init_mlp(k3, cfg, dtype),
    }


def init_encdec_params(cfg: ModelConfig, key) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 4)
    enc_keys = jax.random.split(keys[0], cfg.enc_layers)
    dec_keys = jax.random.split(keys[1], cfg.n_layers)
    params: Dict[str, Any] = {
        "embed": {
            "embedding": (
                jax.random.normal(keys[2], (cfg.vocab, cfg.d_model)) * cfg.d_model**-0.5
            ).astype(dtype)
        },
        # whisper ties the output head to the embedding
        "enc": [_init_enc_layer(k, cfg, dtype) for k in enc_keys],
        "enc_norm": _init_ln(cfg, dtype),
        "dec": [_init_dec_layer(k, cfg, dtype) for k in dec_keys],
        "dec_norm": _init_ln(cfg, dtype),
    }
    return params


def encode(params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    x = x + _sinusoid_at(positions, cfg.d_model).astype(x.dtype)
    x = constrain(x, "batch", "seq", "d_model")

    def layer(lp, x_in):
        h = layernorm(lp["ln1"], x_in, cfg.norm_eps)
        # bidirectional; whisper has no rope (sinusoid added above) so we
        # pass zero positions through a rope-free config path.
        mix, _ = attention_block(
            lp["attn"], h, cfg, positions=positions, causal=False
        )
        x_in = x_in + mix
        h2 = layernorm(lp["ln2"], x_in, cfg.norm_eps)
        return x_in + mlp_block(lp["mlp"], h2, cfg)

    fn = jax.checkpoint(layer) if cfg.remat else layer
    for lp in params["enc"]:
        x = fn(lp, x)
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def init_encdec_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=None) -> dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.head_dim
    return {
        "pos": jnp.zeros((), jnp.int32),
        "self": [init_kv_cache(cfg, batch, max_seq, dtype) for _ in range(cfg.n_layers)],
        # cross K/V filled by decode_forward when enc_out is provided
        "cross": [
            {
                "k": jnp.zeros((batch, cfg.n_kv_heads, cfg.enc_seq, hd), dtype),
                "v": jnp.zeros((batch, cfg.n_kv_heads, cfg.enc_seq, hd), dtype),
            }
            for _ in range(cfg.n_layers)
        ],
    }


def decode_forward(
    params,
    tokens: jax.Array,
    cfg: ModelConfig,
    *,
    enc_out: Optional[jax.Array] = None,
    cache: Optional[dict] = None,
) -> Tuple[jax.Array, Optional[dict], jax.Array]:
    """Decoder stack. enc_out given -> (re)compute cross K/V (train/prefill);
    otherwise cross K/V read from cache (decode steps)."""
    x = embed(params["embed"], tokens)
    b, s = tokens.shape
    cache_pos = cache["pos"] if cache is not None else None
    base = jnp.arange(s)[None, :] + (cache_pos if cache_pos is not None else 0)
    positions = jnp.broadcast_to(base, (b, s))
    x = x + _sinusoid_at(positions, cfg.d_model).astype(x.dtype)

    new_cache: Optional[dict] = None
    if cache is not None:
        new_cache = {"pos": cache_pos + s, "self": [], "cross": []}

    aux = jnp.zeros((), jnp.float32)

    def layer(lp, x_in, self_cache, cross_kv):
        h = layernorm(lp["ln1"], x_in, cfg.norm_eps)
        mix, nc = attention_block(
            lp["self_attn"], h, cfg,
            positions=positions, causal=True,
            cache=self_cache, cache_pos=cache_pos,
        )
        x_in = x_in + mix
        hx = layernorm(lp["ln_x"], x_in, cfg.norm_eps)
        if cross_kv is None:
            ck, cv = encode_cross_kv(lp["cross_attn"], enc_out, cfg)
        else:
            ck, cv = cross_kv
        x_in = x_in + cross_attention_block(lp["cross_attn"], hx, (ck, cv), cfg)
        h2 = layernorm(lp["ln2"], x_in, cfg.norm_eps)
        x_in = x_in + mlp_block(lp["mlp"], h2, cfg)
        return x_in, nc, (ck, cv)

    fn = jax.checkpoint(layer) if (cfg.remat and cache is None) else layer
    for i, lp in enumerate(params["dec"]):
        self_cache = cache["self"][i] if cache is not None else None
        cross_kv = None
        if enc_out is None:
            assert cache is not None, "decode without enc_out needs cached cross K/V"
            cross_kv = (cache["cross"][i]["k"], cache["cross"][i]["v"])
        x, nc, (ck, cv) = fn(lp, x, self_cache, cross_kv)
        if cache is not None:
            new_cache["self"].append(nc)
            new_cache["cross"].append({"k": ck, "v": cv})

    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = unembed(params["embed"], x, tied=True)
    return logits, new_cache, aux

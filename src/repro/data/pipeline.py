"""Synthetic, deterministic, host-sharded token pipeline.

Production posture: each host generates only ITS shard of the global batch
(shard_for_host), batches are reproducible functions of (seed, step) so an
elastic restart at step k regenerates the identical stream, and the
iterator supports skipping to a step for checkpoint resume. Swap
``SyntheticLM`` for a file-backed source by implementing the same
``__call__(step) -> batch`` contract.

The token distribution is a mixture of Zipfian unigrams and a repeated
n-gram process, so cross-entropy actually decreases during the e2e example
(pure-uniform tokens would pin the loss at log V).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.frontends import make_stub_frames, make_stub_positions

__all__ = ["DataConfig", "SyntheticLM", "shard_for_host"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int  # per-host batch
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    ngram: int = 8  # motif length for learnable structure


class SyntheticLM:
    """batch = pipeline(step): deterministic per (seed, step)."""

    def __init__(self, cfg: ModelConfig, data: DataConfig):
        self.cfg = cfg
        self.data = data
        rng = np.random.default_rng(data.seed)
        # Fixed motif table: 256 motifs of length ngram over a Zipf vocab.
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        probs = ranks ** (-data.zipf_a)
        self._probs = probs / probs.sum()
        self._motifs = rng.integers(
            0, cfg.vocab, size=(256, data.ngram), dtype=np.int64
        )

    def __call__(self, step: int) -> Dict[str, jax.Array]:
        d = self.data
        rng = np.random.default_rng((d.seed << 32) ^ step)
        n_tokens = d.batch * (d.seq_len + 1)
        # mixture: 50% zipf unigrams, 50% motif continuations
        flat = rng.choice(self.cfg.vocab, size=n_tokens, p=self._probs)
        seq = flat.reshape(d.batch, d.seq_len + 1)
        n_mot = d.seq_len // (2 * d.ngram)
        for b in range(d.batch):
            ids = rng.integers(0, 256, size=n_mot)
            starts = rng.integers(0, d.seq_len - d.ngram, size=n_mot)
            for m, s in zip(ids, starts):
                seq[b, s : s + d.ngram] = self._motifs[m]
        tokens = jnp.asarray(seq[:, :-1], jnp.int32)
        labels = jnp.asarray(seq[:, 1:], jnp.int32)
        batch = {"tokens": tokens, "labels": labels}
        if self.cfg.frontend == "audio_stub":
            batch["frames"] = make_stub_frames(
                self.cfg, d.batch, jax.random.PRNGKey(step)
            )
        if self.cfg.mrope:
            batch["positions"] = make_stub_positions(d.batch, d.seq_len)
        return batch

    def iterate(self, start_step: int = 0) -> Iterator[Dict[str, jax.Array]]:
        step = start_step
        while True:
            yield self(step)
            step += 1


def shard_for_host(
    global_batch: int, host_index: Optional[int] = None, host_count: Optional[int] = None
) -> int:
    """Per-host batch size for multi-host data loading."""
    host_index = jax.process_index() if host_index is None else host_index
    host_count = jax.process_count() if host_count is None else host_count
    base = global_batch // host_count
    extra = 1 if host_index < global_batch % host_count else 0
    return base + extra

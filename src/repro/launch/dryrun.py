import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the 512-chip production mesh on
# CPU placeholder devices; smoke tests and benches see 1 device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this produces, with ZERO device allocation (ShapeDtypeStruct
stand-ins):
  * compiled = jit(step).lower(specs).compile() on the production mesh —
    success proves the sharding config is coherent (no mismatched
    collectives, no uneven jit-input shardings);
  * compiled.memory_analysis()  -> per-device bytes (fits-in-HBM evidence);
  * compiled.cost_analysis()    -> FLOPs / bytes for the roofline terms;
  * parsed collective bytes from the post-SPMD HLO (launch/roofline.py).

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json, which
EXPERIMENTS.md §Dry-run / §Roofline and benchmarks/roofline_table.py read.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch whisper_tiny --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import traceback
from typing import Any, Dict, Optional

import jax

from repro import obs
from repro.configs import ARCH_IDS, SHAPES, get_config, skip_reason
from repro.core.backend import JIT_SAFE_KINDS, MatmulBackend
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import model_flops, roofline_terms
from repro.launch.specs import serve_cell_specs, train_cell_specs
from repro.models import model as M
from repro.models.sharding import DEFAULT_RULES, ShardingRules, use_sharding
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

TRAIN_ACCUM = 8  # grad-accumulation microbatches for train cells
# Per-arch overrides: larger models need smaller microbatches to fit HBM.
ACCUM_OVERRIDES = {"qwen2_vl_72b": 16, "qwen1_5_32b": 16, "internlm2_20b": 16}


def _mesh(kind: str):
    return make_production_mesh(multi_pod=(kind == "multi"))


def lower_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    backend: Optional[MatmulBackend] = None,
    rules: ShardingRules = DEFAULT_RULES,
    accum: int = TRAIN_ACCUM,
):
    """Returns (lowered, compiled, meta) for one cell."""
    mesh = _mesh(mesh_kind)
    cfg = get_config(arch)
    if backend is not None:
        cfg = dataclasses.replace(cfg, matmul_backend=backend)
    shape = SHAPES[shape_name]
    chips = mesh.devices.size

    with use_sharding(mesh, rules):
        if shape.kind == "train":
            opt_cfg = AdamWConfig()
            state_shapes, batch_shapes, state_sh, batch_sh = train_cell_specs(
                cfg, shape, mesh, opt_cfg, rules
            )
            # microbatch must stay >= the batch-shard count, or activations
            # fall back to replicated (divisibility rule) and per-device
            # work explodes.
            batch_shards = 1
            for ax in rules.rules.get("batch", ()):
                batch_shards *= mesh.shape.get(ax, 1)
            accum = max(1, min(accum, shape.global_batch // max(batch_shards, 1)))
            step = make_train_step(cfg, opt_cfg, accum_steps=accum)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            )
            lowered = jitted.lower(state_shapes, batch_shapes)
        elif shape.kind == "prefill":
            params_shapes, cache_shapes, batch_shapes, params_sh, cache_sh, batch_sh = (
                serve_cell_specs(cfg, shape, mesh, rules)
            )

            def prefill_fn(params, batch, cache):
                return M.apply_prefill(params, batch, cache, cfg)

            jitted = jax.jit(
                prefill_fn,
                in_shardings=(params_sh, batch_sh, cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(2,),
            )
            lowered = jitted.lower(params_shapes, batch_shapes, cache_shapes)
        else:  # decode
            params_shapes, cache_shapes, batch_shapes, params_sh, cache_sh, batch_sh = (
                serve_cell_specs(cfg, shape, mesh, rules)
            )
            if cfg.mrope:

                def decode_fn(params, tokens, positions, cache):
                    return M.apply_decode(
                        params, tokens, cache, cfg, positions=positions
                    )

                jitted = jax.jit(
                    decode_fn,
                    in_shardings=(
                        params_sh, batch_sh["tokens"], batch_sh["positions"], cache_sh,
                    ),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(3,),
                )
                lowered = jitted.lower(
                    params_shapes,
                    batch_shapes["tokens"],
                    batch_shapes["positions"],
                    cache_shapes,
                )
            else:

                def decode_fn(params, tokens, cache):
                    return M.apply_decode(params, tokens, cache, cfg)

                jitted = jax.jit(
                    decode_fn,
                    in_shardings=(params_sh, batch_sh["tokens"], cache_sh),
                    out_shardings=(None, cache_sh),
                    donate_argnums=(2,),
                )
                lowered = jitted.lower(
                    params_shapes, batch_shapes["tokens"], cache_shapes
                )

        compiled = lowered.compile()

    meta = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "chips": chips,
        "kind": shape.kind,
        "accum": accum if shape.kind == "train" else None,
        "backend": (backend.kind if backend else cfg.matmul_backend.kind),
    }
    return lowered, compiled, meta


def _memory_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": repr(e)}
    out = {}
    for attr in (
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ):
        val = getattr(ma, attr, None)
        if val is not None:
            out[attr] = int(val)
    if not out:
        out["repr"] = repr(ma)
    return out


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    *,
    backend: Optional[MatmulBackend] = None,
    rules: ShardingRules = DEFAULT_RULES,
    accum: int = TRAIN_ACCUM,
    tag: str = "",
) -> Dict[str, Any]:
    """Lower+compile one cell and extract all dry-run artifacts."""
    reason = skip_reason(arch, shape_name)
    if reason:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "skipped": reason}

    tr = obs.get_tracer()
    span = tr.begin(
        "dryrun.compile", cat="launch",
        arch=arch, shape=shape_name, mesh=mesh_kind,
    )
    lowered, compiled, meta = lower_cell(
        arch, shape_name, mesh_kind, backend=backend, rules=rules, accum=accum
    )
    tr.end(span)
    t_compile = span.duration

    # Execution-weighted static analysis (XLA's cost_analysis does NOT
    # multiply while-loop bodies by trip count — see launch/hlo_analysis).
    hlo_text = compiled.as_text()
    costs = analyze_hlo(hlo_text)
    from repro.core.compat import compiled_cost_analysis

    xla_cost = compiled_cost_analysis(compiled)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = meta["chips"]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = model_flops(
        cfg.param_count(), cfg.active_param_count(), tokens, shape.kind
    )
    # The partitioned HLO module is the per-device program.
    terms = roofline_terms(
        hlo_flops=costs.dot_flops,
        hlo_bytes=costs.hbm_bytes,
        coll_bytes=costs.collective_bytes,
        chips=chips,
        per_device=True,
    )
    global_flops = costs.dot_flops * chips
    result = {
        **meta,
        "tag": tag,
        "compile_seconds": round(t_compile, 1),
        "memory": _memory_dict(compiled),
        "cost_analysis": {
            "flops_per_device": costs.dot_flops,
            "hbm_bytes_per_device": costs.hbm_bytes,
            "flops_global": global_flops,
            "xla_flops_unscaled": float(xla_cost.get("flops", 0.0)),
        },
        "collectives": {
            "total": costs.collective_bytes,
            **{k: v for k, v in costs.collective_by_kind.items()},
        },
        "model_flops": mf,
        "useful_fraction": (mf / global_flops) if global_flops else None,
        "roofline": terms,
        "tokens": tokens,
    }
    return result


def save_result(result: Dict[str, Any], out_dir: str = OUT_DIR):
    os.makedirs(out_dir, exist_ok=True)
    tag = f"__{result['tag']}" if result.get("tag") else ""
    name = f"{result['arch']}__{result['shape']}__{result['mesh']}{tag}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(result, f, indent=2, default=str)
    return os.path.join(out_dir, name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true", help="run every (arch x shape)")
    ap.add_argument(
        "--backend",
        # Every dry-run cell is lowered under jit: only the jit-safe
        # registered kinds.
        choices=list(JIT_SAFE_KINDS),
        help="matmul routing, validated against the registered kinds; "
        "'auto' resolves per shape from the calibrated cost model at "
        "trace time (--depth becomes the max depth)",
    )
    ap.add_argument("--depth", type=int, default=1)
    ap.add_argument("--min-dim", type=int, default=2048)
    ap.add_argument("--accum", type=int, default=TRAIN_ACCUM)
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument(
        "--trace-out", default="",
        help="enable obs tracing and write a Chrome/Perfetto trace here",
    )
    args = ap.parse_args()
    if args.trace_out:
        obs.configure(enabled=True)

    backend = None
    if args.backend and args.backend != "naive":
        backend = MatmulBackend(kind=args.backend, depth=args.depth, min_dim=args.min_dim)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    failures = []
    for arch, shape in cells:
        for mesh_kind in meshes:
            tag = f"__{args.tag}" if args.tag else ""
            out_name = os.path.join(
                OUT_DIR, f"{arch}__{shape}__{mesh_kind}{tag}.json"
            )
            if args.skip_existing and os.path.exists(out_name):
                print(f"[skip existing] {arch} {shape} {mesh_kind}")
                continue
            print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...", flush=True)
            try:
                accum = (
                    ACCUM_OVERRIDES.get(arch, args.accum)
                    if args.accum == TRAIN_ACCUM
                    else args.accum
                )
                result = run_cell(
                    arch, shape, mesh_kind,
                    backend=backend, accum=accum, tag=args.tag,
                )
                path = save_result(result)
                if result.get("skipped"):
                    print(f"  SKIPPED: {result['skipped']}")
                else:
                    r = result["roofline"]
                    print(
                        f"  ok in {result['compile_seconds']}s | "
                        f"compute {r['compute_s']:.3e}s memory {r['memory_s']:.3e}s "
                        f"collective {r['collective_s']:.3e}s -> {r['bottleneck']}"
                    )
                    mem = result["memory"]
                    if "temp_size_in_bytes" in mem:
                        print(
                            f"  mem/device: args {mem.get('argument_size_in_bytes',0)/2**30:.2f} GiB, "
                            f"temps {mem['temp_size_in_bytes']/2**30:.2f} GiB"
                        )
                print(f"  -> {path}")
            except Exception as e:
                failures.append((arch, shape, mesh_kind, repr(e)))
                print(f"  FAILED: {e}")
                traceback.print_exc()
    if args.trace_out:
        from repro.obs import export

        export.write_trace(args.trace_out, metrics=obs.get_metrics())
        print(f"trace -> {args.trace_out}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        raise SystemExit(1)
    print("\nall requested cells OK")


if __name__ == "__main__":
    main()

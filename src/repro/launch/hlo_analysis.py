"""Static HLO analyzer with while-loop trip-count propagation.

XLA's compiled.cost_analysis() counts each while-loop BODY once — for a
scan-over-layers model with grad-accumulation that undercounts FLOPs by
orders of magnitude (layers x accum). This analyzer parses the post-SPMD
HLO text, recovers each while loop's trip count (XLA's own
known_trip_count backend_config, falling back to condition-constant
parsing), and walks the call graph multiplying nested execution counts,
producing:

  * dot_flops        — 2 * elems(out) * contraction_size per dot/conv
  * collective_bytes — operand bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute
  * hbm_bytes        — a fusion-level traffic estimate: operand + result
                       bytes of every non-trivial top-level instruction

All three are EXECUTION-WEIGHTED (multiplied through loop nests), which is
what the roofline terms need. Operand shapes are resolved through a
per-computation symbol table (HLO operands are %name references).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = ["analyze_hlo", "HloCosts"]

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shape_bytes(shapes: List[Tuple[str, str]]) -> int:
    return sum(_shape_elems(dims) * _DTYPE_BYTES.get(dt, 4) for dt, dims in shapes)


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    line: str
    result_shapes: List[Tuple[str, str]]
    operand_names: List[str]


@dataclasses.dataclass
class _Comp:
    name: str
    instrs: List[_Instr]
    symbols: Dict[str, List[Tuple[str, str]]]  # instr name -> result shapes

    def operand_shapes(self, ins: _Instr) -> List[Tuple[str, str]]:
        out: List[Tuple[str, str]] = []
        for nm in ins.operand_names:
            out.extend(self.symbols.get(nm, []))
        return out


@dataclasses.dataclass
class HloCosts:
    dot_flops: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    hbm_bytes: float = 0.0
    while_trip_counts: Dict[str, int] = dataclasses.field(default_factory=dict)

    def merge_scaled(self, other: "HloCosts", k: float):
        self.dot_flops += other.dot_flops * k
        self.collective_bytes += other.collective_bytes * k
        for kk, v in other.collective_by_kind.items():
            self.collective_by_kind[kk] = self.collective_by_kind.get(kk, 0.0) + v * k
        self.hbm_bytes += other.hbm_bytes * k


def _parse_computations(text: str) -> Dict[str, _Comp]:
    comps: Dict[str, _Comp] = {}
    current: Optional[_Comp] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*\S.*\{\s*$", s)
        if m and not line.startswith(" "):
            current = _Comp(name=m.group(1), instrs=[], symbols={})
            comps[current.name] = current
            continue
        if s == "}" and not line.startswith(" "):
            current = None
            continue
        if current is None or "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        mop = re.search(r"\b([a-z][a-z0-9\-]*)\(", rhs)
        if not mop:
            continue
        op = mop.group(1)
        pre, post = rhs[: mop.start()], rhs[mop.start():]
        result_shapes = _SHAPE_RE.findall(pre)
        # operand names inside the first balanced paren group
        depth = 0
        args_chars: List[str] = []
        for ch in post[post.index("("):]:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args_chars.append(ch)
        operand_names = _OPERAND_RE.findall("".join(args_chars))
        name = lhs.strip().lstrip("%").replace("ROOT ", "").strip()
        if name.startswith("ROOT"):
            name = name[4:].strip().lstrip("%")
        ins = _Instr(
            name=name, op=op, line=s,
            result_shapes=result_shapes, operand_names=operand_names,
        )
        current.instrs.append(ins)
        current.symbols[name] = result_shapes
    return comps


def _trip_count_from_cond(cond: Optional[_Comp]) -> int:
    if cond is None:
        return 1
    const_vals: Dict[str, int] = {}
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                const_vals[ins.name] = int(m.group(1))
    for ins in cond.instrs:
        if ins.op != "compare" and "compare" not in ins.line:
            continue
        names = ins.operand_names
        direction = (
            "LT" if "direction=LT" in ins.line
            else ("LE" if "direction=LE" in ins.line else None)
        )
        for cand in names:
            if cand in const_vals:
                n = const_vals[cand]
                if direction == "LE":
                    n += 1
                return max(n, 1)
    return 1


def _dot_flops(comp: _Comp, ins: _Instr) -> float:
    if not ins.result_shapes or not ins.operand_names:
        return 0.0
    res_elems = sum(_shape_elems(dims) for _, dims in ins.result_shapes)
    lhs_shapes = comp.symbols.get(ins.operand_names[0], [])
    if not lhs_shapes:
        return 2.0 * res_elems  # unknown contraction
    lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d]
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.line)
    if m and m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                k *= lhs_dims[i]
    return 2.0 * res_elems * k


# ops whose operand+result bytes approximate real HBM traffic at the
# post-fusion level. Producer result + consumer operand = write + read,
# which is exactly the two HBM touches of a materialized buffer. Excluded:
# reshape/bitcast/broadcast/transpose (layout-only or fused), raw
# elementwise (wrapped into kLoop fusions by the compiler), tuple plumbing.
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "copy",
    "dynamic-update-slice", "dynamic-slice", "gather", "scatter",
    "reduce", "reduce-window", "sort", "concatenate", "pad",
    "select-and-scatter", "cholesky", "triangular-solve",
}


def _analyze_comp(
    name: str,
    comps: Dict[str, _Comp],
    cache: Dict[str, HloCosts],
    stack: Tuple[str, ...] = (),
) -> HloCosts:
    if name in cache:
        return cache[name]
    comp = comps.get(name)
    if comp is None or name in stack:
        return HloCosts()
    costs = HloCosts()
    for ins in comp.instrs:
        if ins.op == "while":
            mb = re.search(r"body=%?([\w\.\-]+)", ins.line)
            mc = re.search(r"condition=%?([\w\.\-]+)", ins.line)
            mt = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.line)
            if mt:
                trips = int(mt.group(1))
            else:
                trips = _trip_count_from_cond(comps.get(mc.group(1)) if mc else None)
            costs.while_trip_counts[ins.name] = trips
            if mb:
                sub = _analyze_comp(mb.group(1), comps, cache, stack + (name,))
                costs.merge_scaled(sub, trips)
                for k, v in sub.while_trip_counts.items():
                    costs.while_trip_counts[f"{ins.name}/{k}"] = v * trips
            continue
        if ins.op == "conditional":
            # one branch executes per device: take the max-cost branch
            branches = re.findall(
                r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w\.\-,% ]+)",
                ins.line,
            )
            names = []
            for grp in branches:
                names.extend(nm.strip().lstrip("%") for nm in grp.split(","))
            subs = [
                _analyze_comp(nm, comps, cache, stack + (name,)) for nm in names if nm
            ]
            if subs:
                best = HloCosts(
                    dot_flops=max(s.dot_flops for s in subs),
                    collective_bytes=max(s.collective_bytes for s in subs),
                    hbm_bytes=max(s.hbm_bytes for s in subs),
                )
                for s in subs:
                    for kk, v in s.collective_by_kind.items():
                        best.collective_by_kind[kk] = max(
                            best.collective_by_kind.get(kk, 0.0), v
                        )
                costs.merge_scaled(best, 1.0)
            continue
        if ins.op in ("call", "custom-call", "async-start"):
            for mm in re.finditer(
                r"(?:to_apply|called_computations)=\{?%?([\w\.\-]+)",
                ins.line,
            ):
                sub = _analyze_comp(mm.group(1), comps, cache, stack + (name,))
                costs.merge_scaled(sub, 1.0)
        if ins.op == "fusion":
            mm = re.search(r"calls=%?([\w\.\-]+)", ins.line)
            if mm:
                sub = _analyze_comp(mm.group(1), comps, cache, stack + (name,))
                # dots/collectives inside the fusion execute once per call;
                # traffic is counted at the fusion boundary below.
                costs.dot_flops += sub.dot_flops
                costs.collective_bytes += sub.collective_bytes
                for kk, v in sub.collective_by_kind.items():
                    costs.collective_by_kind[kk] = costs.collective_by_kind.get(kk, 0.0) + v
        if ins.op in ("dot", "convolution"):
            costs.dot_flops += _dot_flops(comp, ins)
        kind = next((c for c in _COLLECTIVES if ins.op.startswith(c)), None)
        if kind and not ins.op.endswith("-done"):
            b = _shape_bytes(comp.operand_shapes(ins))
            costs.collective_bytes += b
            costs.collective_by_kind[kind] = costs.collective_by_kind.get(kind, 0.0) + b
        if ins.op in _TRAFFIC_OPS:
            op_bytes = _shape_bytes(comp.operand_shapes(ins))
            res_bytes = _shape_bytes(ins.result_shapes)
            if ins.op in ("dynamic-slice", "gather") or (
                ins.op == "fusion"
                and "dynamic-slice" in ins.name
                and "update" not in ins.name
            ):
                # reads only the slice, not the sliced operand
                costs.hbm_bytes += 2 * res_bytes
            elif ins.op == "dynamic-update-slice" or (
                ins.op == "fusion" and "dynamic-update-slice" in ins.name
            ):
                # XLA aliases DUS in place: the full buffer appears as an
                # operand AND the result but only the updated slice touches
                # HBM. Stash-shaped operands (same size as the result, often
                # via bitcast chains) are aliases, not reads — subtract all.
                aliased = 0
                for nm in ins.operand_names:
                    b = _shape_bytes(comp.symbols.get(nm, []))
                    if b and abs(b - res_bytes) < max(res_bytes // 64, 1):
                        aliased += b
                effective = max(op_bytes - aliased, res_bytes // 64)
                costs.hbm_bytes += 2 * effective
            else:
                costs.hbm_bytes += op_bytes + res_bytes
    cache[name] = costs
    return costs


def analyze_hlo(text: str, entry: Optional[str] = None) -> HloCosts:
    comps = _parse_computations(text)
    if not comps:
        return HloCosts()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w\.\-]+)", text, re.MULTILINE)
        entry = m.group(1) if m else next(iter(comps))
    cache: Dict[str, HloCosts] = {}
    return _analyze_comp(entry, comps, cache)

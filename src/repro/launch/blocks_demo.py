"""Out-of-core Strassen demo: multiply matrices bigger than the device budget.

Drives :mod:`repro.blocks` end to end — ingest dense operands into a host
block store (dict / RAM arena / npy memmap spill), walk the tagged
recursion tree level by level, stage the 7^depth leaf products through
device memory in budgeted async-pipelined waves, and verify the result
against the dense matmul.

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.blocks_demo --n 1024 \
      --budget-mb 1 --depth 3 --store memmap --check
  PYTHONPATH=src python -m repro.launch.blocks_demo --m 2048 --k 1024 \
      --n 1536 --budget-mb 2 --dtype bfloat16 --store arena

``--depth 0`` picks the shallowest depth whose leaf fits the budget.
Prints the scheduler's execution stats: staging waves, H2D/D2H bytes,
peak device bytes vs the budget, host store peak, and per-phase seconds.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main():
    # Scheme choices come from the plan registry, so a newly registered
    # bilinear plan is immediately drivable from this CLI.
    from repro.blocks.plan import BilinearPlan, get_plan, plan_names

    schemes = [
        n for n in plan_names() if isinstance(get_plan(n), BilinearPlan)
    ]
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1024, help="matrix side (square)")
    ap.add_argument("--m", type=int, default=0, help="rows of A (default --n)")
    ap.add_argument("--k", type=int, default=0, help="cols of A (default --n)")
    ap.add_argument("--depth", type=int, default=0,
                    help="recursion depth; 0 = shallowest that fits the budget")
    ap.add_argument("--budget-mb", type=float, default=64.0,
                    help="peak device bytes the leaf waves may occupy")
    ap.add_argument("--block", type=int, default=0,
                    help="store block side; 0 = one block per leaf")
    ap.add_argument("--store", choices=["dict", "arena", "memmap"], default="dict")
    ap.add_argument("--store-root", default=None,
                    help="spill directory for --store memmap")
    ap.add_argument("--dtype", choices=["float32", "bfloat16"], default="float32")
    ap.add_argument("--scheme", choices=schemes, default="strassen")
    ap.add_argument("--leaf-backend", default="auto",
                    help="matmul routing kind for the leaf waves")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async 2-deep staging pipeline")
    ap.add_argument("--check", action="store_true",
                    help="verify against the dense jnp.matmul")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="chaos injection: per-get block drop probability "
                    "(corruption and leaf failures are injected at "
                    "proportional rates); recovery recomputes from lineage")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the deterministic chaos harness")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None, help="write stats JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the run here")
    args = ap.parse_args()

    from repro import obs
    from repro.blocks.scheduler import min_depth_for_budget, strassen_oot_matmul
    from repro.core.backend import MatmulBackend

    if args.trace_out:
        obs.configure(enabled=True)

    m = args.m or args.n
    k = args.k or args.n
    n = args.n
    budget = int(args.budget_mb * 2**20)
    dtype = np.dtype(args.dtype) if args.dtype == "float32" else None
    if dtype is None:
        import ml_dtypes

        dtype = np.dtype(ml_dtypes.bfloat16)
    depth = args.depth or min_depth_for_budget(
        m, k, n, budget, dtype, pipelined=not args.no_prefetch
    )

    rng = np.random.default_rng(args.seed)
    a = rng.standard_normal((m, k)).astype(dtype)
    b = rng.standard_normal((k, n)).astype(dtype)
    op_bytes = max(a.nbytes, b.nbytes)
    print(
        f"A {a.shape} @ B {b.shape} {dtype.name}: operands "
        f"{op_bytes / 2**20:.1f} MiB each, device budget "
        f"{budget / 2**20:.1f} MiB "
        f"({'smaller than an operand — out-of-core' if budget < op_bytes else 'fits'}), "
        f"depth {depth} -> {7**depth} leaves",
        flush=True,
    )

    chaos = None
    if args.fault_rate > 0:
        from repro.blocks.recovery import ChaosConfig

        chaos = ChaosConfig(
            drop=args.fault_rate,
            corrupt=args.fault_rate * 0.4,
            leaf_fail_rate=args.fault_rate * 0.5,
            seed=args.chaos_seed,
        )
        print(
            f"chaos: drop {chaos.drop:.3f} / corrupt {chaos.corrupt:.3f} / "
            f"leaf-fail {chaos.leaf_fail_rate:.3f} (seed {chaos.seed}) — "
            "lineage recovery on"
        )

    backend = MatmulBackend(kind=args.leaf_backend, depth=2)
    out, stats = strassen_oot_matmul(
        a, b,
        depth=depth, budget_bytes=budget, scheme=args.scheme, backend=backend,
        block=args.block or None, prefetch=not args.no_prefetch,
        store=args.store, store_root=args.store_root,
        chaos=chaos,
    )

    print(
        f"done in {stats.total_s:.2f}s  "
        f"(divide {stats.divide_s:.2f}s, leaf {stats.leaf_s:.2f}s "
        f"[{stats.waves} waves x {stats.wave_size}], combine {stats.combine_s:.2f}s)"
    )
    print(
        f"pipeline: {'async 2-deep' if stats.prefetch else 'synchronous'} | "
        f"stage {stats.stage_s:.2f}s, fetch {stats.fetch_s:.2f}s, "
        f"overlap efficiency {stats.overlap_efficiency:.2f}"
    )
    print(
        f"device: peak {stats.peak_device_bytes / 2**20:.2f} / "
        f"{stats.budget_bytes / 2**20:.2f} MiB budget | staged "
        f"H2D {stats.h2d_bytes / 2**20:.1f} MiB, D2H {stats.d2h_bytes / 2**20:.1f} MiB "
        f"({stats.stage_dtype} staging)"
    )
    print(f"host store peak: {stats.host_store_peak_bytes / 2**20:.1f} MiB ({args.store})")
    if chaos is not None:
        print(
            f"faults: {stats.injected_faults} injected "
            f"({stats.lost_blocks} lost, {stats.corrupt_blocks} corrupt) | "
            f"{stats.recovered_blocks} recomputed from lineage, "
            f"{stats.leaf_retries} leaf retries, "
            f"{stats.unrecovered_faults} unrecovered | "
            f"rung {stats.rung} ({stats.degrades} degrades)"
        )

    if args.check:
        import jax.numpy as jnp

        want = np.asarray(jnp.matmul(jnp.asarray(a), jnp.asarray(b)))
        scale = float(np.abs(want.astype(np.float32)).max()) or 1.0
        err = float(
            np.abs(out.astype(np.float32) - want.astype(np.float32)).max() / scale
        )
        tol = 1e-2 if dtype.itemsize < 4 else 2e-3
        print(f"parity vs dense: rel err {err:.2e} ({'OK' if err < tol else 'FAIL'})")
        if err >= tol:
            raise SystemExit(1)

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(stats.to_dict(), f, indent=1)
        print(f"wrote {args.json_out}")

    if args.trace_out:
        from repro.obs import export

        export.write_trace(args.trace_out, metrics=obs.get_metrics())
        print(f"wrote {args.trace_out} ({len(obs.get_tracer().spans)} spans)")


if __name__ == "__main__":
    main()

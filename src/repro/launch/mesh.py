"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
initialization, and smoke tests must keep seeing 1 device.
"""
from __future__ import annotations

from repro.core.compat import make_mesh

__all__ = ["make_production_mesh", "make_mesh_for"]


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16x16 per pod; 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(n_devices: int, model_parallel: int = 16):
    """Elastic variant: fit (data, model) to an arbitrary device count."""
    from repro.runtime.elastic import plan_mesh

    shape, axes = plan_mesh(n_devices, model_parallel=model_parallel)
    return make_mesh(shape, axes)

"""Summarize dry-run JSONs into the EXPERIMENTS.md tables.

Usage: PYTHONPATH=src python -m repro.launch.summarize [--mesh single] [--md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def load(mesh: str, tag: str = ""):
    cells = []
    suffix = f"__{tag}" if tag else ""
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}{suffix}.json"))):
        base = os.path.basename(path)[: -len(".json")]
        parts = base.split("__")
        if (len(parts) == 3) != (not tag):
            continue
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(c, md=False):
    sep = " | " if md else "  "
    if c.get("skipped"):
        return sep.join([c["arch"], c["shape"], c["mesh"], "SKIP: " + c["skipped"]])
    r = c["roofline"]
    mem = c.get("memory", {})
    hbm = (mem.get("argument_size_in_bytes", 0) + mem.get("temp_size_in_bytes", 0)) / 2**30
    uf = c.get("useful_fraction")
    cols = [
        c["arch"], c["shape"], c["mesh"],
        f"{r['compute_s']:.2e}", f"{r['memory_s']:.2e}", f"{r['collective_s']:.2e}",
        r["bottleneck"],
        f"{uf:.2f}" if uf is not None else "-",
        f"{hbm:.1f}",
        f"{c.get('compile_seconds', 0):.0f}s",
    ]
    return sep.join(cols)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--md", action="store_true")
    args = ap.parse_args()
    cells = load(args.mesh, args.tag)
    hdr = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
           "bottleneck", "useful_frac", "HBM_GiB/dev", "compile"]
    if args.md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for c in cells:
            print("| " + fmt_row(c, md=True) + " |")
    else:
        print("  ".join(hdr))
        for c in cells:
            print(fmt_row(c))


if __name__ == "__main__":
    main()

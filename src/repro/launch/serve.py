"""Serving launcher: mesh-sharded batched inference for any assigned arch.

The serving twin of launch/train.py: fits the elastic mesh, shards params
and cache by the same logical rules as the dry-run, and runs the
prefill + decode loop of serving/engine.py under that sharding.

CPU-scale usage:
  PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma_9b \
      --smoke --batch 4 --prompt-len 32 --new-tokens 32
Add --mesh --model-parallel 2 under a multi-device XLA_FLAGS env to
exercise the sharded path.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.backend import JIT_SAFE_KINDS, MatmulBackend
from repro.launch.mesh import make_mesh_for
from repro.launch.specs import param_logical_axes, sharding_tree
from repro.models import model as M
from repro.models.frontends import make_stub_frames
from repro.models.sharding import DEFAULT_RULES, use_sharding
from repro.serving.engine import Engine, ServeConfig


def main():
    import dataclasses

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="phi4_mini_3_8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    # continuous-batching surface (ServeConfig)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode bucket width (requests resident at once)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page in the paged pool")
    ap.add_argument("--page-budget", type=int, default=0,
                    help="usable KV pages; 0 = slots * ceil(max_seq/page_size)")
    ap.add_argument("--admission", choices=["queue", "reject"], default="queue")
    ap.add_argument("--sync-interval", type=int, default=4,
                    help="decode steps between host<->device token syncs")
    ap.add_argument("--batching", choices=["continuous", "static"],
                    default="continuous",
                    help="scheduler: continuous admits mid-decode; static "
                    "gang-schedules full batches (baseline)")
    ap.add_argument("--request-timeout", type=float, default=0.0,
                    help="per-request watchdog seconds: a request still "
                    "decoding past this is evicted (reason 'timeout') and "
                    "its pages freed; 0 disables")
    ap.add_argument("--mesh", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument(
        "--backend",
        # prefill/decode are jitted: only the jit-safe registered kinds.
        choices=list(JIT_SAFE_KINDS),
        default=None,
        help="matmul routing, validated against the registered kinds; "
        "'auto' turns on the autotune dispatcher for every projection",
    )
    ap.add_argument("--strassen-depth", type=int, default=1)
    ap.add_argument("--strassen-min-dim", type=int, default=1024)
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the run here")
    args = ap.parse_args()
    if args.trace_out:
        from repro import obs

        obs.configure(enabled=True)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.backend:
        cfg = dataclasses.replace(
            cfg,
            matmul_backend=MatmulBackend(
                kind=args.backend,
                depth=max(args.strassen_depth, 1),
                min_dim=args.strassen_min_dim,
            ),
        )
    key = jax.random.PRNGKey(args.seed)

    mesh = None
    if args.mesh:
        mesh = make_mesh_for(jax.device_count(), args.model_parallel)
        print(f"mesh: {dict(mesh.shape)}")

    ctx = use_sharding(mesh, DEFAULT_RULES) if mesh is not None else _null()
    with ctx:
        if mesh is not None:
            p_shapes = jax.eval_shape(lambda k: M.init_params(cfg, k), key)
            p_sh = sharding_tree(p_shapes, mesh, param_logical_axes, DEFAULT_RULES)
            params = jax.jit(
                lambda k: M.init_params(cfg, k), out_shardings=p_sh
            )(key)
        else:
            params = M.init_params(cfg, key)

        # ServeConfig is the single serving-surface config; Engine applies
        # it to the model config via ServeConfig.apply_to.
        engine = Engine(
            cfg,
            params,
            ServeConfig(
                max_seq=args.max_seq,
                temperature=args.temperature,
                slots=args.slots,
                page_size=args.page_size,
                page_budget=args.page_budget,
                admission=args.admission,
                sync_interval=args.sync_interval,
                batching=args.batching,
                request_timeout_s=args.request_timeout,
            ),
        )
        prompts = jax.random.randint(
            key, (args.batch, args.prompt_len), 0, cfg.vocab
        )
        frames = (
            make_stub_frames(cfg, args.batch) if cfg.frontend == "audio_stub" else None
        )
        if cfg.is_encdec or frames is not None:
            # encoder-decoder archs serve through the legacy batched path
            t0 = time.perf_counter()
            tokens, stats = engine.generate(prompts, args.new_tokens, frames=frames)
            dt = time.perf_counter() - t0
            n = tokens.shape[0] * tokens.shape[1]
            print(
                f"arch={cfg.name} generated {tokens.shape} in {dt:.2f}s "
                f"({n/dt:.1f} tok/s incl. compile); stats={stats}"
            )
            _write_trace(args.trace_out, engine)
            return
        # request API: submit the batch as independent requests (staggered
        # lengths) and let the scheduler pack the decode bucket
        import numpy as np

        prompts_np = np.asarray(prompts)
        t0 = time.perf_counter()
        handles = [
            engine.submit(prompts_np[i], args.new_tokens + (i % 3))
            for i in range(args.batch)
        ]
        n = len(list(engine.stream(handles)))
        dt = time.perf_counter() - t0
        for h in handles:
            ttft, _ = h.latency_stats()
            print(
                f"  req {h.id}: {h.state.value} ({h.finish_reason}) "
                f"{len(h.tokens())} tokens, ttft={ttft:.3f}s"
            )
        print(
            f"arch={cfg.name} served {len(handles)} requests / {n} tokens "
            f"in {dt:.2f}s ({n/dt:.1f} tok/s incl. compile)"
        )
        print(f"serve_stats: {engine.serve_stats()}")
        _write_trace(args.trace_out, engine)


def _write_trace(path, engine):
    if not path:
        return
    from repro.obs import export

    export.write_trace(path, metrics=engine.metrics)
    obs = engine.stats()["obs"]
    print(
        f"wrote {path} ({obs['tracer']['spans']} spans, "
        f"{len(obs['metrics'])} metric series)"
    )


import contextlib


@contextlib.contextmanager
def _null():
    yield


if __name__ == "__main__":
    main()

"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, per the v5e hardware model:

    compute    = HLO_FLOPs            / (chips * 197e12 FLOP/s)
    memory     = HLO_bytes_accessed   / (chips * 819e9  B/s)
    collective = collective_bytes     / (chips * 50e9   B/s per ICI link)

HLO_FLOPs / bytes come from compiled.cost_analysis(). XLA:CPU reports
cost analysis for the PER-DEVICE partitioned module, so global = value *
chips; we record both and state the convention in EXPERIMENTS.md.

collective_bytes is not in cost_analysis: we parse the post-SPMD HLO text
and sum OPERAND sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict

__all__ = [
    "HW",
    "Hardware",
    "collective_bytes",
    "roofline_terms",
    "model_flops",
]


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link


HW = Hardware()

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# shape token like bf16[256,1024] (layout braces optional)
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind over the HLO module text."""
    totals: Dict[str, int] = {k: 0 for k in _COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        # find which collective op this line APPLIES (rhs op name), e.g.
        # %ag = bf16[8,128] all-gather(bf16[1,128] %x), dims=...
        rhs = stripped.split("=", 1)[1]
        op = None
        for kind in _COLLECTIVE_OPS:
            # match "<shapes> kind(" — op name directly before its args
            if re.search(rf"\]\S*\s+{kind}(-start)?\(", rhs) or rhs.lstrip().startswith(
                kind
            ):
                op = kind
                break
        if op is None:
            continue
        # operand shapes are the shape tokens INSIDE the call parens
        call = rhs[rhs.index("(") + 1 :]
        depth = 1
        args = []
        for ch in call:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args.append(ch)
        arg_str = "".join(args)
        for dtype, dims in _SHAPE_RE.findall(arg_str):
            totals[op] += _shape_bytes(dtype, dims)
    totals["total"] = sum(totals[k] for k in _COLLECTIVE_OPS)
    return totals


def model_flops(param_count: int, active_param_count: int, tokens: int, kind: str) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference fwd), N = active params."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * active_param_count * tokens


def roofline_terms(
    *,
    hlo_flops: float,
    hlo_bytes: float,
    coll_bytes: float,
    chips: int,
    per_device: bool,
    hw: Hardware = HW,
) -> Dict[str, float]:
    """Seconds for each roofline term. per_device: cost_analysis convention."""
    scale = 1.0 if per_device else 1.0 / chips
    t_compute = hlo_flops * scale / hw.peak_flops
    t_memory = hlo_bytes * scale / hw.hbm_bw
    t_coll = coll_bytes * scale / hw.ici_bw
    terms = {
        "compute_s": t_compute,
        "memory_s": t_memory,
        "collective_s": t_coll,
    }
    terms["bottleneck"] = max(terms, key=lambda k: terms[k])[: -2]
    terms["bound_s"] = max(t_compute, t_memory, t_coll)
    return terms

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run cells for the paper's OWN workload: standalone distributed matmul.

Lowers naive / Strassen-BFS / Strassen-2D distributed matmuls on the
production mesh and extracts the roofline terms — the direct analogue of
the paper's Fig 8/9 at TPU-pod scale, and the §Perf hillclimb target most
representative of the paper's technique (the in-layer embedding of
Strassen is analyzed separately and refuted; see EXPERIMENTS.md).

Usage:
  PYTHONPATH=src python -m repro.launch.matmul_cell --n 16384 \
      --strategies naive bfs_d1 bfs_d2 bfs_d3 2d_d1 --mesh single
"""
import argparse
import functools
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.distributed import strassen_2d, strassen_bfs_sharded
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms

OUT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)


def _naive(a, b, mesh):
    """MLLib/Marlin-analogue: classic sharded matmul (8 mults per 2x2)."""
    a = jax.lax.with_sharding_constraint(a, NamedSharding(mesh, P("data", None)))
    b = jax.lax.with_sharding_constraint(b, NamedSharding(mesh, P(None, "model")))
    out = jnp.matmul(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
    return jax.lax.with_sharding_constraint(out, NamedSharding(mesh, P("data", "model")))


def _bfs_replicated(a, b, mesh, depth):
    """CAPS 'unlimited memory' scheme: replicate inputs (n^2 fits easily),
    run all divide levels locally (zero comm), shard the 7^depth leaf batch
    over the WHOLE mesh, combine levels reshard downward."""
    from repro.core.strassen import strassen_matmul
    import jax.numpy as _jnp

    rep = NamedSharding(mesh, P())
    a = jax.lax.with_sharding_constraint(a, rep)
    b = jax.lax.with_sharding_constraint(b, rep)
    axes = tuple(ax for ax in ("pod", "data", "model") if ax in mesh.shape)
    batch = NamedSharding(mesh, P(axes, None, None))

    def leaf(ta, tb):
        ta = jax.lax.with_sharding_constraint(ta, batch)
        tb = jax.lax.with_sharding_constraint(tb, batch)
        out = _jnp.einsum("mij,mjk->mik", ta, tb)
        return jax.lax.with_sharding_constraint(out, batch)

    out = strassen_matmul(a, b, depth=depth, leaf_fn=leaf)
    return jax.lax.with_sharding_constraint(
        out, NamedSharding(mesh, P("data", "model"))
    )


def strategy_fn(name: str, mesh):
    if name == "naive":
        return functools.partial(_naive, mesh=mesh)
    if name == "shardmap1":
        # explicit (rows x 7) grid from the same device pool (4 idle of 256)
        import numpy as np
        from repro.core.distributed import strassen_shardmap_2d

        n_dev = mesh.devices.size
        rows = n_dev // 7
        devs = np.asarray(mesh.devices).reshape(-1)[: rows * 7].reshape(rows, 7)
        grid = jax.sharding.Mesh(devs, ("rows", "mult"))
        return functools.partial(strassen_shardmap_2d, mesh=grid)
    if name == "shardmap3d":
        import numpy as np
        from repro.core.distributed import strassen_shardmap_3d

        n_dev = mesh.devices.size
        side = int((n_dev // 7) ** 0.5)  # 256//7=36 -> 6x6
        devs = (
            np.asarray(mesh.devices).reshape(-1)[: side * side * 7]
            .reshape(side, side, 7)
        )
        grid = jax.sharding.Mesh(devs, ("rb", "cb", "mult"))
        # block (quadrant) output layout — the paper's Block data structure
        return functools.partial(strassen_shardmap_3d, mesh=grid, merge=False)
    kind, _, d = name.partition("_d")
    depth = int(d)
    if kind == "bfs":
        return functools.partial(strassen_bfs_sharded, mesh=mesh, depth=depth)
    if kind == "bfsrep":
        return functools.partial(_bfs_replicated, mesh=mesh, depth=depth)
    if kind == "2d":
        return functools.partial(strassen_2d, mesh=mesh, depth=depth)
    raise ValueError(name)


def run_cell(n: int, strategy: str, mesh_kind: str, dtype=jnp.bfloat16):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = mesh.devices.size
    fn = strategy_fn(strategy, mesh)
    spec = jax.ShapeDtypeStruct((n, n), dtype)
    if strategy in ("shardmap1", "shardmap3d"):
        # inputs live replicated on the explicit grid submesh
        import numpy as np
        if strategy == "shardmap1":
            rows = chips // 7
            devs = np.asarray(mesh.devices).reshape(-1)[: rows * 7].reshape(rows, 7)
            grid = jax.sharding.Mesh(devs, ("rows", "mult"))
            chips = rows * 7
        else:
            side = int((chips // 7) ** 0.5)
            devs = (
                np.asarray(mesh.devices).reshape(-1)[: side * side * 7]
                .reshape(side, side, 7)
            )
            grid = jax.sharding.Mesh(devs, ("rb", "cb", "mult"))
            chips = side * side * 7
        shard = NamedSharding(grid, P())
    else:
        shard = NamedSharding(mesh, P(("data",), None))
    span = obs.get_tracer().begin(
        "matmul_cell.compile", cat="launch", n=n, strategy=strategy, mesh=mesh_kind
    )
    jitted = jax.jit(fn, in_shardings=(shard, shard))
    compiled = jitted.lower(spec, spec).compile()
    obs.get_tracer().end(span)
    costs = analyze_hlo(compiled.as_text())
    terms = roofline_terms(
        hlo_flops=costs.dot_flops,
        hlo_bytes=costs.hbm_bytes,
        coll_bytes=costs.collective_bytes,
        chips=chips,
        per_device=True,
    )
    ma = compiled.memory_analysis()
    ideal = 2.0 * n**3 / chips  # useful flops per device
    result = {
        "workload": "paper_matmul",
        "n": n,
        "strategy": strategy,
        "mesh": mesh_kind,
        "chips": chips,
        "compile_seconds": round(span.duration, 1),
        "roofline": terms,
        "flops_per_device": costs.dot_flops,
        "useful_fraction": ideal / costs.dot_flops if costs.dot_flops else None,
        "collectives_by_kind": costs.collective_by_kind,
        "collective_bytes": costs.collective_bytes,
        "hbm_bytes": costs.hbm_bytes,
        "memory": {
            "argument_size_in_bytes": int(ma.argument_size_in_bytes),
            "temp_size_in_bytes": int(ma.temp_size_in_bytes),
        },
    }
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"matmul__n{n}__{strategy}__{mesh_kind}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument(
        "--strategies", nargs="+",
        default=["naive", "bfs_d1", "bfs_d2", "bfs_d3", "2d_d1", "2d_d2"],
    )
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    args = ap.parse_args()
    base = None
    for s in args.strategies:
        r = run_cell(args.n, s, args.mesh)
        t = r["roofline"]
        if s == "naive":
            base = t
        rel = f"  bound vs naive {t['bound_s']/base['bound_s']:.3f}x" if base else ""
        print(
            f"{s:8s} compute {t['compute_s']:.3e}  memory {t['memory_s']:.3e}  "
            f"collective {t['collective_s']:.3e} -> {t['bottleneck']}{rel}  "
            f"(useful {r['useful_fraction']:.2f})"
        )


if __name__ == "__main__":
    main()

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Perf hillclimb driver: scripted hypothesis -> change -> re-lower -> diff.

Each VARIANT is a named, reproducible modification of a dry-run cell
(backend routing, accumulation, attention tiling, sharding rules). The
driver lowers the variant, extracts the roofline terms, and prints the
delta vs the cell's baseline — the §Perf iteration log in EXPERIMENTS.md
is generated from these JSONs (tag = variant name).

Usage:
  PYTHONPATH=src python -m repro.launch.perf --cell internlm2_20b:train_4k:single \
      --variants baseline strassen_d1 winograd_d1
"""
import argparse
import dataclasses
from typing import Dict, Optional

from repro.core.backend import MatmulBackend
from repro.launch import dryrun
from repro.models.sharding import DEFAULT_RULES, ShardingRules

# A variant transforms (cfg_overrides, backend, rules, accum) knobs.


@dataclasses.dataclass
class Variant:
    name: str
    hypothesis: str
    backend: Optional[MatmulBackend] = None
    accum: Optional[int] = None
    cfg_overrides: Dict = dataclasses.field(default_factory=dict)
    rules: Optional[ShardingRules] = None


def _rules_with(**updates) -> ShardingRules:
    base = dict(DEFAULT_RULES.rules)
    base.update(updates)
    return ShardingRules(rules=base)


VARIANTS: Dict[str, Variant] = {
    v.name: v
    for v in [
        Variant("baseline", "paper-faithful framework defaults"),
        # --- the paper's technique applied to the model's projections
        Variant(
            "strassen_d1",
            "Strassen depth-1 on projections >= 2048: compute term x7/8 on "
            "routed matmuls; memory term grows ~ (7/4-1) on operand combos",
            backend=MatmulBackend(kind="strassen", depth=1, min_dim=2048),
        ),
        Variant(
            "strassen_d2",
            "depth-2: compute x(7/8)^2 on routed matmuls, more combine traffic",
            backend=MatmulBackend(kind="strassen", depth=2, min_dim=2048),
        ),
        Variant(
            "winograd_d1",
            "Winograd 7-mult/15-add: same compute as strassen_d1, ~17% fewer "
            "divide/combine adds -> lower memory term (beyond paper)",
            backend=MatmulBackend(kind="winograd", depth=1, min_dim=2048),
        ),
        # --- memory-term levers
        Variant(
            "accum_2x",
            "double grad accumulation: halves live activation stash; HBM "
            "temp down ~2x, weight re-read traffic up ~2x",
            accum=-2,  # marker: multiply default by 2
        ),
        Variant(
            "qchunk_1k",
            "larger attention q-chunk (512->1024): fewer stash rounds, "
            "bigger transient p-block; net HBM traffic down for long seq",
            cfg_overrides={"attn_q_chunk": 1024, "attn_k_chunk": 2048},
        ),
        Variant(
            "scan_group_8",
            "8-layer scan groups: halves boundary stash count vs 4 "
            "(recompute unchanged: remat already per-group)",
            cfg_overrides={"block_pattern": ("attn",) * 8},
        ),
        # --- family-specific levers
        Variant(
            "mlstm_chunk64",
            "chunkwise-parallel mLSTM (exact): matrix state written once "
            "per 64-token chunk instead of per token -> state HBM traffic "
            "/64; intra-chunk work becomes (64x64) MXU matmuls",
            cfg_overrides={"mlstm_chunk": 64},
        ),
        Variant(
            "mlstm_chunk128",
            "chunk=128: state traffic /128, quadratic intra term x2 vs 64",
            cfg_overrides={"mlstm_chunk": 128},
        ),
        Variant(
            "mlstm_chunk256",
            "chunk=256: state traffic /256, quadratic intra term x4 vs 64",
            cfg_overrides={"mlstm_chunk": 256},
        ),
        Variant(
            "moe_grouped",
            "per-batch-row MoE dispatch: data-dependent scatter/gather stay "
            "on their data shard -> routing-induced collectives vanish; "
            "capacity per group (same expected compute)",
            cfg_overrides={"moe_group_dispatch": True},
        ),
        Variant(
            "moe_grouped_accum4",
            "grouped dispatch + accum 4 (vs 8): half the per-microbatch "
            "grad reductions per step -> all-reduce bytes down ~2x; live "
            "activations up 2x",
            cfg_overrides={"moe_group_dispatch": True},
            accum=4,
        ),
        Variant(
            "moe_grouped_accum16",
            "grouped dispatch + accum 16: tests the reverse direction — "
            "smaller microbatches, more reduction rounds",
            cfg_overrides={"moe_group_dispatch": True},
            accum=16,
        ),
        Variant(
            "mlstm_chunk64_qchunk",
            "chunkwise mLSTM + bigger attention chunks (xlstm has no attn; "
            "isolates whether residual memory is mLSTM-side or elsewhere)",
            cfg_overrides={"mlstm_chunk": 64, "attn_q_chunk": 1024},
        ),
        # --- collective-term levers
        Variant(
            "no_fsdp",
            "replicate params over data axis (no FSDP): removes per-layer "
            "all-gathers -> collective term down; HBM args up by data-axis x",
            rules=_rules_with(fsdp=()),
        ),
        Variant(
            "fsdp_pod",
            "FSDP over (pod,data) both: param shards 2x smaller, all-gather "
            "crosses pods (DCI) — tests pod-axis sensitivity",
            rules=_rules_with(fsdp=("pod", "data")),
        ),
    ]
}


def run_variant(arch: str, shape: str, mesh: str, variant: Variant):
    accum = dryrun.ACCUM_OVERRIDES.get(arch, dryrun.TRAIN_ACCUM)
    if variant.accum is not None:
        accum = accum * 2 if variant.accum == -2 else variant.accum

    # config overrides ride through a monkeypatched get_config
    if variant.cfg_overrides:
        import repro.configs as configs

        orig = configs.get_config

        def patched(a, **kw):
            cfg = orig(a, **kw)
            return dataclasses.replace(cfg, **variant.cfg_overrides)

        configs.get_config = patched
        dryrun.get_config = patched
    try:
        result = dryrun.run_cell(
            arch, shape, mesh,
            backend=variant.backend,
            rules=variant.rules or DEFAULT_RULES,
            accum=accum,
            tag=variant.name,
        )
    finally:
        if variant.cfg_overrides:
            configs.get_config = orig
            dryrun.get_config = orig
    result["hypothesis"] = variant.hypothesis
    dryrun.save_result(result)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, help="arch:shape:mesh")
    ap.add_argument("--variants", nargs="+", default=["baseline"])
    args = ap.parse_args()
    arch, shape, mesh = args.cell.split(":")

    results = {}
    for name in args.variants:
        v = VARIANTS[name]
        print(f"[perf] {args.cell} variant={name}")
        print(f"       hypothesis: {v.hypothesis}")
        r = run_variant(arch, shape, mesh, v)
        results[name] = r
        t = r["roofline"]
        print(
            f"       compute {t['compute_s']:.3e}  memory {t['memory_s']:.3e}  "
            f"collective {t['collective_s']:.3e}  -> {t['bottleneck']}"
        )
    if "baseline" in results and len(results) > 1:
        base = results["baseline"]["roofline"]
        print("\ndeltas vs baseline:")
        for name, r in results.items():
            if name == "baseline":
                continue
            t = r["roofline"]
            print(
                f"  {name:16s} compute {t['compute_s']/base['compute_s']:.3f}x  "
                f"memory {t['memory_s']/base['memory_s']:.3f}x  "
                f"collective {t['collective_s']/base['collective_s']:.3f}x  "
                f"bound {t['bound_s']/base['bound_s']:.3f}x"
            )


if __name__ == "__main__":
    main()

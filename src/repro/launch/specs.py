"""Shape/sharding specs for every (arch x shape) dry-run cell.

Builds ShapeDtypeStruct stand-ins (weak-type-correct, shardable, zero
allocation) for train/prefill/decode step arguments, plus NamedSharding
trees derived from per-leaf LOGICAL axes. Logical assignment is by
parameter path (regex tail-match), so the same table covers raw params,
optimizer moments (same tails under m/ v/), and scan-stacked group params
(leading layer dim detected via 'groups/').
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs import Shape
from repro.models import model as M
from repro.models.config import ModelConfig
from repro.models.sharding import DEFAULT_RULES, ShardingRules
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import init_train_state

__all__ = [
    "param_logical_axes",
    "cache_logical_axes",
    "batch_logical_axes",
    "sharding_tree",
    "train_cell_specs",
    "serve_cell_specs",
    "path_of",
]

# (regex matched with .search against the path, logical axes for the BASE
# (unstacked) shape). Order matters: first hit wins.
_PARAM_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/unembedding$", ("fsdp", "vocab")),
    (r"embed/embedding$", ("vocab", "fsdp")),
    (r"w(q|k|v)/w$", ("fsdp", "heads")),
    (r"w(q|k|v)/b$", ("heads",)),
    (r"wo/w$", ("heads", "fsdp")),  # attention out OR mlstm output gate (D, dv)
    (r"wo/b$", ("heads",)),
    (r"(wi|wf)/w$", ("fsdp", None)),
    (r"(wi|wf)/b$", (None,)),
    (r"(up|gate|in_gate|in_rec|wa|wx)/w$", ("fsdp", "d_ff")),
    (r"(up|gate|in_gate|in_rec|wa|wx)/b$", ("d_ff",)),
    (r"down/w$", ("d_ff", "fsdp")),
    (r"down/b$", (None,)),
    (r"out/w$", ("d_ff", "fsdp")),  # mlstm/slstm/rglru output proj (wide, D)
    (r"out/b$", (None,)),
    (r"router/w$", (None, "experts")),
    (r"w_(gate|up)$", ("experts", "fsdp", "d_ff")),
    (r"w_down$", ("experts", "d_ff", "fsdp")),
    (r"mixer/w/w$", ("fsdp", None, None, "state")),  # slstm input proj
    (r"mixer/w/b$", (None, None, "state")),
    (r"mixer/r$", (None, None, "state", None)),  # slstm recurrent (4,H,dh,dh)
    (r"conv_w$", (None, "d_ff")),
    (r"conv_b$", ("d_ff",)),
    (r"lam$", ("d_ff",)),
    (r"(scale|bias)$", None),  # norms: replicate (None * ndim)
)

_CACHE_RULES: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"(^|/)pos$", ()),
    (r"/(k|v)$", ("batch", "kv_heads", "cache_seq", None)),
    (r"/C$", ("batch", None, "state", None)),  # mlstm matrix memory (B,H,dk,dv)
    (r"/n$", ("batch", None, "state")),
    (r"/m$", ("batch", None)),
    (r"/c$", ("batch", None, "state")),  # slstm
    (r"/h$", None),  # slstm (B,H,dh) / rglru (B,W): resolved by ndim below
    (r"/conv$", ("batch", None, "state")),
)


def path_of(key_path) -> str:
    parts = []
    for p in key_path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(parts)


def _match(rules, path: str, shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    ndim = len(shape)
    base_ndim = ndim - 1 if "groups/" in path else ndim  # scan-stacked leaf?
    for pattern, axes in rules:
        if re.search(pattern, path):
            if axes is None:
                if pattern == r"/h$":  # slstm (B,H,dh) vs rglru (B,W)
                    axes = ("batch", None, "state") if base_ndim == 3 else ("batch", "state")
                else:
                    return (None,) * ndim
            if len(axes) < ndim:  # leading layer-group dims replicate
                return (None,) * (ndim - len(axes)) + tuple(axes)
            assert len(axes) == ndim, (path, shape, axes)
            return tuple(axes)
    return (None,) * ndim


def param_logical_axes(path: str, shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    return _match(_PARAM_RULES, path, shape)


def cache_logical_axes(path: str, shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    return _match(_CACHE_RULES, path, shape)


def batch_logical_axes(name: str, shape: Tuple[int, ...]) -> Tuple[Optional[str], ...]:
    return ("batch",) + (None,) * (len(shape) - 1)


def sharding_tree(
    shapes_tree,
    mesh: Mesh,
    logical_fn,
    rules: ShardingRules = DEFAULT_RULES,
):
    """Map a pytree of ShapeDtypeStructs -> NamedSharding tree."""

    def one(key_path, leaf):
        path = path_of(key_path)
        axes = logical_fn(path, tuple(leaf.shape))
        return NamedSharding(mesh, rules.spec(mesh, axes, leaf.shape))

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


# ------------------------------------------------------------------ cells


def _batch_specs(cfg: ModelConfig, shape: Shape, *, with_labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if with_labels:
        specs["labels"] = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if cfg.frontend == "audio_stub":
        specs["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.enc_seq, cfg.d_model), jnp.dtype(cfg.dtype)
        )
    if cfg.mrope:
        specs["positions"] = jax.ShapeDtypeStruct((b, s, 3), jnp.int32)
    return specs


def train_cell_specs(
    cfg: ModelConfig,
    shape: Shape,
    mesh: Mesh,
    opt_cfg: AdamWConfig,
    rules: ShardingRules = DEFAULT_RULES,
):
    """(state_shapes, batch_shapes, state_shardings, batch_shardings)."""
    key = jax.random.PRNGKey(0)
    state_shapes = jax.eval_shape(
        lambda k: init_train_state(cfg, opt_cfg, k), key
    )
    batch_shapes = _batch_specs(cfg, shape, with_labels=True)
    state_sh = sharding_tree(state_shapes, mesh, param_logical_axes, rules)
    batch_sh = sharding_tree(
        batch_shapes, mesh, lambda p, s: batch_logical_axes(p, s), rules
    )
    return state_shapes, batch_shapes, state_sh, batch_sh


def serve_cell_specs(
    cfg: ModelConfig,
    shape: Shape,
    mesh: Mesh,
    rules: ShardingRules = DEFAULT_RULES,
):
    """Specs for prefill (full seq) or decode (1 token + cache of seq_len)."""
    b, s = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)
    params_shapes = jax.eval_shape(lambda k: M.init_params(cfg, k), key)
    cache_shapes = jax.eval_shape(lambda: M.init_cache(cfg, b, s))
    params_sh = sharding_tree(params_shapes, mesh, param_logical_axes, rules)
    cache_sh = sharding_tree(cache_shapes, mesh, cache_logical_axes, rules)

    if shape.kind == "prefill":
        batch_shapes = _batch_specs(cfg, shape, with_labels=False)
    else:  # decode: one new token
        # decode against an encoder context needs no frames (cross-KV cached)
        batch_shapes = {"tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32)}
        if cfg.mrope:
            batch_shapes["positions"] = jax.ShapeDtypeStruct((b, 1, 3), jnp.int32)
    batch_sh = sharding_tree(
        batch_shapes, mesh, lambda p, s2: batch_logical_axes(p, s2), rules
    )
    return params_shapes, cache_shapes, batch_shapes, params_sh, cache_sh, batch_sh

"""Training launcher: config -> mesh -> data -> jitted step -> ckpt loop.

Real-cluster posture on any device count:
  * fits the canonical mesh to the available devices (elastic),
  * shards params/opt-state/batch via the same logical rules as the
    dry-run (launch/specs.py),
  * auto-resumes from the newest complete checkpoint,
  * straggler watchdog triggers checkpoint+restart recommendation.

CPU-scale usage (see examples/train_e2e.py for the packaged version):
  PYTHONPATH=src python -m repro.launch.train --arch phi4_mini_3_8b \
      --smoke --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time

import jax

from repro import obs
from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.core.backend import MatmulBackend
from repro.data.pipeline import DataConfig, SyntheticLM, shard_for_host
from repro.launch.mesh import make_mesh_for
from repro.launch.specs import batch_logical_axes, param_logical_axes, sharding_tree
from repro.models.sharding import DEFAULT_RULES, use_sharding
from repro.optim.adamw import AdamWConfig
from repro.runtime.checkpoint import CheckpointManager, save_pytree
from repro.runtime.elastic import StragglerMonitor
from repro.training.train_step import init_train_state, make_train_step

# Clean exit for "checkpointed and stopped on sustained straggler": the
# job supervisor restarts the run (plan_mesh re-fits to the survivors)
# instead of treating it as a crash. Mirrors EX_TEMPFAIL.
STRAGGLER_EXIT_CODE = 75


def build(cfg, opt_cfg, *, batch, seq, accum, mesh=None, rules=DEFAULT_RULES, seed=0):
    """Returns (state, pipeline, jitted_step). mesh=None -> single device."""
    data = SyntheticLM(cfg, DataConfig(batch=batch, seq_len=seq, seed=seed))
    step = make_train_step(cfg, opt_cfg, accum_steps=accum)

    if mesh is None:
        state = init_train_state(cfg, opt_cfg, jax.random.PRNGKey(seed))
        return state, data, jax.jit(step, donate_argnums=(0,))

    with use_sharding(mesh, rules):
        state_shapes = jax.eval_shape(
            lambda k: init_train_state(cfg, opt_cfg, k), jax.random.PRNGKey(seed)
        )
        state_sh = sharding_tree(state_shapes, mesh, param_logical_axes, rules)
        init_fn = jax.jit(
            lambda k: init_train_state(cfg, opt_cfg, k), out_shardings=state_sh
        )
        state = init_fn(jax.random.PRNGKey(seed))
        sample = data(0)
        batch_shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), sample
        )
        batch_sh = sharding_tree(
            batch_shapes, mesh, lambda p, s: batch_logical_axes(p, s), rules
        )
        jitted = jax.jit(
            step,
            in_shardings=(state_sh, batch_sh),
            out_shardings=(state_sh, None),
            donate_argnums=(0,),
        )
    return state, data, jitted


def train_loop(
    cfg,
    opt_cfg,
    *,
    steps,
    batch,
    seq,
    accum=1,
    mesh=None,
    ckpt_dir=None,
    save_every=50,
    log_every=10,
    seed=0,
    stats_out=None,
    stop_on_straggler=False,
):
    """Run the training loop; returns (state, loss history).

    ``stats_out``: optional dict filled with run measurements
    (median_step_time_s, steps_run) — the step-time evidence the summary
    JSON and the autotune-vs-hand-picked comparison report.

    ``stop_on_straggler``: when the watchdog flags a sustained slowdown,
    force-save a checkpoint (regardless of ``save_every`` alignment) and
    stop the loop cleanly; the flag's evidence lands in
    ``stats_out['straggler']`` so the launcher can exit with
    :data:`STRAGGLER_EXIT_CODE`. Off, the flag is logged and training
    continues (the library-default behavior tests rely on).
    """
    state, data, jitted = build(
        cfg, opt_cfg, batch=batch, seq=seq, accum=accum, mesh=mesh, seed=seed
    )
    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, save_every=save_every, keep_last=3)
        resumed, state_r = mgr.restore_latest(state)
        if resumed is not None:
            state, start = state_r, resumed
            print(f"[resume] from step {resumed}")

    watchdog = StragglerMonitor()
    history = []
    with use_sharding(mesh, DEFAULT_RULES) if mesh is not None else _null():
        for step_i in range(start, steps):
            watchdog.start_step()
            state, metrics = jitted(state, data(step_i))
            jax.block_until_ready(metrics["loss"])
            flagged = watchdog.end_step()
            loss = float(metrics["loss"])
            history.append(loss)
            if step_i % log_every == 0 or step_i == steps - 1:
                print(
                    f"step {step_i:5d} loss {loss:.4f} "
                    f"gnorm {float(metrics.get('grad_norm', 0.0)):.3f} "
                    f"lr {float(metrics.get('lr', 0.0)):.2e} "
                    f"({watchdog.median_step_time*1e3:.0f} ms/step)",
                    flush=True,
                )
            if mgr:
                mgr.maybe_save(state, step_i + 1, extra={"loss": loss})
            if flagged:
                reason = watchdog.flag_reason()
                print(
                    f"[straggler] sustained slowdown "
                    f"(step/median x{reason['median']:.2f}, "
                    f"streak {reason['streak']}) — checkpoint + restart advised"
                )
                if stop_on_straggler:
                    if ckpt_dir:
                        save_pytree(
                            state, ckpt_dir, step=step_i + 1,
                            extra={"loss": loss, "straggler": reason},
                        )
                        print(f"[straggler] checkpointed step {step_i + 1}; stopping")
                    if stats_out is not None:
                        stats_out["straggler"] = reason
                    break
                if mgr:
                    mgr.maybe_save(state, step_i + 1, extra={"straggler": True})
    if stats_out is not None:
        stats_out["median_step_time_s"] = watchdog.median_step_time
        stats_out["steps_run"] = len(history)  # executed, not planned
    return state, history


@contextlib.contextmanager
def _null():
    yield


def autotune_step_delta(
    baseline_cfg,
    opt_cfg,
    *,
    auto_step_time,
    steps,
    batch,
    seq,
    accum=1,
    mesh=None,
):
    """Measure the autotuned-vs-hand-picked step-time delta (ROADMAP item).

    Runs a short baseline segment on ``baseline_cfg`` (the hand-picked
    backend; same shapes, no checkpointing) and returns the summary-JSON
    fields: step_time_handpicked_s, step_time_delta_s and — when the
    baseline measured — step_time_delta_pct. Use enough ``steps`` that the
    median is not dominated by the compile step.
    """
    base_stats = {}
    train_loop(
        baseline_cfg, opt_cfg,
        steps=steps, batch=batch, seq=seq, accum=accum, mesh=mesh,
        ckpt_dir=None, log_every=max(steps, 1), stats_out=base_stats,
    )
    base_t = base_stats.get("median_step_time_s", 0.0)
    out = {
        "step_time_handpicked_s": base_t,
        "step_time_delta_s": auto_step_time - base_t,
    }
    if base_t:
        out["step_time_delta_pct"] = 100.0 * (auto_step_time - base_t) / base_t
    print(
        f"[autotune] step time {auto_step_time*1e3:.1f} ms vs hand-picked "
        f"{base_t*1e3:.1f} ms ({out.get('step_time_delta_pct', 0.0):+.1f}%)"
    )
    return out


def main():
    from repro.core.backend import JIT_SAFE_KINDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), required=True)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--mesh", action="store_true", help="build a device mesh")
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument(
        "--backend",
        # The train step is jitted: only the jit-safe registered kinds
        # (use repro.launch.blocks_demo for the out-of-core surface).
        choices=list(JIT_SAFE_KINDS),
        default="naive",
        help="matmul routing, validated against the registered kinds; "
        "'auto' sets matmul_autotune=True so every dense projection "
        "resolves from the calibrated dispatcher (--strassen-depth "
        "becomes the max depth it may pick)",
    )
    ap.add_argument("--strassen-depth", type=int, default=1)
    ap.add_argument("--strassen-min-dim", type=int, default=1024)
    ap.add_argument(
        "--compare-steps", type=int, default=0,
        help="with --backend auto: also run this many steps on the "
        "hand-picked (config default) backend and record the measured "
        "step-time delta in the summary JSON",
    )
    ap.add_argument(
        "--no-exit-on-straggler", action="store_true",
        help="keep training through a straggler flag instead of "
        "checkpointing and exiting with code 75 for a supervised restart",
    )
    ap.add_argument("--summary-out", default=None,
                    help="write a run-summary JSON (loss, step time, "
                    "backend, autotune telemetry) here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the run here")
    args = ap.parse_args()
    if args.trace_out:
        obs.configure(enabled=True)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    baseline_cfg = cfg  # the hand-picked backend, for --compare-steps
    if args.backend == "auto":
        cfg = dataclasses.replace(
            cfg,
            matmul_autotune=True,
            matmul_backend=MatmulBackend(
                kind="auto", depth=max(args.strassen_depth, 1),
                min_dim=args.strassen_min_dim,
            ),
        )
    elif args.backend != "naive":
        cfg = dataclasses.replace(
            cfg,
            matmul_backend=MatmulBackend(
                kind=args.backend, depth=args.strassen_depth, min_dim=args.strassen_min_dim
            ),
        )
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 5), total_steps=args.steps)
    mesh = None
    if args.mesh:
        mesh = make_mesh_for(jax.device_count(), args.model_parallel)
        print(f"mesh: {dict(mesh.shape)}")

    per_host = shard_for_host(args.batch)
    run_stats = {}
    t0 = time.time()
    _, history = train_loop(
        cfg, opt_cfg,
        steps=args.steps, batch=per_host, seq=args.seq, accum=args.accum,
        mesh=mesh, ckpt_dir=args.ckpt_dir, save_every=args.save_every,
        stats_out=run_stats,
        stop_on_straggler=not args.no_exit_on_straggler,
    )
    dt = time.time() - t0
    print(f"done: {len(history)} steps in {dt:.1f}s; loss {history[0]:.3f} -> {history[-1]:.3f}")

    summary = {
        "arch": args.arch,
        "backend": args.backend,
        "steps": args.steps,
        "wall_s": dt,
        "loss_first": history[0],
        "loss_last": history[-1],
        **run_stats,
    }
    if args.backend == "auto":
        from repro.core import autotune

        summary["autotune"] = {
            "kinds": autotune.get_telemetry().kind_counts(),
            "calibration": autotune.calibration_snapshot(),
        }
        if args.compare_steps > 0:
            summary.update(
                autotune_step_delta(
                    baseline_cfg, opt_cfg,
                    auto_step_time=run_stats.get("median_step_time_s", 0.0),
                    steps=args.compare_steps, batch=per_host, seq=args.seq,
                    accum=args.accum, mesh=mesh,
                )
            )
    if args.summary_out:
        import json

        with open(args.summary_out, "w") as f:
            json.dump(summary, f, indent=1)
        print(f"wrote {args.summary_out}")
    if args.trace_out:
        from repro.obs import export

        export.write_trace(args.trace_out, metrics=obs.get_metrics())
        print(f"wrote {args.trace_out}")
    if "straggler" in run_stats:
        raise SystemExit(STRAGGLER_EXIT_CODE)


if __name__ == "__main__":
    main()

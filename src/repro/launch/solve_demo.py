"""Out-of-core SPIN solver demo: invert / triangular-solve under a budget.

Drives :mod:`repro.blocks.solve` end to end — build a well-conditioned SPD
(or triangular) input, walk the SPIN block-recursive dataflow plan, run
the dense leaves on device, and route every recursive multiply back
through the tagged out-of-core scheduler whenever its working set exceeds
the device budget. Verifies against ``jnp.linalg.inv`` /
``jax.scipy.linalg.solve_triangular``.

Usage (CPU-scale):
  PYTHONPATH=src python -m repro.launch.solve_demo --n 1024 \
      --budget-mb 1 --op inverse --store memmap --check
  PYTHONPATH=src python -m repro.launch.solve_demo --n 2048 --op trsm \
      --nrhs 512 --budget-mb 2 --dtype bfloat16 --check

``--depth 0`` picks the shallowest depth whose dense leaf fits the
budget. Prints the solver's execution stats: nested out-of-core matmul
runs, staging waves, H2D/D2H bytes, peak device bytes vs the budget, and
(with ``--fault-rate``) the chaos/recovery tallies.
"""
from __future__ import annotations

import argparse
import json

import numpy as np


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--n", type=int, default=1024, help="matrix side (square)")
    ap.add_argument("--op", choices=["inverse", "trsm"], default="inverse")
    ap.add_argument("--nrhs", type=int, default=0,
                    help="RHS columns for --op trsm (default --n)")
    ap.add_argument("--upper", action="store_true",
                    help="solve an upper-triangular system (--op trsm)")
    ap.add_argument("--depth", type=int, default=0,
                    help="solver recursion depth; 0 = shallowest whose "
                    "dense leaf fits the budget")
    ap.add_argument("--budget-mb", type=float, default=64.0,
                    help="peak device bytes any wave may occupy")
    ap.add_argument("--store", choices=["dict", "arena", "memmap"], default="dict")
    ap.add_argument("--store-root", default=None,
                    help="spill directory for --store memmap")
    ap.add_argument("--dtype", choices=["float32", "bfloat16"], default="float32")
    ap.add_argument("--scheme", choices=["strassen", "winograd"], default="strassen",
                    help="matmul scheme for the nested out-of-core multiplies")
    ap.add_argument("--no-prefetch", action="store_true",
                    help="disable the async staging pipeline in nested multiplies")
    ap.add_argument("--check", action="store_true",
                    help="verify against the dense device solver")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="chaos injection: per-get block drop probability in "
                    "the nested out-of-core multiplies (corruption and leaf "
                    "failures at proportional rates); lineage recovery heals")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the deterministic chaos harness")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json-out", default=None, help="write stats JSON here")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace of the run here")
    args = ap.parse_args()

    from repro import obs
    from repro.blocks.solve import (
        solver_min_depth_for_budget,
        spin_inverse_oot,
        triangular_solve_oot,
    )

    if args.trace_out:
        obs.configure(enabled=True)

    n = args.n
    nrhs = (args.nrhs or n) if args.op == "trsm" else n
    budget = int(args.budget_mb * 2**20)
    dtype = np.dtype(args.dtype) if args.dtype == "float32" else None
    if dtype is None:
        import ml_dtypes

        dtype = np.dtype(ml_dtypes.bfloat16)
    leaf_kind = "inv" if args.op == "inverse" else (
        "trsm_upper" if args.upper else "trsm_lower"
    )
    depth = args.depth or solver_min_depth_for_budget(
        n, budget, np.result_type(dtype, np.float32),
        nrhs=nrhs if args.op == "trsm" else None, leaf_kind=leaf_kind,
    )

    rng = np.random.default_rng(args.seed)
    g = rng.standard_normal((n, n)).astype(np.float32)
    if args.op == "inverse":
        # Well-conditioned SPD: every leading principal block invertible,
        # which the SPIN recursion requires.
        a = (g @ g.T / n + np.eye(n, dtype=np.float32) * 2.0).astype(dtype)
        operands = (a,)
    else:
        t = np.triu(g) if args.upper else np.tril(g)
        t = (t / np.sqrt(n) + np.eye(n, dtype=np.float32) * 2.0).astype(dtype)
        b = rng.standard_normal((n, nrhs)).astype(dtype)
        operands = (t, b)
    op_bytes = max(x.nbytes for x in operands)
    print(
        f"{args.op} {n}x{n}" + (f" rhs {n}x{nrhs}" if args.op == "trsm" else "")
        + f" {dtype.name}: largest operand {op_bytes / 2**20:.1f} MiB, "
        f"device budget {budget / 2**20:.1f} MiB "
        f"({'smaller than an operand — out-of-core' if budget < op_bytes else 'fits'}), "
        f"solver depth {depth}",
        flush=True,
    )

    chaos = None
    if args.fault_rate > 0:
        from repro.blocks.recovery import ChaosConfig

        chaos = ChaosConfig(
            drop=args.fault_rate,
            corrupt=args.fault_rate * 0.4,
            leaf_fail_rate=args.fault_rate * 0.5,
            seed=args.chaos_seed,
        )
        print(
            f"chaos: drop {chaos.drop:.3f} / corrupt {chaos.corrupt:.3f} / "
            f"leaf-fail {chaos.leaf_fail_rate:.3f} (seed {chaos.seed}) — "
            "lineage recovery on"
        )

    common = dict(
        depth=depth, budget_bytes=budget, scheme=args.scheme,
        prefetch=not args.no_prefetch, store=args.store,
        store_root=args.store_root, chaos=chaos,
    )
    if args.op == "inverse":
        out, stats = spin_inverse_oot(operands[0], **common)
    else:
        out, stats = triangular_solve_oot(
            operands[0], operands[1], lower=not args.upper, **common
        )

    print(
        f"done in {stats.total_s:.2f}s  "
        f"({stats.oot_runs} nested out-of-core multiplies, "
        f"{stats.leaves} matmul leaves in {stats.waves} waves; "
        f"leaf {stats.leaf_s:.2f}s)"
    )
    print(
        f"device: peak {stats.peak_device_bytes / 2**20:.2f} / "
        f"{stats.budget_bytes / 2**20:.2f} MiB budget | staged "
        f"H2D {stats.h2d_bytes / 2**20:.1f} MiB, D2H {stats.d2h_bytes / 2**20:.1f} MiB "
        f"({stats.stage_dtype} staging) | overlap efficiency "
        f"{stats.overlap_efficiency:.2f}"
    )
    if chaos is not None:
        print(
            f"faults: {stats.injected_faults} injected "
            f"({stats.lost_blocks} lost, {stats.corrupt_blocks} corrupt) | "
            f"{stats.recovered_blocks} recomputed from lineage, "
            f"{stats.leaf_retries} leaf retries, "
            f"{stats.unrecovered_faults} unrecovered | "
            f"rung {stats.rung} ({stats.degrades} degrades)"
        )

    if args.check:
        import jax.numpy as jnp

        if args.op == "inverse":
            want = np.asarray(jnp.linalg.inv(jnp.asarray(operands[0])))
        else:
            import jax.scipy.linalg as jsl

            want = np.asarray(jsl.solve_triangular(
                jnp.asarray(operands[0]), jnp.asarray(operands[1]),
                lower=not args.upper,
            ))
        scale = float(np.abs(want.astype(np.float32)).max()) or 1.0
        err = float(
            np.abs(out.astype(np.float32) - want.astype(np.float32)).max() / scale
        )
        tol = 1e-2 if dtype.itemsize < 4 else 1e-5
        print(f"parity vs dense: rel err {err:.2e} ({'OK' if err < tol else 'FAIL'})")
        if err >= tol:
            raise SystemExit(1)

    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(stats.to_dict(), f, indent=1)
        print(f"wrote {args.json_out}")

    if args.trace_out:
        from repro.obs import export

        export.write_trace(args.trace_out, metrics=obs.get_metrics())
        print(f"wrote {args.trace_out} ({len(obs.get_tracer().spans)} spans)")


if __name__ == "__main__":
    main()

"""whisper-tiny [audio]: 4+4L d384 6H ff1536 v51865 — enc-dec backbone.

Conv/mel frontend is a STUB: input_specs provides (B, 1500, 384) frame
embeddings. LayerNorm + plain-GELU MLPs, tied output head, sinusoidal
positions (no RoPE). [arXiv:2212.04356]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,          # decoder layers
    enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    head_dim=64,
    act="gelu",
    glu=False,
    norm="layernorm",
    norm_eps=1e-5,
    rope_theta=0.0,
    tie_embeddings=True,
    frontend="audio_stub",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    enc_layers=2,
    enc_seq=24,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=128,
    head_dim=16,
    act="gelu",
    glu=False,
    norm="layernorm",
    norm_eps=1e-5,
    rope_theta=0.0,
    tie_embeddings=True,
    frontend="audio_stub",
    dtype="float32",
    remat=False,
)

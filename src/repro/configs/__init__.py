"""Architecture registry: the 10 assigned archs + the paper's own workload.

Each <arch>.py exports CONFIG (the exact published configuration) and
SMOKE_CONFIG (a reduced same-family config for CPU tests). Input shapes
(train_4k / prefill_32k / decode_32k / long_500k) are defined here because
they are shared by every LM architecture.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple

from repro.models.config import ModelConfig

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "Shape",
    "get_config",
    "get_smoke_config",
    "list_archs",
    "cell_is_runnable",
    "skip_reason",
]

ARCH_IDS: Tuple[str, ...] = (
    "phi4_mini_3_8b",
    "internlm2_20b",
    "qwen1_5_32b",
    "gemma_7b",
    "olmoe_1b_7b",
    "qwen2_moe_a2_7b",
    "xlstm_1_3b",
    "whisper_tiny",
    "qwen2_vl_72b",
    "recurrentgemma_9b",
)


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


def _module(arch: str):
    if arch not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; have {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).SMOKE_CONFIG
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def list_archs() -> List[str]:
    return list(ARCH_IDS)


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    """Why an (arch x shape) dry-run cell is skipped, or None if runnable.

    Policy (DESIGN.md §Arch-applicability):
      * long_500k requires sub-quadratic context handling -> only the SSM
        (xlstm) and hybrid (recurrentgemma, whose attention is a 2048-token
        local window) archs run it.
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k":
        kinds = set(cfg.layer_kinds())
        if "attn" in kinds or cfg.is_encdec:
            return "long_500k skipped: full-attention arch (quadratic KV cache)"
    return None


def cell_is_runnable(arch: str, shape_name: str) -> bool:
    return skip_reason(arch, shape_name) is None

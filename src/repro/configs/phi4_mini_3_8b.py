"""phi4-mini-3.8b [dense]: 32L d3072 24H (GQA kv=8) ff8192 v200064.

RoPE + SwiGLU + GQA. [arXiv:2412.08905; hf microsoft/Phi-4-mini-instruct]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=8192,
    vocab=200064,
    # remat/scan boundary every 4 layers (halves stash vs per-layer scan)
    block_pattern=("attn",) * 4,
    head_dim=128,
    act="silu",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="phi4-mini-smoke",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=192,
    vocab=128,
    head_dim=16,
    act="silu",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)

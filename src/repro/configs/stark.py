"""The paper's own workload configs: square matmuls 4096..16384.

These drive the paper-table benchmarks (Fig 8/9/10/11, Table VI/VII) and
the examples. Depth is the paper's p - q (recursion levels); the paper's
partition count b = 2**depth.
"""
import dataclasses
from typing import Tuple

from repro.core.backend import MatmulBackend


@dataclasses.dataclass(frozen=True)
class StarkWorkload:
    n: int                      # matrix side (paper: 2^p)
    depth: int                  # recursion levels (paper: p - q)
    scheme: str = "strassen"    # strassen | winograd | naive8
    fused: bool = False         # beyond-paper Pallas-fused last level

    @property
    def partitions(self) -> int:
        return 2**self.depth


# Paper §V sizes (scaled set used for CPU-measurable benchmarks first).
PAPER_SIZES: Tuple[int, ...] = (4096, 8192, 16384)
BENCH_SIZES: Tuple[int, ...] = (256, 512, 1024, 2048)
PARTITIONS: Tuple[int, ...] = (2, 4, 8, 16, 32)

DEFAULT = StarkWorkload(n=1024, depth=2)

BACKENDS = {
    "naive": MatmulBackend(kind="naive"),
    "stark": MatmulBackend(kind="strassen", depth=2, min_dim=256),
    "stark_winograd": MatmulBackend(kind="winograd", depth=2, min_dim=256),
    "stark_fused": MatmulBackend(kind="strassen_fused", depth=2, min_dim=256),
}

"""qwen1.5-32b [dense]: 64L d5120 40H (GQA kv=40 = MHA) ff27392 v152064.

QKV bias (the Qwen1.5 signature). [hf Qwen/Qwen1.5-32B]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    # remat/scan boundary every 4 layers (halves stash vs per-layer scan)
    block_pattern=("attn",) * 4,
    head_dim=128,
    act="silu",
    glu=True,
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen1.5-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab=128,
    head_dim=16,
    act="silu",
    glu=True,
    qkv_bias=True,
    dtype="float32",
    remat=False,
)

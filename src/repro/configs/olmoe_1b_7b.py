"""olmoe-1b-7b [moe]: 16L d2048 16H (kv=16) expert_ff=1024 v50304, 64e top-8.

64 routed experts, top-8, no shared experts. [arXiv:2409.02060]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab=50304,
    # remat/scan boundary every 4 layers (halves stash vs per-layer scan)
    block_pattern=("attn",) * 4,
    head_dim=128,
    act="silu",
    glu=True,
    rope_theta=10000.0,
    n_experts=64,
    top_k=8,
    d_expert=1024,
)

SMOKE_CONFIG = ModelConfig(
    name="olmoe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=128,
    head_dim=16,
    act="silu",
    glu=True,
    n_experts=8,
    top_k=2,
    d_expert=32,
    capacity_factor=2.0,
    dtype="float32",
    remat=False,
)

"""qwen2-vl-72b [vlm]: 80L d8192 64H (GQA kv=8) ff29568 v152064 — M-RoPE.

Vision frontend is a STUB: the backbone receives token ids plus (B, S, 3)
M-RoPE position triplets; dynamic resolution lives in the (stubbed) ViT.
[arXiv:2409.12191]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab=152064,
    # remat/scan boundary every 4 layers (halves stash vs per-layer scan)
    block_pattern=("attn",) * 4,
    head_dim=128,
    act="silu",
    glu=True,
    qkv_bias=True,
    rope_theta=1000000.0,
    mrope=True,
    mrope_sections=(16, 24, 24),
    frontend="vision_stub",
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-vl-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=128,
    head_dim=16,
    act="silu",
    glu=True,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(4, 2, 2),
    frontend="vision_stub",
    dtype="float32",
    remat=False,
)

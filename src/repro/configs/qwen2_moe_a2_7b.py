"""qwen2-moe-a2.7b [moe]: 24L d2048 16H (kv=16) expert_ff=1408 v151936.

60 routed experts top-4 + 4 shared experts. [hf Qwen/Qwen1.5-MoE-A2.7B]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=0,
    vocab=151936,
    # remat/scan boundary every 4 layers (halves stash vs per-layer scan)
    block_pattern=("attn",) * 4,
    head_dim=128,
    act="silu",
    glu=True,
    qkv_bias=True,
    rope_theta=1000000.0,
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_expert=1408,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2-moe-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=128,
    head_dim=16,
    act="silu",
    glu=True,
    qkv_bias=True,
    n_experts=6,
    top_k=2,
    n_shared_experts=2,
    d_expert=32,
    capacity_factor=2.0,
    dtype="float32",
    remat=False,
)

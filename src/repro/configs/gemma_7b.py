"""gemma-7b [dense]: 28L d3072 16H (MHA kv=16) ff24576 v256000.

GeGLU, head_dim=256 (wider than d_model/heads). [arXiv:2403.08295]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv_heads=16,
    d_ff=24576,
    vocab=256000,
    # remat/scan boundary every 4 layers (halves stash vs per-layer scan)
    block_pattern=("attn",) * 4,
    head_dim=256,
    act="gelu",
    glu=True,
    rope_theta=10000.0,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=192,
    vocab=128,
    head_dim=32,  # wider-than-d_model/heads preserved
    act="gelu",
    glu=True,
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)

"""xlstm-1.3b [ssm]: 48L d2048 4H ff=0 v50304 — mLSTM + sLSTM blocks.

xLSTM[7:1] layout: 7 mLSTM blocks per sLSTM block. Recurrent state is O(1)
in sequence length -> long_500k eligible. [arXiv:2405.04517]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    rope_theta=0.0,
    block_pattern=(
        "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm",
    ),
    mlstm_qk_dim=1024,
    mlstm_v_dim=2048,
)

SMOKE_CONFIG = ModelConfig(
    name="xlstm-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=128,
    rope_theta=0.0,
    block_pattern=("mlstm", "mlstm", "mlstm", "slstm"),
    mlstm_qk_dim=32,
    mlstm_v_dim=64,
    dtype="float32",
    remat=False,
)

"""recurrentgemma-9b [hybrid]: 38L d4096 16H (MQA kv=1) ff12288 v256000.

Griffin layout — (RG-LRU, RG-LRU, local attention) repeating 1:2, local
window 2048, GeGLU MLPs. State is O(window) -> long_500k eligible.
[arXiv:2402.19427]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    act="gelu",
    glu=True,
    rope_theta=10000.0,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=2048,
    rnn_width=4096,
    tie_embeddings=True,
)

SMOKE_CONFIG = ModelConfig(
    name="recurrentgemma-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=128,
    head_dim=16,
    act="gelu",
    glu=True,
    block_pattern=("rglru", "rglru", "local_attn"),
    local_window=16,
    rnn_width=64,
    tie_embeddings=True,
    dtype="float32",
    remat=False,
)

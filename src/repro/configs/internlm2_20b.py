"""internlm2-20b [dense]: 48L d6144 48H (GQA kv=8) ff16384 v92544.

GQA. [arXiv:2403.17297; hf internlm/internlm2-20b]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    # remat/scan boundary every 4 layers (halves stash vs per-layer scan)
    block_pattern=("attn",) * 4,
    head_dim=128,
    act="silu",
    glu=True,
    rope_theta=1000000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="internlm2-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=1,
    d_ff=256,
    vocab=128,
    head_dim=16,
    act="silu",
    glu=True,
    dtype="float32",
    remat=False,
)
